"""Batched async-slot engine: vmap-of-single-tree oracle + kernel parity.

Mirrors ``tests/test_batched_search.py`` for the *async* engine:
``run_async_search_batched`` carries per-tree RNG streams with exactly the
single engine's split structure and applies the same per-tick masking
``vmap`` gives a batched ``while_loop``, so its output must agree *exactly*
(bit-identical root statistics) with ``jax.vmap`` of
:func:`repro.core.async_search.run_async_search` — for every batch size,
under batch padding, for both ``uct`` and ``wu_uct`` score kinds, and with
the Pallas kernel on or off.
"""

import jax
import numpy as np
import pytest

from repro.core import PolicyConfig, SearchConfig
from repro.core.async_search import run_async_search
from repro.core.batched_async_search import run_async_search_batched
from repro.envs import make_bandit_tree


def _cfg(kind="wu_uct", stat_mode="wu", **kw):
    base = dict(
        num_simulations=24,
        wave_size=4,
        max_depth=5,
        max_sim_steps=5,
        max_width=3,
        gamma=0.9,
        policy=PolicyConfig(kind=kind),
        stat_mode=stat_mode,
    )
    base.update(kw)
    return SearchConfig(**base)


def _roots_and_rngs(env, B, seed=0):
    roots = jax.vmap(env.init)(jax.random.split(jax.random.PRNGKey(seed), B))
    rngs = jax.random.split(jax.random.PRNGKey(seed + 1), B)
    return roots, rngs


def _assert_results_equal(single, batched, lanes=slice(None)):
    for field in ("root_n", "action", "tree_size", "ticks", "max_o"):
        np.testing.assert_array_equal(
            np.asarray(getattr(single, field))[lanes],
            np.asarray(getattr(batched, field))[lanes],
            err_msg=field,
        )
    np.testing.assert_allclose(
        np.asarray(single.root_v)[lanes],
        np.asarray(batched.root_v)[lanes],
        rtol=1e-6,
        err_msg="root_v",
    )


@pytest.mark.parametrize("B", [1, 3, 8])
@pytest.mark.parametrize(
    "kind,stat_mode", [("wu_uct", "wu"), ("uct", "none")]
)
def test_batched_async_matches_vmapped_single(B, kind, stat_mode):
    """ISSUE acceptance: bit-identical to jax.vmap(run_async_search) for
    B ∈ {1, 3, 8} and both score kinds."""
    env = make_bandit_tree(depth=4, num_actions=3, seed=3)
    cfg = _cfg(kind, stat_mode)
    roots, rngs = _roots_and_rngs(env, B, seed=11)
    single = jax.jit(jax.vmap(lambda s, k: run_async_search(env, cfg, s, k)))(
        roots, rngs
    )
    batched = jax.jit(lambda s, k: run_async_search_batched(env, cfg, s, k))(
        roots, rngs
    )
    _assert_results_equal(single, batched)


def test_batched_async_ragged_padding_is_independent():
    """Trees are independent: a ragged batch padded out to a larger B must
    reproduce the unpadded lanes bit-exactly (padding lanes change nothing),
    even though padded lanes keep the while_loop alive for extra ticks."""
    env = make_bandit_tree(depth=4, num_actions=3, seed=5)
    # Padding lanes run a *different* (longer) search than the real lanes so
    # the master loop's trip count genuinely differs between the two runs.
    cfg = _cfg("wu_uct", "wu", num_simulations=16, wave_size=4)
    B_real, B_pad = 5, 8
    roots_pad, rngs_pad = _roots_and_rngs(env, B_pad, seed=21)
    roots_real = jax.tree.map(lambda x: x[:B_real], roots_pad)
    rngs_real = rngs_pad[:B_real]

    fn = jax.jit(lambda s, k: run_async_search_batched(env, cfg, s, k))
    padded = fn(roots_pad, rngs_pad)
    real = fn(roots_real, rngs_real)
    _assert_results_equal(padded, real, lanes=slice(0, B_real))


def test_batched_async_kernel_path_matches_reference_path():
    """use_kernel=True (Pallas tree_select) and False (jnp oracle) agree."""
    env = make_bandit_tree(depth=4, num_actions=4, seed=7)
    cfg = _cfg("wu_uct", "wu", max_width=4)
    roots, rngs = _roots_and_rngs(env, B=6, seed=2)
    with_kernel = jax.jit(
        lambda s, k: run_async_search_batched(env, cfg, s, k, use_kernel=True)
    )(roots, rngs)
    without = jax.jit(
        lambda s, k: run_async_search_batched(env, cfg, s, k, use_kernel=False)
    )(roots, rngs)
    _assert_results_equal(with_kernel, without)


def test_batched_async_treep_stat_mode_matches_vmap():
    """Virtual-loss bookkeeping rides the same masked batched variants."""
    env = make_bandit_tree(depth=4, num_actions=3, seed=9)
    cfg = _cfg("treep", "vl")
    roots, rngs = _roots_and_rngs(env, B=4, seed=31)
    single = jax.jit(jax.vmap(lambda s, k: run_async_search(env, cfg, s, k)))(
        roots, rngs
    )
    batched = jax.jit(lambda s, k: run_async_search_batched(env, cfg, s, k))(
        roots, rngs
    )
    _assert_results_equal(single, batched)


def test_batched_async_every_rollout_completes():
    """Visit-mass conservation at the roots: each tree's completed child
    visits sum to T minus at most the early root-sims (all children pending
    in the first fill), mirroring the single-engine sanity check."""
    env = make_bandit_tree(depth=4, num_actions=4, seed=0)
    cfg = _cfg("wu_uct", "wu", num_simulations=32, wave_size=8, max_width=4)
    roots, rngs = _roots_and_rngs(env, B=6, seed=1)
    res = jax.jit(lambda s, k: run_async_search_batched(env, cfg, s, k))(
        roots, rngs
    )
    T, W = cfg.num_simulations, cfg.wave_size
    sums = np.asarray(res.root_n).sum(axis=1)
    assert ((T - 2 * W <= sums) & (sums <= T)).all(), sums
    assert not np.asarray(res.overflowed).any()
