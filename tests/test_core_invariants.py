"""Tree-statistics invariants of the wave engine (any wave size).

These are the paper's implicit correctness conditions:
* every initiated rollout is eventually observed — ``O == 0`` after search;
* each rollout contributes exactly one completed visit at the root;
* no node stays pending;
* values remain within the achievable-return envelope.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import SearchConfig, PolicyConfig
from repro.core import tree as tree_lib
from repro.core.wu_uct import run_search
from repro.envs import make_bandit_tree, make_random_mdp


def _final_tree_search(env, cfg, seed=0):
    """Run a search but return the final tree (re-implements the wave loop
    tail to expose internals)."""
    from repro.core.wu_uct import _phase1_select, _phase2_work, _phase3_settle

    key = jax.random.PRNGKey(seed)
    root_state = env.init(key)
    capacity = cfg.num_simulations + cfg.wave_size + 1
    tree = tree_lib.init_tree(root_state, capacity, env.num_actions)

    @jax.jit
    def wave(tree, rng):
        rng, k_sel, k_sim = jax.random.split(rng, 3)
        tree, slots, _ = _phase1_select(tree, k_sel, cfg)
        cs, re, dc, rets = _phase2_work(env, cfg, tree, slots, k_sim)
        tree = _phase3_settle(tree, cfg, slots, cs, re, dc, rets)
        return tree, rng

    rng = key
    for _ in range(cfg.num_simulations // cfg.wave_size):
        tree, rng = wave(tree, rng)
    return jax.device_get(tree)


@pytest.mark.parametrize("wave_size", [1, 4, 16])
def test_o_returns_to_zero_and_counts(wave_size):
    depth, A = 4, 3
    env = make_bandit_tree(depth=depth, num_actions=A, seed=3)
    cfg = SearchConfig(
        num_simulations=48,
        wave_size=wave_size,
        max_depth=depth + 1,
        max_sim_steps=depth + 1,
        max_width=A,
        gamma=1.0,
        policy=PolicyConfig(kind="wu_uct"),
        stat_mode="wu",
    )
    tree = _final_tree_search(env, cfg)

    np.testing.assert_array_equal(tree.O, 0.0)          # all observed
    assert not tree.pending.any()                        # no half-born nodes
    assert tree.N[0] == cfg.num_simulations              # root visits = T_max
    kids = tree.children[0]
    child_n = sum(tree.N[k] for k in kids if k >= 0)
    assert child_n <= tree.N[0]
    # Values bounded by the max achievable return (rewards in [0,1), γ=1).
    assert np.all(tree.N >= 0)
    active = tree.N > 0
    assert np.all(tree.V[active] <= depth + 1e-5)
    assert np.all(tree.V[active] >= -1e-6)
    # Parent/child link consistency.
    size = int(tree.size)
    for idx in range(1, size):
        p = tree.parent[idx]
        a = tree.action[idx]
        assert tree.children[p, a] == idx
        assert tree.depth[idx] == tree.depth[p] + 1


def test_stochastic_env_search_invariants():
    env = make_random_mdp(num_states=16, num_actions=3, horizon=8, seed=5)
    cfg = SearchConfig(
        num_simulations=32,
        wave_size=8,
        max_depth=6,
        max_sim_steps=8,
        max_width=3,
        gamma=0.95,
        policy=PolicyConfig(kind="wu_uct"),
        stat_mode="wu",
    )
    tree = _final_tree_search(env, cfg)
    np.testing.assert_array_equal(tree.O, 0.0)
    assert tree.N[0] == cfg.num_simulations
    assert not tree.pending.any()


# ---------------------------------------------------------------------------
# Property tests on the incomplete/complete update pair (Algorithms 2 & 3):
# any interleaving of paired updates leaves O == 0 and N == #completions,
# and V equals the plain running mean of the injected discounted returns.
# ---------------------------------------------------------------------------


def _chain_tree(length: int, gamma: float, rewards):
    env = make_bandit_tree(depth=length + 1, num_actions=1, seed=0)
    key = jax.random.PRNGKey(0)
    tree = tree_lib.init_tree(env.init(key), capacity=length + 2, num_actions=1)
    # Build a chain 0 -> 1 -> ... -> length with given edge rewards.
    for i in range(1, length + 1):
        tree = tree._replace(
            parent=tree.parent.at[i].set(i - 1),
            action=tree.action.at[i].set(0),
            children=tree.children.at[i - 1, 0].set(i),
            depth=tree.depth.at[i].set(i),
            R=tree.R.at[i].set(rewards[i - 1]),
            size=jnp.int32(i + 1),
        )
    return tree


@settings(max_examples=25, deadline=None)
@given(
    data=st.data(),
    length=st.integers(min_value=1, max_value=5),
    n_rollouts=st.integers(min_value=1, max_value=6),
)
def test_update_interleaving_invariants(data, length, n_rollouts):
    gamma = 0.9
    rewards = data.draw(
        st.lists(
            st.floats(min_value=-1, max_value=1, allow_nan=False, width=32),
            min_size=length,
            max_size=length,
        )
    )
    returns = data.draw(
        st.lists(
            st.floats(min_value=-1, max_value=1, allow_nan=False, width=32),
            min_size=n_rollouts,
            max_size=n_rollouts,
        )
    )
    tree = _chain_tree(length, gamma, rewards)
    leaf = jnp.int32(length)

    # Build a random interleaving: each rollout issues incomplete then
    # (later) complete, in hypothesis-chosen order.
    ops = []
    for i in range(n_rollouts):
        ops.append(("inc", i))
    # completes permuted
    perm = data.draw(st.permutations(list(range(n_rollouts))))
    for i in perm:
        pos = data.draw(st.integers(min_value=0, max_value=len(ops)))
        ops.insert(pos, ("done", i))
    # Enforce inc-before-done per rollout index.
    seen_inc = set()
    fixed = []
    pending_done = []
    for op, i in ops:
        if op == "inc":
            seen_inc.add(i)
            fixed.append(("inc", i))
            still = [j for j in pending_done if j in seen_inc]
            for j in still:
                fixed.append(("done", j))
                pending_done.remove(j)
        else:
            if i in seen_inc:
                fixed.append(("done", i))
            else:
                pending_done.append(i)
    for j in pending_done:
        fixed.append(("done", j))

    inc = jax.jit(tree_lib.incomplete_update)
    comp = jax.jit(lambda t, n, r: tree_lib.complete_update(t, n, r, gamma))
    max_o = 0.0
    for op, i in fixed:
        if op == "inc":
            tree = inc(tree, leaf)
        else:
            tree = comp(tree, leaf, jnp.float32(returns[i]))
        max_o = max(max_o, float(tree.O[0]))
        assert float(tree.O[0]) >= 0.0

    tree = jax.device_get(tree)
    np.testing.assert_array_equal(tree.O[: length + 1], 0.0)
    np.testing.assert_array_equal(tree.N[: length + 1], n_rollouts)

    # V at each node must equal the running mean of its discounted returns —
    # identical for every completion order that injects the same returns in
    # the same sequence?  Means are order-independent: check against the mean.
    for node in range(length, -1, -1):
        r_bar = np.zeros(n_rollouts)
        for k, i in enumerate([i for op, i in fixed if op == "done"]):
            acc = returns[i]
            for e in range(length, node - 1, -1):
                acc = (rewards[e - 1] if e >= 1 else 0.0) + gamma * acc
            r_bar[k] = acc
        np.testing.assert_allclose(tree.V[node], r_bar.mean(), rtol=2e-4, atol=2e-4)
