"""End-to-end behaviour tests for the paper's system.

Covers the paper's top-level claims at CPU scale:
  1. WU-UCT solves planning tasks (finds optimal arms / completes levels);
  2. performance is insensitive to the worker count (Fig. 4c-d);
  3. WU-UCT beats virtual-loss TreeP on exploitation (Sec. 4);
  4. naive parallelization shows exploration collapse; WU-UCT does not;
  5. the serving engine (continuous batching) matches naive generation.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_config
from repro.core.baselines import make_algorithm
from repro.envs import make_bandit_tree, make_tap_game
from repro.envs.bandit_tree import solve_bandit_tree


def test_wu_uct_finds_optimal_arm():
    env = make_bandit_tree(depth=4, num_actions=4, seed=0)
    _, opt_a, _ = solve_bandit_tree(4, 4, 0, gamma=1.0)
    cfg = make_config(
        "wu_uct", num_simulations=128, wave_size=8, max_depth=8,
        max_sim_steps=8, max_width=4, gamma=1.0,
    )
    fn = make_algorithm("wu_uct", env, cfg)
    state = env.init(jax.random.PRNGKey(0))
    hits = sum(
        int(fn(state, jax.random.PRNGKey(t)).action) == opt_a for t in range(5)
    )
    assert hits >= 4


def test_worker_count_insensitivity():
    """Fig 4(c-d): visit distribution quality is stable across wave sizes."""
    env = make_bandit_tree(depth=4, num_actions=4, seed=2)
    _, opt_a, _ = solve_bandit_tree(4, 4, 2, gamma=1.0)
    shares = []
    for w in (1, 4, 16):
        cfg = make_config(
            "wu_uct", num_simulations=128, wave_size=w, max_depth=8,
            max_sim_steps=8, max_width=4, gamma=1.0,
        )
        fn = make_algorithm("wu_uct", env, cfg)
        state = env.init(jax.random.PRNGKey(0))
        share = []
        for t in range(4):
            res = fn(state, jax.random.PRNGKey(10 + t))
            n = np.asarray(res.root_n)
            share.append(n[opt_a] / n.sum())
        shares.append(np.mean(share))
    # Optimal-arm visit share must not collapse as W grows.
    assert min(shares) > 0.45, shares
    assert max(shares) - min(shares) < 0.35, shares


def test_wu_uct_beats_treep_exploitation():
    """Sec. 4 exploitation failure: large virtual loss flattens TreeP's visit
    distribution; WU-UCT keeps exploiting the best arm."""
    env = make_bandit_tree(depth=4, num_actions=4, seed=0)
    _, opt_a, _ = solve_bandit_tree(4, 4, 0, gamma=1.0)
    state = env.init(jax.random.PRNGKey(0))

    def opt_share(algo, **kw):
        cfg = make_config(
            algo, num_simulations=128, wave_size=16, max_depth=8,
            max_sim_steps=8, max_width=4, gamma=1.0, **kw,
        )
        fn = make_algorithm(algo, env, cfg)
        vals = []
        for t in range(4):
            res = fn(state, jax.random.PRNGKey(50 + t))
            n = np.asarray(res.root_n)
            vals.append(n[opt_a] / n.sum())
        return np.mean(vals)

    wu = opt_share("wu_uct")
    tp = opt_share("treep", r_vl=5.0)
    assert wu > tp + 0.1, (wu, tp)


def test_wu_uct_reduces_duplicate_selection():
    """Sec. 2.2 collapse of exploration: within a wave, WU-UCT's O statistics
    diversify stop-nodes relative to stale-stats selection (treep r_vl=0 is
    exactly eq. (2) with no in-flight correction)."""
    env = make_bandit_tree(depth=5, num_actions=4, seed=7)
    state = env.init(jax.random.PRNGKey(0))
    dups = {}
    for name, algo, kw in [
        ("naive", "treep", dict(r_vl=0.0)),
        ("wu_uct", "wu_uct", {}),
    ]:
        cfg = make_config(
            algo, num_simulations=96, wave_size=16, max_depth=8,
            max_sim_steps=8, max_width=4, gamma=1.0, **kw,
        )
        fn = make_algorithm(algo, env, cfg)
        vals = [
            float(fn(state, jax.random.PRNGKey(60 + t)).dup_selections)
            for t in range(3)
        ]
        dups[name] = np.mean(vals)
    assert dups["wu_uct"] < dups["naive"], dups


def test_serving_engine_matches_naive_generation():
    from repro.configs import get_reduced
    from repro.models import forward, init_params
    from repro.serving import ServeConfig, ServingEngine

    cfg = get_reduced("llama3-8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(
        cfg, params, ServeConfig(batch_slots=2, max_len=32, eos_token=1)
    )
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(2, cfg.vocab_size, size=8)) for _ in range(3)]
    outs = engine.run(prompts, max_ticks=40)

    # Naive greedy generation, one request at a time.
    for prompt, out in zip(prompts, outs):
        assert len(out) > 0
        toks = list(prompt)
        naive = []
        for _ in range(len(out)):
            logits, _ = forward(
                params, cfg, {"tokens": jnp.asarray(toks, jnp.int32)[None]}
            )
            t = int(jnp.argmax(logits[0, len(toks) - 1]))
            naive.append(t)
            toks.append(t)
            if t == 1:
                break
        assert naive == out[: len(naive)], (naive, out)


def test_serving_engine_batched_admission_matches_sequential():
    """Multi-prompt admission (one ragged batched prefill + one cache
    splice) must agree with admitting the same prompts one at a time."""
    from repro.configs import get_reduced
    from repro.models import init_params
    from repro.serving import ServeConfig, ServingEngine

    cfg = get_reduced("llama3-8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    sc = ServeConfig(batch_slots=4, max_len=32, eos_token=1)
    rng = np.random.default_rng(1)
    # Ragged prompt lengths exercise the right-padded prefill.
    prompts = [list(rng.integers(2, cfg.vocab_size, size=n)) for n in (4, 9, 6)]

    batched = ServingEngine(cfg, params, sc)
    slots_b = batched.add_requests(prompts)
    assert slots_b == [0, 1, 2]

    seq = ServingEngine(cfg, params, sc)
    slots_s = [seq.add_request(p) for p in prompts]
    assert slots_s == [0, 1, 2]

    assert [o[:] for o in batched.outputs] == [o[:] for o in seq.outputs]
    for _ in range(4):
        batched.step()
        seq.step()
    assert [o[:] for o in batched.outputs] == [o[:] for o in seq.outputs]
    # One more prompt than free slots: the overflow request waits.
    slots = batched.add_requests([prompts[0], prompts[1]])
    assert slots[0] == 3 and slots[1] is None


def test_tap_game_episode_completes_with_search():
    env = make_tap_game(grid_size=5, num_colors=3, goal_count=6, step_budget=16)
    from repro.core import play_episode

    cfg = make_config(
        "wu_uct", num_simulations=32, wave_size=8, max_depth=8,
        max_sim_steps=10, max_width=5, gamma=1.0,
    )
    ret, moves, done = play_episode(env, cfg, jax.random.PRNGKey(3), max_moves=16)
    assert done and ret > 0.5  # goal completed within budget
