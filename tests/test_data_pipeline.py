"""Data pipeline extras: prefetcher overlap + MoE expert-padding safety."""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.training.data import Prefetcher, SyntheticStream


def test_prefetcher_yields_in_order_and_overlaps():
    stream = SyntheticStream(50, batch_size=4, seq_len=8, seed=1)
    pf = Prefetcher(stream, start_step=3, depth=2)
    try:
        steps = []
        for _ in range(4):
            step, batch = next(pf)
            steps.append(step)
            assert batch["tokens"].shape == (4, 8)
        assert steps == [3, 4, 5, 6]
        # Determinism: same addressing as direct batch_at.
        np.testing.assert_array_equal(
            np.asarray(batch["tokens"]), stream.batch_at(6)["tokens"]
        )
    finally:
        pf.close()


def test_moe_expert_padding_never_routes_to_dead_experts():
    """qwen2-moe pads 60→64 experts for EP; the 4 dead experts must receive
    zero tokens and zero gradient signal."""
    import dataclasses

    from repro.configs import get_reduced
    from repro.models import init_params
    from repro.models.layers import moe_block

    cfg = dataclasses.replace(
        get_reduced("qwen2-moe-a2.7b"),
        num_experts=8,
        num_experts_real=6,     # 2 padded (dead) experts
        num_experts_per_tok=2,
        capacity_factor=4.0,
    )
    params = init_params(cfg, jax.random.PRNGKey(0))
    bp = jax.tree.map(lambda x: x[0], params["blocks"])["moe"]
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)

    def loss(moe_params):
        out, aux = moe_block(moe_params, cfg, x)
        return jnp.sum(out**2) + aux

    g = jax.grad(loss)(bp)
    # Dead experts (indices >= 6) get exactly zero gradient.
    for name in ("w_gate", "w_up", "w_down"):
        dead = np.asarray(g[name][6:])
        assert np.all(dead == 0.0), name
        live = np.asarray(g[name][:6])
        assert np.any(live != 0.0), name
