"""Per-kernel validation: shape/dtype sweeps, assert_allclose vs ref.py.

All Pallas kernels run in interpret mode (CPU container; TPU is the target).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.decode_attention.ops import (
    decode_attention,
    paged_decode_attention,
    paged_tree_decode_attention,
    tree_decode_attention,
)
from repro.kernels.decode_attention.ref import (
    decode_attention_ref,
    paged_decode_attention_ref,
    paged_tree_decode_attention_ref,
    tree_decode_attention_ref,
)
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref_chunked, ssd_ref_sequential
from repro.kernels.tree_select.ops import tree_select
from repro.kernels.tree_select.ref import tree_select_ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-5, atol=2e-5
    )


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

FA_SHAPES = [
    # (b, sq, sk, hq, hkv, d, causal, bq, bk)
    (2, 128, 128, 4, 2, 64, True, 64, 64),
    (1, 256, 256, 8, 8, 32, True, 128, 64),
    (2, 64, 64, 4, 1, 128, False, 32, 32),
    (1, 512, 512, 2, 2, 64, True, 128, 256),
    (1, 128, 128, 6, 2, 64, True, 128, 128),   # single kv block
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", FA_SHAPES)
def test_flash_attention_matches_ref(shape, dtype):
    b, sq, sk, hq, hkv, d, causal, bq, bk = shape
    ks = jax.random.split(jax.random.PRNGKey(hash(shape) % 2**31), 3)
    q = jax.random.normal(ks[0], (b, sq, hq, d), dtype)
    k = jax.random.normal(ks[1], (b, sk, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, sk, hkv, d), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
    )


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------

DA_SHAPES = [
    # (b, s, hq, hkv, d, kv_len, bk)
    (2, 256, 8, 2, 64, 200, 64),
    (1, 512, 4, 4, 128, 512, 128),
    (3, 128, 16, 4, 32, 1, 64),
    (1, 1024, 8, 1, 64, 700, 256),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", DA_SHAPES)
def test_decode_attention_matches_ref(shape, dtype):
    b, s, hq, hkv, d, kv_len, bk = shape
    ks = jax.random.split(jax.random.PRNGKey(hash(shape) % 2**31), 3)
    q = jax.random.normal(ks[0], (b, hq, d), dtype)
    kc = jax.random.normal(ks[1], (b, s, hkv, d), dtype)
    vc = jax.random.normal(ks[2], (b, s, hkv, d), dtype)
    out = decode_attention(q, kc, vc, jnp.int32(kv_len), block_k=bk)
    ref = decode_attention_ref(q, kc, vc, kv_len)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_ragged_kv_len_matches_ref(dtype):
    """Per-batch [B] cache lengths (continuous batching / async slot caches)
    run through the same kernel as the scalar path."""
    b, s, hq, hkv, d, bk = 4, 256, 8, 2, 64, 64
    ks = jax.random.split(jax.random.PRNGKey(42), 3)
    q = jax.random.normal(ks[0], (b, hq, d), dtype)
    kc = jax.random.normal(ks[1], (b, s, hkv, d), dtype)
    vc = jax.random.normal(ks[2], (b, s, hkv, d), dtype)
    lens = jnp.asarray([1, 63, 200, 256], jnp.int32)
    out = decode_attention(q, kc, vc, lens, block_k=bk)
    ref = decode_attention_ref(q, kc, vc, lens)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
    )


# ---------------------------------------------------------------------------
# paged_decode_attention — page-table addressed pool blocks via SMEM
# scalar prefetch; parity with the dense kernel over gathered pages.
# ---------------------------------------------------------------------------

PDA_SHAPES = [
    # (b, hq, hkv, d, block_size, n_pages, num_blocks, lens)
    (4, 8, 2, 64, 16, 4, 32, (1, 17, 48, 64)),
    (2, 4, 4, 128, 32, 2, 8, (64, 33)),
    (3, 16, 4, 32, 8, 8, 64, (5, 40, 64)),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", PDA_SHAPES)
def test_paged_decode_attention_matches_ref(shape, dtype):
    b, hq, hkv, d, bs, npg, P, lens = shape
    ks = jax.random.split(jax.random.PRNGKey(hash(shape) % 2**31), 4)
    q = jax.random.normal(ks[0], (b, hq, d), dtype)
    pool_k = jax.random.normal(ks[1], (P, bs, hkv, d), dtype)
    pool_v = jax.random.normal(ks[2], (P, bs, hkv, d), dtype)
    # Random non-overlapping page assignment (the allocator's invariant).
    table = (
        jax.random.permutation(ks[3], P)[: b * npg]
        .reshape(b, npg).astype(jnp.int32)
    )
    kv_len = jnp.asarray(lens, jnp.int32)
    out = paged_decode_attention(q, pool_k, pool_v, table, kv_len)
    ref = paged_decode_attention_ref(q, pool_k, pool_v, table, kv_len)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
    )
    # ... and with the pages gathered dense, through the dense kernel.
    kd = pool_k[table].reshape(b, npg * bs, hkv, d)
    vd = pool_v[table].reshape(b, npg * bs, hkv, d)
    dense = decode_attention(q, kd, vd, kv_len, block_k=bs)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(dense, np.float32),
        **_tol(dtype),
    )


def test_paged_decode_attention_ignores_garbage_table_entries():
    """Table entries at page indices >= ceil(len/bs) are garbage by contract
    (sentinel or stale ids) — they must not leak into the output."""
    b, hq, hkv, d, bs, npg, P = 2, 4, 2, 32, 8, 4, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    q = jax.random.normal(ks[0], (b, hq, d), jnp.float32)
    pool_k = jax.random.normal(ks[1], (P, bs, hkv, d), jnp.float32)
    pool_v = jax.random.normal(ks[2], (P, bs, hkv, d), jnp.float32)
    kv_len = jnp.asarray([10, 3], jnp.int32)   # 2 pages / 1 page live
    table = jnp.asarray([[0, 1, 2, 3], [4, 5, 6, 7]], jnp.int32)
    out = paged_decode_attention(q, pool_k, pool_v, table, kv_len)
    # Sentinel P beyond the live prefix, stale ids pointing anywhere: same.
    garbled = jnp.asarray([[0, 1, P, P], [4, 9, 0, P]], jnp.int32)
    out_g = paged_decode_attention(q, pool_k, pool_v, garbled, kv_len)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(out_g), rtol=2e-5, atol=2e-5
    )


# ---------------------------------------------------------------------------
# tree_decode_attention — A speculative candidates share one prefix read;
# block-diagonal (identity) tree mask over the speculative tail.
# ---------------------------------------------------------------------------

TDA_SHAPES = [
    # (b, s, a, hq, hkv, d, kv_len, bk)
    (2, 256, 2, 8, 2, 64, 200, 64),
    (1, 512, 4, 4, 4, 128, 512, 128),
    (3, 128, 16, 16, 4, 32, 1, 64),
    (1, 256, 4, 8, 1, 64, 170, 256),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", TDA_SHAPES)
def test_tree_decode_attention_matches_ref(shape, dtype):
    b, s, a, hq, hkv, d, kv_len, bk = shape
    ks = jax.random.split(jax.random.PRNGKey(hash(shape) % 2**31), 5)
    q = jax.random.normal(ks[0], (b, a, hq, d), dtype)
    kc = jax.random.normal(ks[1], (b, s, hkv, d), dtype)
    vc = jax.random.normal(ks[2], (b, s, hkv, d), dtype)
    ksp = jax.random.normal(ks[3], (b, a, hkv, d), dtype)
    vsp = jax.random.normal(ks[4], (b, a, hkv, d), dtype)
    out = tree_decode_attention(q, kc, vc, ksp, vsp, jnp.int32(kv_len),
                                block_k=bk)
    ref = tree_decode_attention_ref(q, kc, vc, ksp, vsp, kv_len)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("a", [2, 4, 16])
def test_tree_decode_attention_ragged_kv_len_matches_ref(dtype, a):
    """Per-batch [B] prefix lengths — the async slot-cache shape."""
    b, s, hq, hkv, d, bk = 4, 256, 8, 2, 64, 64
    ks = jax.random.split(jax.random.PRNGKey(42 + a), 5)
    q = jax.random.normal(ks[0], (b, a, hq, d), dtype)
    kc = jax.random.normal(ks[1], (b, s, hkv, d), dtype)
    vc = jax.random.normal(ks[2], (b, s, hkv, d), dtype)
    ksp = jax.random.normal(ks[3], (b, a, hkv, d), dtype)
    vsp = jax.random.normal(ks[4], (b, a, hkv, d), dtype)
    lens = jnp.asarray([1, 63, 200, 256], jnp.int32)
    out = tree_decode_attention(q, kc, vc, ksp, vsp, lens, block_k=bk)
    ref = tree_decode_attention_ref(q, kc, vc, ksp, vsp, lens)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
    )


def test_tree_decode_attention_matches_per_candidate_decode():
    """Each candidate under the identity mask sees prefix + its OWN tail
    entry only — identical to running plain decode attention per candidate
    with that entry appended to the cache."""
    b, s, a, hq, hkv, d = 2, 128, 4, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    q = jax.random.normal(ks[0], (b, a, hq, d), jnp.float32)
    kc = jax.random.normal(ks[1], (b, s, hkv, d), jnp.float32)
    vc = jax.random.normal(ks[2], (b, s, hkv, d), jnp.float32)
    ksp = jax.random.normal(ks[3], (b, a, hkv, d), jnp.float32)
    vsp = jax.random.normal(ks[4], (b, a, hkv, d), jnp.float32)
    kv_len = jnp.asarray([100, 64], jnp.int32)
    out = tree_decode_attention(q, kc, vc, ksp, vsp, kv_len, block_k=64)
    for i in range(a):
        kci = kc.at[jnp.arange(b), kv_len].set(ksp[:, i])
        vci = vc.at[jnp.arange(b), kv_len].set(vsp[:, i])
        one = decode_attention(q[:, i], kci, vci, kv_len + 1, block_k=64)
        np.testing.assert_allclose(
            np.asarray(out[:, i]), np.asarray(one), rtol=2e-5, atol=2e-5,
            err_msg=f"candidate {i}",
        )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("a", [2, 4, 16])
def test_paged_tree_decode_attention_matches_ref(dtype, a):
    b, hq, hkv, d, bs, npg, P = 4, 8, 2, 64, 16, 4, 32
    ks = jax.random.split(jax.random.PRNGKey(7 * a), 6)
    q = jax.random.normal(ks[0], (b, a, hq, d), dtype)
    pool_k = jax.random.normal(ks[1], (P, bs, hkv, d), dtype)
    pool_v = jax.random.normal(ks[2], (P, bs, hkv, d), dtype)
    ksp = jax.random.normal(ks[3], (b, a, hkv, d), dtype)
    vsp = jax.random.normal(ks[4], (b, a, hkv, d), dtype)
    table = (
        jax.random.permutation(ks[5], P)[: b * npg]
        .reshape(b, npg).astype(jnp.int32)
    )
    kv_len = jnp.asarray([1, 17, 48, 64], jnp.int32)
    out = paged_tree_decode_attention(
        q, pool_k, pool_v, table, ksp, vsp, kv_len
    )
    ref = paged_tree_decode_attention_ref(
        q, pool_k, pool_v, table, ksp, vsp, kv_len
    )
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
    )
    # ... and against the dense tree kernel over gathered pages.
    kd = pool_k[table].reshape(b, npg * bs, hkv, d)
    vd = pool_v[table].reshape(b, npg * bs, hkv, d)
    dense = tree_decode_attention(q, kd, vd, ksp, vsp, kv_len, block_k=bs)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(dense, np.float32),
        **_tol(dtype),
    )


# ---------------------------------------------------------------------------
# ssd_scan — validated against BOTH the chunked jnp oracle and the O(S)
# sequential recurrence (ground truth).
# ---------------------------------------------------------------------------

SSD_SHAPES = [
    # (b, s, h, p, n, chunk)
    (2, 128, 4, 32, 16, 32),
    (1, 256, 2, 64, 32, 64),
    (1, 64, 8, 16, 64, 64),    # single chunk
    (2, 96, 3, 16, 8, 32),
]


@pytest.mark.parametrize("shape", SSD_SHAPES)
def test_ssd_scan_matches_refs(shape):
    b, s, h, p, n, chunk = shape
    ks = jax.random.split(jax.random.PRNGKey(hash(shape) % 2**31), 4)
    xdt = jax.random.normal(ks[0], (b, s, h, p), jnp.float32) * 0.3
    dA = -jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))   # negative
    Bm = jax.random.normal(ks[2], (b, s, n), jnp.float32) * 0.3
    Cm = jax.random.normal(ks[3], (b, s, n), jnp.float32) * 0.3
    out = ssd_scan(xdt, dA, Bm, Cm, chunk=chunk)
    ref_c = ssd_ref_chunked(xdt, dA, Bm, Cm, chunk=chunk)
    ref_s = ssd_ref_sequential(xdt, dA, Bm, Cm)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_c), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_s), rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    s_chunks=st.integers(min_value=1, max_value=4),
    h=st.integers(min_value=1, max_value=4),
)
def test_ssd_scan_property(seed, s_chunks, h):
    chunk, p, n, b = 32, 16, 8, 1
    s = chunk * s_chunks
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    xdt = jax.random.normal(ks[0], (b, s, h, p)) * 0.3
    dA = -jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    Bm = jax.random.normal(ks[2], (b, s, n)) * 0.3
    Cm = jax.random.normal(ks[3], (b, s, n)) * 0.3
    out = ssd_scan(xdt, dA, Bm, Cm, chunk=chunk)
    ref = ssd_ref_sequential(xdt, dA, Bm, Cm)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-4, atol=3e-4)


# ---------------------------------------------------------------------------
# tree_select
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    b_blocks=st.integers(min_value=1, max_value=3),
    a=st.sampled_from([4, 16, 20, 81]),
)
def test_tree_select_matches_ref(seed, b_blocks, a):
    block_b = 32
    b = block_b * b_blocks
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    n_c = jnp.floor(jax.random.uniform(ks[0], (b, a)) * 10)
    o_c = jnp.floor(jax.random.uniform(ks[1], (b, a)) * 3)
    v_c = jax.random.normal(ks[2], (b, a))
    n_p = jnp.sum(n_c, axis=1) + 1
    o_p = jnp.sum(o_c, axis=1)
    valid = jax.random.uniform(ks[3], (b, a)) < 0.7
    # ensure at least one valid per row
    valid = valid.at[:, 0].set(True)
    act, score = tree_select(n_c, o_c, v_c, n_p, o_p, valid, block_b=block_b)
    act_ref, score_ref = tree_select_ref(n_c, o_c, v_c, n_p, o_p, valid)
    # Scores must match; actions must achieve the same (possibly tied) score.
    np.testing.assert_allclose(
        np.asarray(score), np.asarray(score_ref), rtol=1e-5, atol=1e-5
    )
    taken = np.asarray(v_c)[np.arange(b), np.asarray(act)]
    taken_ref = np.asarray(v_c)[np.arange(b), np.asarray(act_ref)]
    assert (np.asarray(act) == np.asarray(act_ref)).mean() > 0.95 or np.allclose(
        taken, taken_ref
    )


def test_tree_select_consistent_with_policies():
    """The kernel must agree with repro.core.policies.child_scores."""
    from repro.core import init_tree
    from repro.core.policies import PolicyConfig, child_scores
    from repro.envs import make_bandit_tree

    env = make_bandit_tree(depth=3, num_actions=4)
    tree = init_tree(env.init(jax.random.PRNGKey(0)), 16, 4)
    tree = tree._replace(
        children=tree.children.at[0].set(jnp.array([1, 2, 3, -1])),
        parent=tree.parent.at[1:4].set(0),
        N=tree.N.at[0].set(9.0).at[1:4].set(jnp.array([4.0, 3.0, 2.0])),
        O=tree.O.at[0].set(2.0).at[1:4].set(jnp.array([1.0, 0.0, 1.0])),
        V=tree.V.at[1:4].set(jnp.array([0.5, 0.9, 0.2])),
    )
    scores = child_scores(tree, jnp.int32(0), PolicyConfig(kind="wu_uct"))

    kids = tree.children[0]
    safe = jnp.maximum(kids, 0)
    act, score = tree_select(
        tree.N[safe][None],
        tree.O[safe][None],
        tree.V[safe][None],
        tree.N[0][None],
        tree.O[0][None],
        (kids >= 0)[None],
        block_b=1,
    )
    assert int(act[0]) == int(jnp.argmax(scores))
    np.testing.assert_allclose(float(score[0]), float(jnp.max(scores)), rtol=1e-5)
