"""Property tests on eq. (4) — the paper's Sec. 3.1/Sec. 4 claims as math.

* Monotonicity: adding unobserved samples O to a child strictly decreases
  its score (in-flight work repels new workers — diversity);
* Vanishing penalty: the relative score penalty of O in-flight visits → 0 as
  N grows (exploitation of a known-best child is not blocked — the property
  virtual loss lacks);
* Parent-O effect: in-flight work through the parent raises ALL children's
  exploration terms equally (no bias).
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import init_tree
from repro.core.policies import PolicyConfig, child_scores
from repro.envs import make_bandit_tree


def _tree_with_root_children(n_children, n_vals, o_vals, v_vals, n_p, o_p):
    env = make_bandit_tree(depth=3, num_actions=n_children)
    tree = init_tree(env.init(jax.random.PRNGKey(0)), 32, n_children)
    kids = jnp.arange(1, n_children + 1, dtype=jnp.int32)
    tree = tree._replace(
        children=tree.children.at[0].set(kids),
        parent=tree.parent.at[1 : n_children + 1].set(0),
        N=tree.N.at[0].set(n_p).at[kids].set(jnp.asarray(n_vals, jnp.float32)),
        O=tree.O.at[0].set(o_p).at[kids].set(jnp.asarray(o_vals, jnp.float32)),
        V=tree.V.at[kids].set(jnp.asarray(v_vals, jnp.float32)),
        size=jnp.int32(n_children + 1),
    )
    return tree


CFG = PolicyConfig(kind="wu_uct", beta=1.0)


@settings(max_examples=30, deadline=None)
@given(
    n=st.floats(min_value=1, max_value=100),
    o=st.floats(min_value=1, max_value=16),
    v=st.floats(min_value=-1, max_value=1),
)
def test_adding_o_decreases_child_score(n, o, v):
    t0 = _tree_with_root_children(2, [n, n], [0.0, 0.0], [v, v], 2 * n, 0.0)
    t1 = _tree_with_root_children(2, [n, n], [o, 0.0], [v, v], 2 * n, o)
    s0 = np.asarray(child_scores(t0, jnp.int32(0), CFG))
    s1 = np.asarray(child_scores(t1, jnp.int32(0), CFG))
    assert s1[0] < s0[0]          # loaded child repels
    assert s1[1] >= s0[1] - 1e-6  # unloaded sibling does not lose


@settings(max_examples=20, deadline=None)
@given(o=st.floats(min_value=1, max_value=16))
def test_penalty_vanishes_with_n(o):
    """Sec. 4: 'this penalty vanishes when N_s becomes large'."""
    gaps = []
    for n in (4.0, 64.0, 4096.0):
        t_clean = _tree_with_root_children(2, [n, n], [0, 0], [1.0, 0.0],
                                           2 * n, 0.0)
        t_load = _tree_with_root_children(2, [n, n], [o, 0], [1.0, 0.0],
                                          2 * n, o)
        sc = np.asarray(child_scores(t_clean, jnp.int32(0), CFG))
        sl = np.asarray(child_scores(t_load, jnp.int32(0), CFG))
        gaps.append(sc[0] - sl[0])   # score drop caused by O on child 0
    assert gaps[0] > gaps[1] > gaps[2] >= 0
    assert gaps[2] < 0.05            # essentially gone at N=4096
    # With large N, the best child stays selected even while loaded —
    # the exploitation property virtual loss lacks.
    t_load = _tree_with_root_children(2, [4096, 4096], [o, 0], [1.0, 0.0],
                                      8192, o)
    s = np.asarray(child_scores(t_load, jnp.int32(0), CFG))
    assert s[0] > s[1]


@settings(max_examples=20, deadline=None)
@given(
    o_p=st.floats(min_value=1, max_value=32),
    n=st.floats(min_value=2, max_value=50),
)
def test_parent_o_raises_all_children_equally(o_p, n):
    t0 = _tree_with_root_children(3, [n] * 3, [0.0] * 3, [0.3, 0.2, 0.1],
                                  3 * n, 0.0)
    t1 = _tree_with_root_children(3, [n] * 3, [0.0] * 3, [0.3, 0.2, 0.1],
                                  3 * n, o_p)
    s0 = np.asarray(child_scores(t0, jnp.int32(0), CFG))
    s1 = np.asarray(child_scores(t1, jnp.int32(0), CFG))
    deltas = s1 - s0
    assert np.all(deltas > 0)                      # more exploration budget
    # uniform across children, up to f32 ulps (deltas can be ~1e-4 small)
    np.testing.assert_allclose(deltas, deltas[0], rtol=1e-3, atol=1e-6)


def test_treep_vc_reduces_to_uct_when_idle():
    """eq. (7) with zero in-flight queries == plain UCT."""
    t = _tree_with_root_children(3, [5, 3, 2], [0, 0, 0], [0.5, 0.1, 0.9],
                                 10, 0.0)
    s_vc = np.asarray(
        child_scores(t, jnp.int32(0), PolicyConfig(kind="treep_vc", r_vl=2.0,
                                                   n_vl=2.0))
    )
    s_uct = np.asarray(child_scores(t, jnp.int32(0), PolicyConfig(kind="uct")))
    np.testing.assert_allclose(s_vc, s_uct, rtol=1e-5)
