"""Async-slot engine (Algorithm 1 port): correctness + straggler overlap."""

import jax
import numpy as np

from repro.core import make_config
from repro.core.async_search import make_async_searcher
from repro.envs import make_bandit_tree, make_tap_game
from repro.envs.bandit_tree import solve_bandit_tree


def test_async_finds_optimum_and_counts_complete():
    env = make_bandit_tree(depth=4, num_actions=4, seed=0)
    _, opt_a, _ = solve_bandit_tree(4, 4, 0, gamma=1.0)
    cfg = make_config(
        "wu_uct", num_simulations=128, wave_size=16, max_depth=8,
        max_sim_steps=8, max_width=4, gamma=1.0,
    )
    search = make_async_searcher(env, cfg)
    state = env.init(jax.random.PRNGKey(0))
    hits, total_n = 0, []
    for t in range(4):
        res = search(state, jax.random.PRNGKey(t))
        hits += int(res.action) == opt_a
        total_n.append(float(np.asarray(res.root_n).sum()))
    assert hits >= 3
    # Every launched rollout completes.  A few early rollouts legitimately
    # simulate from the root itself (all children pending in the first fill),
    # so child visits sum to T minus at most ~2W root-sims.
    T, W = cfg.num_simulations, cfg.wave_size
    assert all(T - 2 * W <= n <= T for n in total_n), total_n


def test_async_overlaps_heterogeneous_rollouts():
    """Straggler mitigation: with 16 slots and rollouts of length ≤ 8, the
    master must finish 128 simulations in far fewer ticks than the serial
    128·len bound — and fewer than (waves × max_len) a barrier schedule
    would need if every wave waited for the longest rollout."""
    env = make_bandit_tree(depth=6, num_actions=3, seed=1)
    cfg = make_config(
        "wu_uct", num_simulations=128, wave_size=16, max_depth=8,
        max_sim_steps=8, max_width=3, gamma=1.0,
    )
    search = make_async_searcher(env, cfg)
    state = env.init(jax.random.PRNGKey(0))
    res = search(state, jax.random.PRNGKey(0))
    ticks = int(res.ticks)
    waves_barrier_bound = (128 // 16) * (cfg.max_sim_steps + 1)
    assert ticks < waves_barrier_bound, (ticks, waves_barrier_bound)
    # max_o is now an honest diagnostic: peak in-flight mass at the root
    # never exceeds the slot count.
    assert 0.0 < float(res.max_o) <= cfg.wave_size


def test_async_matches_wave_engine_quality():
    """Both engines implement the same WU statistics; on an easy problem
    with a known optimum their *trial-averaged* root visit-mass
    distributions must agree within an explicit tolerance.

    The old single-trial top-3-overlap assertion was seed-sensitive (one
    draw of two diffuse 25-action distributions).  This version averages a
    seeded trial battery on the 4-action bandit, where both engines
    concentrate: measured total-variation distance is ≤ 0.10 across seed
    bases (tolerance 0.25), and each engine puts ≥ 0.64 of its visit mass
    on the optimal action (threshold 0.4)."""
    from repro.core.wu_uct import make_searcher

    env = make_bandit_tree(depth=4, num_actions=4, seed=0)
    _, opt_a, _ = solve_bandit_tree(4, 4, 0, gamma=1.0)
    cfg = make_config(
        "wu_uct", num_simulations=128, wave_size=8, max_depth=8,
        max_sim_steps=8, max_width=4, gamma=1.0,
    )
    state = env.init(jax.random.PRNGKey(0))
    wave = make_searcher(env, cfg)
    asy = make_async_searcher(env, cfg)
    T, W = cfg.num_simulations, cfg.wave_size

    def mean_visit_dist(search):
        dists = []
        for s in range(100, 108):
            n = np.asarray(search(state, jax.random.PRNGKey(s)).root_n)
            assert T - 2 * W <= n.sum() <= T      # every rollout completes
            dists.append(n / n.sum())
        return np.mean(dists, axis=0)

    p_wave = mean_visit_dist(wave)
    p_async = mean_visit_dist(asy)
    tv = 0.5 * np.abs(p_wave - p_async).sum()
    assert tv < 0.25, (tv, p_wave, p_async)
    # Both engines identify the optimum and commit real mass to it.
    assert p_wave.argmax() == opt_a and p_async.argmax() == opt_a
    assert p_wave[opt_a] > 0.4 and p_async[opt_a] > 0.4
