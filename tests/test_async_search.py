"""Async-slot engine (Algorithm 1 port): correctness + straggler overlap."""

import jax
import numpy as np

from repro.core import make_config, make_async_searcher
from repro.envs import make_bandit_tree, make_tap_game
from repro.envs.bandit_tree import solve_bandit_tree


def test_async_finds_optimum_and_counts_complete():
    env = make_bandit_tree(depth=4, num_actions=4, seed=0)
    _, opt_a, _ = solve_bandit_tree(4, 4, 0, gamma=1.0)
    cfg = make_config(
        "wu_uct", num_simulations=128, wave_size=16, max_depth=8,
        max_sim_steps=8, max_width=4, gamma=1.0,
    )
    search = make_async_searcher(env, cfg)
    state = env.init(jax.random.PRNGKey(0))
    hits, total_n = 0, []
    for t in range(4):
        res = search(state, jax.random.PRNGKey(t))
        hits += int(res.action) == opt_a
        total_n.append(float(np.asarray(res.root_n).sum()))
    assert hits >= 3
    # Every launched rollout completes.  A few early rollouts legitimately
    # simulate from the root itself (all children pending in the first fill),
    # so child visits sum to T minus at most ~2W root-sims.
    T, W = cfg.num_simulations, cfg.wave_size
    assert all(T - 2 * W <= n <= T for n in total_n), total_n


def test_async_overlaps_heterogeneous_rollouts():
    """Straggler mitigation: with 16 slots and rollouts of length ≤ 8, the
    master must finish 128 simulations in far fewer ticks than the serial
    128·len bound — and fewer than (waves × max_len) a barrier schedule
    would need if every wave waited for the longest rollout."""
    env = make_bandit_tree(depth=6, num_actions=3, seed=1)
    cfg = make_config(
        "wu_uct", num_simulations=128, wave_size=16, max_depth=8,
        max_sim_steps=8, max_width=3, gamma=1.0,
    )
    search = make_async_searcher(env, cfg)
    state = env.init(jax.random.PRNGKey(0))
    res = search(state, jax.random.PRNGKey(0))
    ticks = int(res.ticks)
    waves_barrier_bound = (128 // 16) * (cfg.max_sim_steps + 1)
    assert ticks < waves_barrier_bound, (ticks, waves_barrier_bound)
    # max_o is now an honest diagnostic: peak in-flight mass at the root
    # never exceeds the slot count.
    assert 0.0 < float(res.max_o) <= cfg.wave_size


def test_async_matches_wave_engine_quality():
    """Both engines implement the same statistics; their root visit
    distributions must broadly agree on an easy problem."""
    from repro.core import make_searcher

    env = make_tap_game(grid_size=5, num_colors=3, goal_count=6, step_budget=14)
    cfg = make_config(
        "wu_uct", num_simulations=64, wave_size=8, max_depth=8,
        max_sim_steps=12, max_width=5, gamma=1.0,
    )
    state = env.init(jax.random.PRNGKey(0))
    wave = make_searcher(env, cfg)(state, jax.random.PRNGKey(1))
    asy = make_async_searcher(env, cfg)(state, jax.random.PRNGKey(1))
    n_w = np.asarray(wave.root_n)
    n_a = np.asarray(asy.root_n)
    # Top action sets overlap (not exact equality — schedules differ).
    top_w = set(np.argsort(n_w)[-3:])
    top_a = set(np.argsort(n_a)[-3:])
    assert len(top_w & top_a) >= 1
    T, W = cfg.num_simulations, cfg.wave_size
    assert T - 2 * W <= n_w.sum() <= T
    assert T - 2 * W <= n_a.sum() <= T
