"""Model-level kernel integration: attn_impl='pallas' ≈ 'xla' end to end.

The Pallas kernels (interpret mode on CPU) must be drop-in replacements for
the jnp paths at the full-model level — forward logits and decode steps
agree within f32 tolerance for every family that has a kernelized hot spot.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import decode_step, forward, init_cache, init_params, prefill


@pytest.mark.parametrize("arch", ["llama3-8b", "mamba2-2.7b", "zamba2-7b"])
def test_pallas_path_matches_xla_forward(arch):
    cfg_x = dataclasses.replace(get_reduced(arch), attn_chunk=32)
    cfg_p = dataclasses.replace(cfg_x, attn_impl="pallas")
    params = init_params(cfg_x, jax.random.PRNGKey(0))
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                     cfg_x.vocab_size)
    }
    lx, _ = jax.jit(lambda p, b: forward(p, cfg_x, b))(params, batch)
    lp, _ = jax.jit(lambda p, b: forward(p, cfg_p, b))(params, batch)
    np.testing.assert_allclose(
        np.asarray(lx, np.float32), np.asarray(lp, np.float32),
        rtol=5e-4, atol=5e-4,
    )


def test_pallas_decode_matches_xla():
    cfg_x = dataclasses.replace(get_reduced("llama3-8b"), attn_chunk=32)
    cfg_p = dataclasses.replace(cfg_x, attn_impl="pallas")
    params = init_params(cfg_x, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg_x.vocab_size)

    def run(cfg):
        cache = init_cache(cfg, 2, 32)
        lg, cache = prefill(params, cfg, {"tokens": toks[:, :8]}, cache)
        outs = [np.asarray(lg, np.float32)]
        for i in range(4):
            lg, cache = decode_step(params, cfg, toks[:, 8 + i - 1], cache)
            outs.append(np.asarray(lg, np.float32))
        return outs

    for a, b in zip(run(cfg_x), run(cfg_p)):
        np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-4)
