"""Unit tests for the sharding rules and the roofline HLO parser (no
compilation — pure spec/regex logic)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.distributed.sharding import (
    _fsdp_rule,
    abstract_mesh,
    batch_spec,
    param_partition_specs,
)
from repro.launch.dryrun import _shape_bytes, collective_bytes
from repro.launch.mesh import make_test_mesh
from repro.models import abstract_params


@pytest.fixture(scope="module")
def mesh():
    # AbstractMesh stand-in for spec logic (no devices needed); the compat
    # constructor papers over the pre-0.5 AbstractMesh signature.
    return abstract_mesh((16, 16), ("data", "model"))


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("strategy", ["tp", "fsdp"])
def test_every_param_gets_a_valid_spec(arch, strategy, mesh):
    cfg = get_config(arch)
    params = abstract_params(cfg)
    specs = param_partition_specs(cfg, params, mesh, strategy)
    flat_p = jax.tree.leaves(params)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    sizes = dict(mesh.shape)
    for leaf, spec in zip(flat_p, flat_s):
        assert isinstance(spec, P)
        assert len(spec) <= len(leaf.shape), (leaf.shape, spec)
        # Every sharded dim must divide evenly.
        for dim, entry in zip(leaf.shape, tuple(spec)):
            if entry is None:
                continue
            parts = 1
            for name in (entry if isinstance(entry, tuple) else (entry,)):
                parts *= sizes[name]
            assert dim % parts == 0, (arch, strategy, leaf.shape, spec)


def test_tp_rules_respect_head_divisibility(mesh):
    """phi3's 40 heads don't divide model=16 → attention replicates."""
    cfg = get_config("phi3-medium-14b")
    params = abstract_params(cfg)
    specs = param_partition_specs(cfg, params, mesh, "tp")
    attn_spec = specs["blocks"]["attn"]["wq"]
    assert all(e is None for e in tuple(attn_spec)), attn_spec
    # llama3's 32 q heads divide → sharded.
    cfg2 = get_config("llama3-8b")
    params2 = abstract_params(cfg2)
    specs2 = param_partition_specs(cfg2, params2, mesh, "tp")
    assert "model" in jax.tree_util.tree_leaves(
        [specs2["blocks"]["attn"]["wq"]],
        is_leaf=lambda x: isinstance(x, P),
    )[0]


def test_fsdp_rule_picks_largest_divisible_dim():
    mesh = abstract_mesh((16, 16), ("data", "model"))
    spec = _fsdp_rule((4096, 14336), mesh, ("data", "model"))
    assert spec == P(None, ("data", "model"))
    # 151936 doesn't divide 256 → falls to the 4096 dim.
    spec = _fsdp_rule((151936, 4096), mesh, ("data", "model"))
    assert spec == P(None, ("data", "model"))
    # nothing divisible → replicate
    spec = _fsdp_rule((7, 13), mesh, ("data", "model"))
    assert spec == P()


def test_batch_spec_fsdp_divisibility():
    mesh = abstract_mesh((16, 16), ("data", "model"))
    assert batch_spec(mesh, "fsdp", 256) == P(("data", "model"))
    # Single-axis specs: pre-0.5 PartitionSpec does not normalize a 1-tuple
    # entry to the bare name, so compare against the bare-name form the code
    # produces.
    assert batch_spec(mesh, "fsdp", 32) == P("data")   # fallback
    assert batch_spec(mesh, "tp", 256) == P("data")


# ---------------------------------------------------------------------------
# HLO collective parser
# ---------------------------------------------------------------------------


def test_shape_bytes():
    assert _shape_bytes("bf16[8,128]{1,0}") == 8 * 128 * 2
    assert _shape_bytes("f32[2,3,4]") == 24 * 4
    assert _shape_bytes("(f32[4], bf16[8])") == 16 + 16
    assert _shape_bytes("pred[]") == 1  # scalar


def test_collective_bytes_parsing():
    hlo = """
  %ag = bf16[1024,512]{1,0} all-gather(%x), replica_groups=[16,16]<=[256], dimensions={0}
  %ar.1 = f32[256]{0} all-reduce(%y), replica_groups=[1,256]<=[256], to_apply=%add
  %rs = bf16[64]{0} reduce-scatter(%z), replica_groups=[32,8]<=[256]
  %cp = f32[128]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %not_a_collective = f32[9] add(%a, %b)
"""
    out = collective_bytes(hlo)
    ag = 1024 * 512 * 2
    assert abs(out["all-gather"] - ag * 15 / 16) < 1
    assert abs(out["all-reduce"] - 2 * 256 * 4 * 255 / 256) < 1
    assert abs(out["reduce-scatter"] - 64 * 2 * 7) < 1
    assert out["collective-permute"] == 128 * 4
    assert out["counts"]["all-gather"] == 1
    assert out["counts"]["all-reduce"] == 1
