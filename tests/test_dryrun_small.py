"""Small-mesh dry-run validation (subprocess: needs 8 host devices).

Validates the sharding machinery end-to-end without the 512-device cost:
lower + compile one representative cell per architecture family on a
(2, 4) = 8-device mesh, plus a multi-pod (2, 2, 2) check and a sharded-MoE
numerical-equivalence test.  Run as a subprocess so the main pytest process
keeps its single-device view.
"""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.cells import build_cell
from repro.launch.mesh import make_test_mesh
from repro.distributed.sharding import use_mesh

results = {}

# --- compile representative cells on the small mesh --------------------
mesh = make_test_mesh()
cells = [
    ("llama3-8b", "train_4k"),
    ("qwen2-moe-a2.7b", "decode_32k"),
    ("mamba2-2.7b", "long_500k"),
    ("whisper-small", "prefill_32k"),
]
for arch, shape in cells:
    cell = build_cell(arch, shape, mesh)
    with use_mesh(mesh):
        compiled = jax.jit(
            cell.fn, in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings,
        ).lower(*cell.arg_specs).compile()
    results[f"{arch}/{shape}"] = "ok"

# --- multi-pod mesh ------------------------------------------------------
mesh3 = make_test_mesh(multi_pod=True)
cell = build_cell("llama3-8b", "train_4k", mesh3)
with use_mesh(mesh3):
    jax.jit(
        cell.fn, in_shardings=cell.in_shardings, out_shardings=cell.out_shardings
    ).lower(*cell.arg_specs).compile()
results["llama3-8b/train_4k/multi_pod"] = "ok"

# --- optimized strategies compile too -------------------------------------
cell = build_cell("llama3-8b", "train_4k", mesh, strategy="fsdp",
                  cfg_overrides={"loss_chunk": 512})
with use_mesh(mesh):
    jax.jit(cell.fn, in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings).lower(*cell.arg_specs).compile()
results["llama3-8b/train_4k/fsdp"] = "ok"
cell = build_cell("llama3-8b", "decode_32k", mesh, kv_mode="batch+seq_model")
with use_mesh(mesh):
    jax.jit(cell.fn, in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings).lower(*cell.arg_specs).compile()
results["llama3-8b/decode_32k/splitkv"] = "ok"

# --- the paper's technique: one WU-UCT wave step on the mesh --------------
from repro.launch.search_cell import build_search_cell

scell = build_search_cell(mesh, wave_size=8, num_simulations=32, d_mlp=256)
with use_mesh(mesh):
    jax.jit(
        scell.fn, in_shardings=scell.in_shardings,
        out_shardings=scell.out_shardings,
    ).lower(*scell.arg_specs).compile()
results["wu_uct_search_wave"] = "ok"

# --- sharded MoE == local MoE (numerics) --------------------------------
from repro.configs import get_reduced
from repro.models import init_params
from repro.models.layers import moe_block
import dataclasses

cfg = dataclasses.replace(
    get_reduced("qwen2-moe-a2.7b"), num_experts=8, capacity_factor=8.0
)
params = init_params(cfg, jax.random.PRNGKey(0))
bp = jax.tree.map(lambda x: x[0], params["blocks"])["moe"]
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model), jnp.float32)

out_local, aux_local = jax.jit(lambda p, x: moe_block(p, cfg, x))(bp, x)
mesh2 = make_test_mesh()  # data=2, model=4 : 8 experts -> 2 per shard
with use_mesh(mesh2):
    out_shard, aux_shard = jax.jit(lambda p, x: moe_block(p, cfg, x))(bp, x)
err = float(jnp.max(jnp.abs(out_local - out_shard)))
results["moe_sharded_vs_local_err"] = err
assert err < 2e-4, err

print("RESULTS:" + json.dumps(results))
"""


def test_small_mesh_dryrun_and_sharded_moe():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD],
        capture_output=True,
        text=True,
        env=env,
        timeout=1500,
        cwd=REPO,
    )
    assert proc.returncode == 0, proc.stdout[-3000:] + proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULTS:")][0]
    results = json.loads(line[len("RESULTS:"):])
    assert results["llama3-8b/train_4k"] == "ok"
    assert results["llama3-8b/train_4k/multi_pod"] == "ok"
    assert results["llama3-8b/train_4k/fsdp"] == "ok"
    assert results["llama3-8b/decode_32k/splitkv"] == "ok"
    assert results["wu_uct_search_wave"] == "ok"
    assert results["moe_sharded_vs_local_err"] < 2e-4
