"""Integration: WU-UCT searching over an LM token environment.

This is the paper's technique driving the framework's model stack: the
simulation step evaluates the policy LM (the role of the distilled PPO net
in App. D), and the search maximizes reward-model log-likelihood.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.core import make_config
from repro.core.wu_uct import make_searcher
from repro.envs.token_env import make_token_env
from repro.models import forward, init_params


def _tiny_lm(vocab=64):
    cfg = dataclasses.replace(
        get_reduced("llama3-8b"), vocab_size=vocab, num_layers=1,
        d_model=32, num_heads=2, num_kv_heads=1, head_dim=16, d_ff=64,
    )
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_token_env_contract():
    cfg, params = _tiny_lm()
    prompt = jnp.asarray([3, 5, 7], jnp.int32)
    env = make_token_env(cfg, params, prompt, max_len=12, top_k=4, eos_token=1)
    s = env.init(jax.random.PRNGKey(0))
    assert int(s.length) == 3
    step = jax.jit(env.step)
    s2, r, d = step(s, jnp.int32(0))
    assert int(s2.length) == 4
    assert np.isfinite(float(r)) and float(r) <= 0.0  # log-prob
    # Deterministic given state.
    s3, r3, _ = step(s, jnp.int32(0))
    assert float(r3) == float(r)
    np.testing.assert_array_equal(np.asarray(s2.tokens), np.asarray(s3.tokens))
    # Action 0 == greedy top-1 token of the policy.
    logits, _ = forward(params, cfg, {"tokens": s.tokens[None]})
    top1 = int(jnp.argmax(logits[0, int(s.length) - 1]))
    assert int(s2.tokens[3]) == top1


def test_wu_uct_token_search_beats_or_matches_greedy():
    # Reward model != policy model: greedy-under-policy is then suboptimal
    # for the reward, and the search (which optimizes reward) must win.
    cfg, params = _tiny_lm()
    reward_params = init_params(cfg, jax.random.PRNGKey(123))
    prompt = jnp.asarray([2, 9], jnp.int32)
    env = make_token_env(
        cfg, params, prompt, max_len=10, top_k=4, eos_token=1,
        reward_cfg=cfg, reward_params=reward_params,
    )
    scfg = make_config(
        "wu_uct", num_simulations=64, wave_size=8, max_depth=6,
        max_sim_steps=6, max_width=4, gamma=1.0,
    )
    search = make_searcher(env, scfg)
    step = jax.jit(env.step)

    def rollout(policy):
        s, total = env.init(jax.random.PRNGKey(0)), 0.0
        key = jax.random.PRNGKey(7)
        for i in range(4):
            key, k = jax.random.split(key)
            a = policy(s, k)
            s, r, d = step(s, a)
            total += float(r)
            if bool(d):
                break
        return total

    greedy = rollout(lambda s, k: jnp.int32(0))
    searched = rollout(lambda s, k: search(s, k).action)
    assert searched >= greedy - 1e-4, (searched, greedy)
