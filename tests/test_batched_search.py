"""Batched multi-root engine: vmap-of-single-tree oracle + kernel parity.

Two correctness pillars:

1. ``run_search_batched`` (selection fused through the Pallas ``tree_select``
   kernel, interpret mode on CPU) must agree *exactly* with ``jax.vmap`` of
   the single-tree wave engine per root — the batched tree layer carries
   per-tree RNG streams with the same split structure, so results are
   bit-compatible, not just statistically close.
2. The extended kernel must match :func:`repro.core.policies.child_scores`
   (the interpret-mode reference) for all four policy kinds.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    PolicyConfig,
    SearchConfig,
    init_tree,
    make_config,
)
from repro.core.batched_search import run_search_batched
from repro.core.wu_uct import run_search
from repro.core import tree as tree_lib
from repro.core import batched_tree as btree_lib
from repro.core.policies import child_scores
from repro.envs import make_bandit_tree
from repro.kernels.tree_select.ops import tree_select


def _roots_and_rngs(env, B, seed=0):
    roots = jax.vmap(env.init)(jax.random.split(jax.random.PRNGKey(seed), B))
    rngs = jax.random.split(jax.random.PRNGKey(seed + 1), B)
    return roots, rngs


def _assert_results_equal(single, batched):
    np.testing.assert_array_equal(
        np.asarray(single.root_n), np.asarray(batched.root_n)
    )
    np.testing.assert_allclose(
        np.asarray(single.root_v), np.asarray(batched.root_v), rtol=1e-6
    )
    np.testing.assert_array_equal(
        np.asarray(single.action), np.asarray(batched.action)
    )
    np.testing.assert_array_equal(
        np.asarray(single.tree_size), np.asarray(batched.tree_size)
    )


def test_batched_matches_vmapped_single_deterministic():
    """ISSUE acceptance: B-batched search with stat_mode='none', wave_size=1,
    deterministic_expansion=True equals jax.vmap of the single engine."""
    env = make_bandit_tree(depth=4, num_actions=3, seed=7)
    cfg = SearchConfig(
        num_simulations=16,
        wave_size=1,
        max_depth=5,
        max_sim_steps=5,
        max_width=3,
        gamma=0.9,
        policy=PolicyConfig(kind="uct"),
        stat_mode="none",
        expand_coin=1.0,
        deterministic_expansion=True,
    )
    roots, rngs = _roots_and_rngs(env, B=8)
    single = jax.jit(jax.vmap(lambda s, k: run_search(env, cfg, s, k)))(
        roots, rngs
    )
    batched = jax.jit(lambda s, k: run_search_batched(env, cfg, s, k))(
        roots, rngs
    )
    _assert_results_equal(single, batched)


@pytest.mark.parametrize(
    "kind,stat_mode",
    [("wu_uct", "wu"), ("treep", "vl"), ("treep_vc", "wu")],
)
def test_batched_matches_vmapped_single_parallel(kind, stat_mode):
    """Per-tree RNG streams mirror the single engine, so equality holds for
    every stat mode and policy — including stochastic rollouts and W>1."""
    env = make_bandit_tree(depth=4, num_actions=3, seed=3)
    cfg = SearchConfig(
        num_simulations=32,
        wave_size=4,
        max_depth=5,
        max_sim_steps=5,
        max_width=3,
        gamma=0.9,
        policy=PolicyConfig(kind=kind, r_vl=1.0),
        stat_mode=stat_mode,
    )
    roots, rngs = _roots_and_rngs(env, B=8, seed=11)
    single = jax.jit(jax.vmap(lambda s, k: run_search(env, cfg, s, k)))(
        roots, rngs
    )
    batched = jax.jit(lambda s, k: run_search_batched(env, cfg, s, k))(
        roots, rngs
    )
    _assert_results_equal(single, batched)


def test_kernel_path_matches_reference_path():
    """use_kernel=True (Pallas) and use_kernel=False (jnp oracle) agree."""
    env = make_bandit_tree(depth=4, num_actions=4, seed=5)
    cfg = make_config(
        "wu_uct", num_simulations=32, wave_size=4, max_depth=6,
        max_sim_steps=6, max_width=4, gamma=1.0,
    )
    roots, rngs = _roots_and_rngs(env, B=8, seed=2)
    with_kernel = jax.jit(
        lambda s, k: run_search_batched(env, cfg, s, k, use_kernel=True)
    )(roots, rngs)
    without = jax.jit(
        lambda s, k: run_search_batched(env, cfg, s, k, use_kernel=False)
    )(roots, rngs)
    _assert_results_equal(with_kernel, without)


@pytest.mark.parametrize("kind", ["uct", "wu_uct", "treep", "treep_vc"])
def test_kernel_matches_child_scores(kind):
    """The fused kernel must reproduce child_scores' argmax/max for every
    policy kind on a fabricated tree with nontrivial N/O/V/VL stats."""
    rng = np.random.default_rng(hash(kind) % 2**31)
    B, A = 16, 5
    cfg = PolicyConfig(kind=kind, beta=1.3, r_vl=0.7, n_vl=1.5)
    env = make_bandit_tree(depth=3, num_actions=A, seed=1)
    root_state = env.init(jax.random.PRNGKey(0))

    acts_ref, scores_ref = [], []
    tables = {k: [] for k in ("n_c", "o_c", "v_c", "vl_c", "n_p", "o_p", "valid")}
    for i in range(B):
        tree = init_tree(root_state, capacity=A + 1, num_actions=A)
        kids = np.where(rng.random(A) < 0.75, np.arange(1, A + 1), -1)
        kids[rng.integers(A)] = rng.integers(1, A + 1)  # ≥1 valid child
        n = np.floor(rng.random(A + 1) * 9)
        o = np.floor(rng.random(A + 1) * 3)
        v = rng.normal(size=A + 1)
        vl = rng.random(A + 1)
        tree = tree._replace(
            children=tree.children.at[0].set(jnp.asarray(kids, jnp.int32)),
            parent=tree.parent.at[1:].set(0),
            N=jnp.asarray(n, jnp.float32),
            O=jnp.asarray(o, jnp.float32),
            V=jnp.asarray(v, jnp.float32),
            VL=jnp.asarray(vl, jnp.float32),
        )
        scores = child_scores(tree, jnp.int32(0), cfg)
        acts_ref.append(int(jnp.argmax(scores)))
        scores_ref.append(float(jnp.max(scores)))

        safe = np.maximum(kids, 0)
        tables["n_c"].append(n[safe])
        tables["o_c"].append(o[safe])
        tables["v_c"].append(v[safe])
        tables["vl_c"].append(vl[safe])
        tables["n_p"].append(n[0])
        tables["o_p"].append(o[0])
        tables["valid"].append(kids >= 0)

    act, score = tree_select(
        jnp.asarray(np.stack(tables["n_c"]), jnp.float32),
        jnp.asarray(np.stack(tables["o_c"]), jnp.float32),
        jnp.asarray(np.stack(tables["v_c"]), jnp.float32),
        jnp.asarray(np.stack(tables["n_p"]), jnp.float32),
        jnp.asarray(np.stack(tables["o_p"]), jnp.float32),
        jnp.asarray(np.stack(tables["valid"])),
        jnp.asarray(np.stack(tables["vl_c"]), jnp.float32),
        kind=kind, beta=cfg.beta, r_vl=cfg.r_vl, n_vl=cfg.n_vl,
    )
    np.testing.assert_array_equal(np.asarray(act), np.asarray(acts_ref))
    np.testing.assert_allclose(
        np.asarray(score), np.asarray(scores_ref), rtol=1e-5, atol=1e-6
    )


# ---------------------------------------------------------------------------
# Capacity guard (satellite): reserve at capacity must not corrupt node 0.
# ---------------------------------------------------------------------------


def test_reserve_child_overflow_is_refused():
    env = make_bandit_tree(depth=3, num_actions=4, seed=0)
    tree = init_tree(env.init(jax.random.PRNGKey(0)), capacity=2, num_actions=4)

    tree, c1, ok1 = tree_lib.reserve_child(tree, jnp.int32(0), jnp.int32(0))
    assert bool(ok1) and int(c1) == 1 and int(tree.size) == 2
    root_children_before = np.asarray(tree.children[0]).copy()
    parent_before = np.asarray(tree.parent).copy()

    tree, c2, ok2 = tree_lib.reserve_child(tree, jnp.int32(0), jnp.int32(1))
    assert not bool(ok2)
    assert int(c2) == 0                       # degraded to the parent node
    assert int(tree.size) == 2                # no phantom allocation
    assert bool(tree.overflowed)
    np.testing.assert_array_equal(np.asarray(tree.parent), parent_before)
    np.testing.assert_array_equal(
        np.asarray(tree.children[0]), root_children_before
    )


def test_batched_reserve_overflow_is_refused_per_tree():
    env = make_bandit_tree(depth=3, num_actions=4, seed=0)
    roots = jax.vmap(env.init)(jax.random.split(jax.random.PRNGKey(0), 2))
    bt = btree_lib.init_batched_tree(roots, capacity=2, num_actions=4)

    parents = jnp.zeros((2,), jnp.int32)
    acts = jnp.array([0, 1], jnp.int32)
    # Tree 0 reserves (fills to capacity); tree 1 masked out.
    bt, _, ok = btree_lib.reserve_children(
        bt, parents, acts, mask=jnp.array([True, False])
    )
    np.testing.assert_array_equal(np.asarray(ok), [True, False])
    # Second round: tree 0 overflows, tree 1 succeeds.
    bt, child, ok = btree_lib.reserve_children(
        bt, parents, acts, mask=jnp.array([True, True])
    )
    np.testing.assert_array_equal(np.asarray(ok), [False, True])
    np.testing.assert_array_equal(np.asarray(bt.overflowed), [True, False])
    np.testing.assert_array_equal(np.asarray(bt.size), [2, 2])
    assert int(child[0]) == 0                 # degraded to the parent


def test_search_result_reports_no_overflow_and_ticks():
    env = make_bandit_tree(depth=4, num_actions=3, seed=1)
    cfg = make_config(
        "wu_uct", num_simulations=32, wave_size=4, max_depth=6,
        max_sim_steps=6, max_width=3, gamma=1.0,
    )
    state = env.init(jax.random.PRNGKey(0))
    res = jax.jit(lambda s, k: run_search(env, cfg, s, k))(
        state, jax.random.PRNGKey(1)
    )
    assert not bool(res.overflowed)
    assert int(res.ticks) == cfg.num_simulations // cfg.wave_size

    roots, rngs = _roots_and_rngs(env, B=4)
    bres = jax.jit(lambda s, k: run_search_batched(env, cfg, s, k))(roots, rngs)
    assert not np.asarray(bres.overflowed).any()
    np.testing.assert_array_equal(
        np.asarray(bres.ticks), [cfg.num_simulations // cfg.wave_size] * 4
    )


def test_rootp_ensemble_merges_committee_stats():
    from repro.core.baselines import run_rootp

    env = make_bandit_tree(depth=4, num_actions=4, seed=0)
    cfg = make_config(
        "rootp", num_simulations=64, wave_size=8, max_depth=8,
        max_sim_steps=8, max_width=4, gamma=1.0,
    )
    state = env.init(jax.random.PRNGKey(0))
    res = jax.jit(lambda s, k: run_rootp(env, cfg, s, k))(
        state, jax.random.PRNGKey(1)
    )
    n = np.asarray(res.root_n)
    assert n.shape == (4,)
    # K committees of T/K sims each; a few early sims may start at the root.
    assert cfg.num_simulations - 2 * cfg.wave_size <= n.sum() <= cfg.num_simulations
    assert 0 <= int(res.action) < 4
