"""Frontier-speculative expansion: tree-batched decode scores every child.

Claim families (ISSUE 7):

* **search parity** — a search driven by the frontier evaluators (dense and
  paged, single and batched engines) makes the SAME discrete decisions as
  the plain cached evaluators: scoring all A candidates per tick and the
  chosen one's commit are bit-equivalent to the one-token decode step;
* **cache hits** — after an EXPAND tick snapshots the frontier, refilling
  the slot back onto the snapshot parent (parent hit) or onto any of its A
  candidate children (child hit) dispatches ZERO model forwards: logits are
  restored from aux and the child's K/V row commits from the snapshot;
* **rollback invalidation** — a refill onto a path that diverges from the
  snapshot parent invalidates the frontier entry; later would-be hits miss;
* **engine accounting** — the async engines thread the hit mask out as a
  cumulative ``frontier_hits`` trace column, monotone and > 0 on searches
  that revisit expanded frontiers;
* **last_logits** — every model evaluator surfaces the most recent
  per-slot logits via ``aux_last_logits`` (satellite).
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import (
    CachedModelEvaluator,
    FrontierModelEvaluator,
    ModelEvaluator,
    PagedCachedModelEvaluator,
    PagedFrontierModelEvaluator,
    SearchSpec,
    build_searcher,
)
from repro.core.evaluators import EXPAND, FREE, SIM
from repro.envs.token_env import TokenEnvState, make_token_env
from repro.models import decode_chunk, init_params

TOL = dict(rtol=2e-4, atol=2e-4)


@pytest.fixture(scope="module")
def lm():
    cfg = dataclasses.replace(
        get_reduced("llama3-8b"), vocab_size=64, num_layers=2,
        d_model=32, num_heads=2, num_kv_heads=1, head_dim=16, d_ff=64,
    )
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


def _ragged_states(max_len=16, lengths=(3, 5, 9), seed=7) -> TokenEnvState:
    n = len(lengths)
    toks = jax.random.randint(
        jax.random.PRNGKey(seed), (n, max_len), 2, 60, jnp.int32
    )
    pos = jnp.arange(max_len)
    lengths = jnp.asarray(lengths, jnp.int32)
    return TokenEnvState(
        tokens=jnp.where(pos[None, :] < lengths[:, None], toks, 0),
        length=lengths,
        done=jnp.zeros((n,), jnp.bool_),
    )


def _scfg():
    return SearchSpec(gamma=1.0, max_sim_steps=8).config


def _spec(batch=0):
    return SearchSpec(
        algo="wu_uct", engine="async", batch=batch, num_simulations=12,
        wave_size=4, max_depth=5, max_sim_steps=5, max_width=4, gamma=1.0,
    )


def _env(lm, max_len=14, top_k=4):
    cfg, params = lm
    return make_token_env(
        cfg, params, jnp.asarray([3, 5, 7], jnp.int32), max_len=max_len,
        top_k=top_k, eos_token=1,
    )


def _expand_tick(ev, scfg, state, aux, acts, seed=0):
    """Drive one EXPAND tick on every row (the frontier snapshot moment)."""
    n = state.length.shape[0]
    kind = jnp.full((n,), EXPAND, jnp.int32)
    keys = jax.random.split(jax.random.PRNGKey(seed), n)
    zeros_b = jnp.zeros((n,), jnp.bool_)
    zeros_f = jnp.zeros((n,), jnp.float32)
    (new_state, *_), aux = ev.tick(
        scfg, kind, jnp.asarray(acts, jnp.int32), state, zeros_b, zeros_f,
        jnp.ones((n,), jnp.float32), jnp.zeros((n,), jnp.int32), keys, aux,
    )
    return new_state, aux


def _child_state(parent: TokenEnvState, child_tok) -> TokenEnvState:
    n = parent.length.shape[0]
    idx = jnp.arange(n)
    s_max = parent.tokens.shape[-1]
    safe = jnp.minimum(parent.length, s_max - 1)
    return TokenEnvState(
        tokens=parent.tokens.at[idx, safe].set(
            jnp.asarray(child_tok, jnp.int32)
        ),
        length=parent.length + 1,
        done=parent.done,
    )


# ---------------------------------------------------------------------------
# Search parity: frontier evaluators reproduce the cached searches.
# ---------------------------------------------------------------------------


def test_frontier_search_matches_cached(lm):
    cfg, params = lm
    env = _env(lm)
    spec = _spec()
    key = jax.random.PRNGKey(2)
    root = env.init(key)
    ev_c = CachedModelEvaluator(cfg, params, top_k=4, eos_token=1)
    ev_f = FrontierModelEvaluator(cfg, params, top_k=4, eos_token=1)
    res_c = build_searcher(env, spec, evaluator=ev_c)(root, key)
    res_f = build_searcher(env, spec, evaluator=ev_f)(root, key)
    for f in ("action", "root_n", "tree_size", "ticks", "overflowed"):
        np.testing.assert_array_equal(
            np.asarray(getattr(res_c, f)), np.asarray(getattr(res_f, f)),
            err_msg=f"field {f}",
        )
    np.testing.assert_allclose(
        np.asarray(res_c.root_v), np.asarray(res_f.root_v), **TOL
    )


def test_paged_frontier_search_matches_paged_cached(lm):
    cfg, params = lm
    env = _env(lm)
    spec = _spec()
    key = jax.random.PRNGKey(2)
    root = env.init(key)
    kw = dict(top_k=4, eos_token=1, block_size=4, num_blocks=96)
    ev_c = PagedCachedModelEvaluator(cfg, params, **kw)
    ev_f = PagedFrontierModelEvaluator(cfg, params, **kw)
    res_c = build_searcher(env, spec, evaluator=ev_c)(root, key)
    res_f = build_searcher(env, spec, evaluator=ev_f)(root, key)
    for f in ("action", "root_n", "tree_size", "ticks", "overflowed"):
        np.testing.assert_array_equal(
            np.asarray(getattr(res_c, f)), np.asarray(getattr(res_f, f)),
            err_msg=f"field {f}",
        )
    np.testing.assert_allclose(
        np.asarray(res_c.root_v), np.asarray(res_f.root_v), **TOL
    )


def test_batched_frontier_search_matches_batched_cached(lm):
    cfg, params = lm
    env = _env(lm)
    B = 3
    spec = _spec(batch=B)
    key = jax.random.PRNGKey(2)
    roots = jax.vmap(env.init)(jax.random.split(key, B))
    rngs = jax.random.split(jax.random.PRNGKey(1), B)
    ev_c = CachedModelEvaluator(cfg, params, top_k=4, eos_token=1)
    ev_f = FrontierModelEvaluator(cfg, params, top_k=4, eos_token=1)
    res_c = build_searcher(env, spec, evaluator=ev_c)(roots, rngs)
    res_f = build_searcher(env, spec, evaluator=ev_f)(roots, rngs)
    for f in ("action", "root_n", "tree_size", "ticks", "overflowed"):
        np.testing.assert_array_equal(
            np.asarray(getattr(res_c, f)), np.asarray(getattr(res_f, f)),
            err_msg=f"field {f}",
        )


# ---------------------------------------------------------------------------
# Frontier cache hits: settle -> sibling refills dispatch zero forwards.
# ---------------------------------------------------------------------------


def _counting_frontier_ev(lm, calls, paged=False):
    cfg, params = lm
    if paged:
        return PagedFrontierModelEvaluator(
            cfg, params, top_k=4, eos_token=1, block_size=4, num_blocks=64,
        )

    def counting_chunk(p, c, t, target, cache):
        jax.debug.callback(lambda: calls.append(1))
        return decode_chunk(p, c, t, target, cache)

    return FrontierModelEvaluator(
        cfg, params, top_k=4, eos_token=1, chunk_fn=counting_chunk,
    )


def test_parent_and_sibling_refills_hit_frontier_cache(lm):
    """After one EXPAND tick, refilling back onto the parent and onto every
    one of the A candidate children answers from the frontier snapshot:
    zero ``decode_chunk`` dispatches, and the restored logits + committed
    K/V row equal a fresh prefill of that path."""
    cfg, params = lm
    calls = []
    ev = _counting_frontier_ev(lm, calls)
    scfg = _scfg()
    parent = _ragged_states(lengths=(5, 7))
    n = 2
    aux0 = ev.init_aux(parent, (n, 1))
    _, aux = _expand_tick(ev, scfg, parent, aux0, acts=[0, 1])
    cand = np.asarray(aux["fr"]["cand"])          # [n, A]
    assert np.asarray(aux["fr"]["valid"]).all()

    # Parent hit: straight back to the snapshot parent, zero forwards.
    calls.clear()
    aux_p, hit = ev.refill_aux(
        scfg, aux, jnp.arange(n), parent, jnp.ones((n,), jnp.bool_)
    )
    jax.effects_barrier()
    assert len(calls) == 0, f"parent hit dispatched {len(calls)} chunks"
    assert np.asarray(hit).all()
    np.testing.assert_array_equal(
        np.asarray(aux_p["len"]), np.asarray(parent.length)
    )
    fresh_p = ev.init_aux(parent, (n, 1))
    np.testing.assert_allclose(
        np.asarray(aux_p["pol"]["logits"], np.float32),
        np.asarray(fresh_p["pol"]["logits"], np.float32), **TOL,
    )

    # Child hits: every candidate rank, zero forwards, correct cache.
    for j in range(ev.top_k):
        child = _child_state(parent, cand[:, j])
        calls.clear()
        aux_c, hit = ev.refill_aux(
            scfg, aux, jnp.arange(n), child, jnp.ones((n,), jnp.bool_)
        )
        jax.effects_barrier()
        assert len(calls) == 0, f"child {j} dispatched {len(calls)} chunks"
        assert np.asarray(hit).all(), f"child {j} missed"
        np.testing.assert_array_equal(
            np.asarray(aux_c["len"]), np.asarray(child.length)
        )
        fresh = ev.init_aux(child, (n, 1))
        np.testing.assert_allclose(
            np.asarray(aux_c["pol"]["logits"], np.float32),
            np.asarray(fresh["pol"]["logits"], np.float32), **TOL,
            err_msg=f"child {j} logits",
        )
        # The committed K/V row is real: decoding one more token from the
        # hit cache equals decoding from the fresh prefill.
        nxt = jnp.asarray([21, 23], jnp.int32)
        l1, _ = ev.decode_fn(
            params, cfg, nxt, dict(aux_c["pol"]["cache"], len=aux_c["len"])
        )
        l2, _ = ev.decode_fn(
            params, cfg, nxt, dict(fresh["pol"]["cache"], len=fresh["len"])
        )
        np.testing.assert_allclose(
            np.asarray(l1, np.float32), np.asarray(l2, np.float32), **TOL,
            err_msg=f"child {j} committed KV row",
        )


def test_paged_sibling_refills_hit_frontier_cache(lm):
    """Paged twin: child hits commit through page bookkeeping (COW/alloc)
    with refcount conservation intact and no catch-up forwards."""
    cfg, params = lm
    ev = _counting_frontier_ev(lm, [], paged=True)
    scfg = _scfg()
    parent = _ragged_states(lengths=(5, 7))
    n = 2
    aux0 = ev.init_aux(parent, (n, 1))
    _, aux = _expand_tick(ev, scfg, parent, aux0, acts=[0, 1])
    cand = np.asarray(aux["fr"]["cand"])

    for j in range(ev.top_k):
        child = _child_state(parent, cand[:, j])
        aux_c, hit = ev.refill_aux(
            scfg, aux, jnp.arange(n), child, jnp.ones((n,), jnp.bool_)
        )
        assert np.asarray(hit).all(), f"child {j} missed"
        np.testing.assert_array_equal(
            np.asarray(aux_c["len"]), np.asarray(child.length)
        )
        fresh = ev.init_aux(child, (n, 1))
        np.testing.assert_allclose(
            np.asarray(aux_c["pol"]["logits"], np.float32),
            np.asarray(fresh["pol"]["logits"], np.float32), **TOL,
            err_msg=f"child {j} logits",
        )


def test_divergent_refill_invalidates_frontier(lm):
    """A refill whose path diverges from the snapshot parent is a miss, and
    it INVALIDATES the entry: going back to the parent afterwards no longer
    hits (the cache was rewritten under the slot)."""
    cfg, params = lm
    calls = []
    ev = _counting_frontier_ev(lm, calls)
    scfg = _scfg()
    parent = _ragged_states(lengths=(6, 6))
    n = 2
    aux0 = ev.init_aux(parent, (n, 1))
    _, aux = _expand_tick(ev, scfg, parent, aux0, acts=[0, 0])

    divergent = np.asarray(parent.tokens).copy()
    divergent[:, 2] = 61                     # diverge inside the prefix
    div_state = TokenEnvState(
        tokens=jnp.asarray(divergent, jnp.int32),
        length=parent.length,
        done=jnp.zeros((n,), jnp.bool_),
    )
    calls.clear()
    aux2, hit = ev.refill_aux(
        scfg, aux, jnp.arange(n), div_state, jnp.ones((n,), jnp.bool_)
    )
    jax.effects_barrier()
    assert not np.asarray(hit).any()
    assert len(calls) > 0, "divergent refill must catch up via forwards"
    assert not np.asarray(aux2["fr"]["valid"]).any(), "entry must invalidate"

    # Back to the original parent: the snapshot is gone, so this is a plain
    # rollback (forwards dispatched), not a stale hit.
    calls.clear()
    aux3, hit = ev.refill_aux(
        scfg, aux2, jnp.arange(n), parent, jnp.ones((n,), jnp.bool_)
    )
    jax.effects_barrier()
    assert not np.asarray(hit).any()
    assert len(calls) > 0
    fresh = ev.init_aux(parent, (n, 1))
    np.testing.assert_allclose(
        np.asarray(aux3["pol"]["logits"], np.float32),
        np.asarray(fresh["pol"]["logits"], np.float32), **TOL,
    )


def test_masked_rows_never_hit(lm):
    cfg, params = lm
    ev = FrontierModelEvaluator(cfg, params, top_k=4, eos_token=1)
    scfg = _scfg()
    parent = _ragged_states(lengths=(5, 7))
    n = 2
    aux0 = ev.init_aux(parent, (n, 1))
    _, aux = _expand_tick(ev, scfg, parent, aux0, acts=[0, 1])
    mask = jnp.asarray([True, False])
    _, hit = ev.refill_aux(scfg, aux, jnp.arange(n), parent, mask)
    np.testing.assert_array_equal(np.asarray(hit), [True, False])


# ---------------------------------------------------------------------------
# Engine accounting: frontier_hits trace column.
# ---------------------------------------------------------------------------


def test_engine_traces_frontier_hits(lm):
    from repro.core.async_search import run_async_search

    cfg, params = lm
    env = _env(lm)
    spec = _spec()
    key = jax.random.PRNGKey(2)
    root = env.init(key)
    ev = FrontierModelEvaluator(cfg, params, top_k=4, eos_token=1)
    fn = jax.jit(functools.partial(
        run_async_search, env, spec.config, trace_ticks=48, evaluator=ev,
    ))
    _, trace = fn(root, key)
    hits = np.asarray(trace.frontier_hits)
    assert hits[-1] > 0, "search never hit the frontier cache"
    assert (np.diff(hits) >= 0).all(), "cumulative counter must be monotone"


def test_batched_engine_traces_frontier_hits(lm):
    from repro.core.batched_async_search import run_async_search_batched

    cfg, params = lm
    env = _env(lm)
    B = 3
    spec = _spec(batch=B)
    key = jax.random.PRNGKey(2)
    roots = jax.vmap(env.init)(jax.random.split(key, B))
    rngs = jax.random.split(jax.random.PRNGKey(1), B)
    ev = FrontierModelEvaluator(cfg, params, top_k=4, eos_token=1)
    fn = jax.jit(functools.partial(
        run_async_search_batched, env, spec.config, trace_ticks=48,
        evaluator=ev,
    ))
    _, trace = fn(roots, rngs)
    hits = np.asarray(trace.frontier_hits)      # [K, B]
    assert hits.shape[-1] == B
    assert hits[-1].sum() > 0
    assert (np.diff(hits, axis=0) >= 0).all()


# ---------------------------------------------------------------------------
# last_logits satellite: every model evaluator surfaces its slot logits.
# ---------------------------------------------------------------------------


def test_uncached_evaluator_surfaces_last_logits(lm):
    cfg, params = lm
    ev = ModelEvaluator(cfg, params, top_k=4, eos_token=1)
    scfg = _scfg()
    state = _ragged_states()
    n = 3
    aux = ev.init_aux(state, (n, 1))
    assert ev.aux_last_logits(aux) is not None
    kind = jnp.full((n,), SIM, jnp.int32)
    keys = jax.random.split(jax.random.PRNGKey(3), n)
    zb, zf = jnp.zeros((n,), jnp.bool_), jnp.zeros((n,), jnp.float32)
    (new_state, *_), aux = ev.tick(
        scfg, kind, jnp.zeros((n,), jnp.int32), state, zb, zf,
        jnp.ones((n,), jnp.float32), jnp.zeros((n,), jnp.int32), keys, aux,
    )
    got = ev.aux_last_logits(aux)
    want = ev._position_logits(params, cfg, state.tokens, state.length)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **TOL
    )


@pytest.mark.parametrize("frontier", [False, True])
def test_cached_evaluators_surface_last_logits(lm, frontier):
    cfg, params = lm
    cls = FrontierModelEvaluator if frontier else CachedModelEvaluator
    ev = cls(cfg, params, top_k=4, eos_token=1)
    state = _ragged_states()
    aux = ev.init_aux(state, (3, 1))
    got = ev.aux_last_logits(aux)
    assert got is not None
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(aux["pol"]["logits"], np.float32), **TOL,
    )
