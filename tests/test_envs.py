"""Environment invariants (tap game mechanics + MDP contract)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.envs import make_bandit_tree, make_random_mdp, make_tap_game
from repro.envs.tap_game import EMPTY, _flood_fill, _gravity


# ---------------------------------------------------------------------------
# Flood fill / gravity unit properties
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    g=st.integers(min_value=3, max_value=7),
    colors=st.integers(min_value=2, max_value=5),
)
def test_flood_fill_is_connected_same_color(seed, g, colors):
    key = jax.random.PRNGKey(seed)
    # key is a parent only: every consumer gets its own fold_in-derived key
    # (consuming key directly AND folding from it correlates the streams).
    grid = jax.random.randint(jax.random.fold_in(key, 0), (g, g), 0, colors,
                              jnp.int8)
    r, c = int(jax.random.randint(jax.random.fold_in(key, 1), (), 0, g)), int(
        jax.random.randint(jax.random.fold_in(key, 2), (), 0, g)
    )
    mask = np.asarray(_flood_fill(grid, jnp.int32(r), jnp.int32(c)))
    grid = np.asarray(grid)
    color = grid[r, c]
    assert mask[r, c]
    # Same color everywhere in the mask.
    assert (grid[mask] == color).all()
    # Connectivity: BFS from (r, c) over same-color cells == mask.
    seen = np.zeros_like(mask)
    stack = [(r, c)]
    seen[r, c] = True
    while stack:
        i, j = stack.pop()
        for di, dj in ((1, 0), (-1, 0), (0, 1), (0, -1)):
            ni, nj = i + di, j + dj
            if 0 <= ni < g and 0 <= nj < g and not seen[ni, nj] and grid[ni, nj] == color:
                seen[ni, nj] = True
                stack.append((ni, nj))
    np.testing.assert_array_equal(mask, seen)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_gravity_no_floating_cells_and_conserves(seed):
    key = jax.random.PRNGKey(seed)
    g = 6
    # key is a parent only — both consumers use fold_in-derived keys.
    grid = jax.random.randint(jax.random.fold_in(key, 0), (g, g), 0, 4,
                              jnp.int8)
    holes = jax.random.uniform(jax.random.fold_in(key, 1), (g, g)) < 0.4
    grid = jnp.where(holes, EMPTY, grid)
    out = np.asarray(_gravity(grid))
    grid = np.asarray(grid)
    # Multiset of colors conserved per column.
    for c in range(g):
        np.testing.assert_array_equal(
            np.sort(out[:, c]), np.sort(grid[:, c])
        )
    # No empty below a non-empty cell (row 0 = top).
    for c in range(g):
        col = out[:, c]
        nonempty_started = False
        for r in range(g):
            if col[r] != EMPTY:
                nonempty_started = True
            else:
                assert not nonempty_started, f"floating cell in column {c}: {col}"


# ---------------------------------------------------------------------------
# MDP contract: deterministic-given-state, done absorbing
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    env_kind=st.sampled_from(["tap", "mdp", "bandit"]),
)
def test_step_deterministic_given_state(seed, env_kind):
    env = {
        "tap": lambda: make_tap_game(grid_size=5, num_colors=3),
        "mdp": lambda: make_random_mdp(num_states=8, num_actions=3, horizon=5),
        "bandit": lambda: make_bandit_tree(depth=3, num_actions=3),
    }[env_kind]()
    key = jax.random.PRNGKey(seed)
    state = env.init(key)
    a = jax.random.randint(jax.random.fold_in(key, 1), (), 0, env.num_actions)
    step = jax.jit(env.step)
    s1, r1, d1 = step(state, a)
    s2, r2, d2 = step(state, a)
    for x, y in zip(jax.tree.leaves(s1), jax.tree.leaves(s2)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert float(r1) == float(r2) and bool(d1) == bool(d2)


def test_done_is_absorbing():
    env = make_bandit_tree(depth=2, num_actions=2)
    s = env.init(jax.random.PRNGKey(0))
    step = jax.jit(env.step)
    for _ in range(5):
        s, r, d = step(s, jnp.int32(0))
    assert bool(d)
    s2, r2, d2 = step(s, jnp.int32(1))
    assert float(r2) == 0.0 and bool(d2)


def test_tap_game_goal_completion_terminates():
    env = make_tap_game(grid_size=5, num_colors=2, goal_count=2, step_budget=30)
    key = jax.random.PRNGKey(1)
    s = env.init(key)
    step = jax.jit(env.step)
    pol = jax.jit(env.policy)
    done = False
    for i in range(30):
        a = pol(jax.random.fold_in(key, i), s)
        s, r, d = step(s, a)
        if bool(d):
            done = True
            break
    assert done  # 2 colors / goal 2: trivially completable within budget
