"""PagedCachedModelEvaluator: shared-pool paged KV vs the dense contract.

Claim families (ISSUE 6):

* **kernel parity** — ``paged_decode_attention`` (page-table addressed pool
  blocks) equals the jnp oracle and the dense kernel over gathered pages
  (the hypothesis-gated sweeps live in ``tests/test_kernels.py``; this file
  keeps one always-collected case);
* **logits parity** — the paged evaluator's init / tick / refill logits
  equal :class:`~repro.core.evaluators.CachedModelEvaluator`'s dense ones,
  so every discrete search decision matches end-to-end through both async
  engines;
* **refcount conservation** — ``refcount[p]`` == live page-table entries
  pointing at ``p`` (page index < ceil(len/bs)) after init, ticks, COW and
  rollback; rollback releases suffix pages back to the pool (no leaks);
* **copy-on-write isolation** — sibling slots share prefix pages from one
  root prefill and split on first divergent write without corrupting each
  other;
* **exhaustion** — an undersized pool raises :class:`PagePoolExhaustedError`
  at the eager boundary instead of corrupting caches;
* **serving** — the paged :class:`~repro.serving.engine.ServingEngine`
  emits token-identical streams to the dense one, returns every page on
  EOS, and admits fewer prompts (not fails) when the pool is tight.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import (
    CachedModelEvaluator,
    PagedCachedModelEvaluator,
    SearchSpec,
    build_searcher,
)
from repro.core.evaluators import SIM
from repro.envs.token_env import TokenEnvState, make_token_env
from repro.models import PagePoolExhaustedError, init_params

TOL = dict(rtol=2e-4, atol=2e-4)


@pytest.fixture(scope="module")
def lm():
    cfg = dataclasses.replace(
        get_reduced("llama3-8b"), vocab_size=64, num_layers=2,
        d_model=32, num_heads=2, num_kv_heads=1, head_dim=16, d_ff=64,
    )
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


def _ragged_states(max_len=16, lengths=(3, 5, 9), seed=7) -> TokenEnvState:
    n = len(lengths)
    toks = jax.random.randint(
        jax.random.PRNGKey(seed), (n, max_len), 2, 60, jnp.int32
    )
    pos = jnp.arange(max_len)
    lengths = jnp.asarray(lengths, jnp.int32)
    return TokenEnvState(
        tokens=jnp.where(pos[None, :] < lengths[:, None], toks, 0),
        length=lengths,
        done=jnp.zeros((n,), jnp.bool_),
    )


def _scfg():
    return SearchSpec(gamma=1.0, max_sim_steps=8).config


def _pair(lm, block_size=4, num_blocks=64):
    cfg, params = lm
    dense = CachedModelEvaluator(cfg, params, top_k=4, eos_token=1)
    paged = PagedCachedModelEvaluator(
        cfg, params, top_k=4, eos_token=1,
        block_size=block_size, num_blocks=num_blocks,
    )
    return dense, paged


def _assert_conservation(ev, aux):
    """refcount[p] == live table entries pointing at p, with multiplicity."""
    rc = np.asarray(aux["refcount"])
    tab = np.asarray(aux["table"])
    lens = np.asarray(aux["len"])
    bs, P = ev.block_size, ev.num_blocks
    live = np.zeros(P, np.int64)
    for i in range(tab.shape[0]):
        for pi in range(-(-int(lens[i]) // bs)):
            assert tab[i, pi] < P, (
                f"slot {i} page {pi}: live entry is sentinel/garbage"
            )
            live[tab[i, pi]] += 1
    np.testing.assert_array_equal(rc, live)


# ---------------------------------------------------------------------------
# Kernel parity (always-collected single case).
# ---------------------------------------------------------------------------


def test_paged_kernel_matches_dense_gather():
    from repro.kernels.decode_attention.ops import (
        decode_attention,
        paged_decode_attention,
    )
    from repro.kernels.decode_attention.ref import paged_decode_attention_ref

    b, hq, hkv, d, bs, npg, P = 4, 4, 2, 16, 8, 4, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (b, hq, d), jnp.float32)
    pool_k = jax.random.normal(ks[1], (P, bs, hkv, d), jnp.float32)
    pool_v = jax.random.normal(ks[2], (P, bs, hkv, d), jnp.float32)
    table = (
        jax.random.permutation(ks[3], P)[: b * npg]
        .reshape(b, npg).astype(jnp.int32)
    )
    kv_len = jnp.asarray([3, 8, 17, 32], jnp.int32)
    out = paged_decode_attention(q, pool_k, pool_v, table, kv_len)
    ref = paged_decode_attention_ref(q, pool_k, pool_v, table, kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)
    kd = pool_k[table].reshape(b, npg * bs, hkv, d)
    vd = pool_v[table].reshape(b, npg * bs, hkv, d)
    dense = decode_attention(q, kd, vd, kv_len, block_k=bs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense), **TOL)
    # Garbage table entries beyond ceil(len/bs) never leak into the output.
    garbled = table.at[0, 1:].set(P)   # row 0: len 3 -> 1 live page
    out_g = paged_decode_attention(q, pool_k, pool_v, garbled, kv_len)
    np.testing.assert_allclose(np.asarray(out_g[0]), np.asarray(out[0]), **TOL)


# ---------------------------------------------------------------------------
# Logits parity with the dense cached evaluator.
# ---------------------------------------------------------------------------


def test_init_aux_matches_dense(lm):
    dense, paged = _pair(lm)
    state = _ragged_states()
    aux_d = dense.init_aux(state, (3, 1))
    aux_p = paged.init_aux(state, (3, 1))
    np.testing.assert_array_equal(
        np.asarray(aux_p["len"]), np.asarray(aux_d["len"])
    )
    np.testing.assert_allclose(
        np.asarray(aux_p["pol"]["logits"], np.float32),
        np.asarray(aux_d["pol"]["logits"], np.float32), **TOL,
    )
    _assert_conservation(paged, aux_p)


def test_tick_chain_matches_dense(lm):
    """Chained SIM ticks: identical sampled tokens and logits, refcount
    conservation after every tick."""
    dense, paged = _pair(lm)
    scfg = _scfg()
    st_d = st_p = _ragged_states()
    n = 3
    aux_d = dense.init_aux(st_d, (n, 1))
    aux_p = paged.init_aux(st_p, (n, 1))
    kind = jnp.full((n,), SIM, jnp.int32)
    cd = cp = dict(
        rollout_done=jnp.zeros((n,), jnp.bool_),
        acc=jnp.zeros((n,), jnp.float32),
        disc=jnp.ones((n,), jnp.float32),
        steps=jnp.zeros((n,), jnp.int32),
    )
    for step in range(5):
        keys = jax.random.split(jax.random.PRNGKey(step), n)
        (st_d, r_d, _, acc, disc, stp, rdone), aux_d = dense.tick(
            scfg, kind, jnp.zeros((n,), jnp.int32), st_d, cd["rollout_done"],
            cd["acc"], cd["disc"], cd["steps"], keys, aux_d,
        )
        cd = dict(rollout_done=rdone, acc=acc, disc=disc, steps=stp)
        (st_p, r_p, _, acc, disc, stp, rdone), aux_p = paged.tick(
            scfg, kind, jnp.zeros((n,), jnp.int32), st_p, cp["rollout_done"],
            cp["acc"], cp["disc"], cp["steps"], keys, aux_p,
        )
        cp = dict(rollout_done=rdone, acc=acc, disc=disc, steps=stp)
        np.testing.assert_array_equal(
            np.asarray(st_p.tokens), np.asarray(st_d.tokens),
            err_msg=f"step {step}: paged/dense sampled different tokens",
        )
        np.testing.assert_allclose(
            np.asarray(r_p, np.float32), np.asarray(r_d, np.float32), **TOL
        )
        np.testing.assert_array_equal(
            np.asarray(aux_p["len"]), np.asarray(aux_d["len"])
        )
        np.testing.assert_allclose(
            np.asarray(aux_p["pol"]["logits"], np.float32),
            np.asarray(aux_d["pol"]["logits"], np.float32), **TOL,
        )
        _assert_conservation(paged, aux_p)


def test_refill_rollback_matches_fresh_prefill_and_releases_pages(lm):
    """Rollback is a page-table edit: logits equal a fresh init_aux at the
    new path, conservation holds, and the released suffix pages rejoin the
    pool (strictly fewer blocks in use than before the rollback)."""
    _, paged = _pair(lm)
    scfg = _scfg()
    start = _ragged_states(lengths=(4, 4, 4))
    n = 3
    aux = paged.init_aux(start, (n, 1))
    kind = jnp.full((n,), SIM, jnp.int32)
    rdone = jnp.zeros((n,), jnp.bool_)
    acc = jnp.zeros((n,), jnp.float32)
    disc = jnp.ones((n,), jnp.float32)
    stp = jnp.zeros((n,), jnp.int32)
    state = start
    for s in range(5):
        keys = jax.random.split(jax.random.PRNGKey(11 + s), n)
        (state, _, _, acc, disc, stp, rdone), aux = paged.tick(
            scfg, kind, jnp.zeros((n,), jnp.int32), state, rdone, acc, disc,
            stp, keys, aux,
        )
    used_before = int(np.asarray(paged.aux_blocks(aux)))

    new_tokens = np.asarray(state.tokens).copy()
    new_len = np.asarray([6, 4, 5])
    new_tokens[0, 6:] = 0
    new_tokens[1, 4:] = 0
    new_tokens[2] = 0
    new_tokens[2, :5] = [7, 11, 13, 17, 19]
    new_state = TokenEnvState(
        tokens=jnp.asarray(new_tokens, jnp.int32),
        length=jnp.asarray(new_len, jnp.int32),
        done=jnp.zeros((n,), jnp.bool_),
    )
    aux2, _ = paged.refill_aux(
        scfg, aux, jnp.arange(n), new_state, jnp.ones((n,), jnp.bool_)
    )
    fresh = paged.init_aux(new_state, (n, 1))
    np.testing.assert_array_equal(np.asarray(aux2["len"]), new_len)
    np.testing.assert_allclose(
        np.asarray(aux2["pol"]["logits"], np.float32),
        np.asarray(fresh["pol"]["logits"], np.float32), **TOL,
    )
    _assert_conservation(paged, aux2)
    used_after = int(np.asarray(paged.aux_blocks(aux2)))
    assert used_after < used_before, (used_before, used_after)


def test_refill_skips_masked_rows(lm):
    """mask=False rows keep their cache untouched — and their pages."""
    _, paged = _pair(lm)
    scfg = _scfg()
    state = _ragged_states()
    aux = paged.init_aux(state, (3, 1))
    shallow = TokenEnvState(
        tokens=state.tokens,
        length=jnp.asarray([1, 1, 1], jnp.int32),
        done=jnp.zeros((3,), jnp.bool_),
    )
    mask = jnp.asarray([False, True, False])
    aux2, _ = paged.refill_aux(scfg, aux, jnp.arange(3), shallow, mask)
    np.testing.assert_array_equal(
        np.asarray(aux2["len"]), [3, 1, 9]
    )
    _assert_conservation(paged, aux2)


# ---------------------------------------------------------------------------
# Copy-on-write prefix sharing.
# ---------------------------------------------------------------------------


def test_siblings_share_prefix_pages(lm):
    """W sibling slots of one root prefill once and point at the SAME
    prefix blocks (refcount == W), so pool use is O(roots), not O(slots)."""
    _, paged = _pair(lm)
    root = _ragged_states(lengths=(8,), seed=3)
    aux = paged.init_aux(root, (1, 4))   # 1 root x 4 siblings
    tab = np.asarray(aux["table"])
    rc = np.asarray(aux["refcount"])
    assert tab.shape[0] == 4
    np.testing.assert_array_equal(tab[0, :2], tab[1, :2])
    np.testing.assert_array_equal(tab[0, :2], tab[3, :2])
    assert (rc[rc > 0] == 4).all()
    assert (rc > 0).sum() == 2           # len 8 / block 4 — shared, once
    _assert_conservation(paged, aux)


def test_cow_isolates_diverging_siblings(lm):
    """Two siblings writing different tokens into a shared page each get a
    private copy; logits match the dense evaluator run with separate
    caches, and conservation holds through the split."""
    cfg, params = lm
    dense, paged = _pair(lm)
    root = _ragged_states(lengths=(8,), seed=3)
    aux_p = paged.init_aux(root, (1, 2))
    dup = TokenEnvState(
        tokens=jnp.repeat(root.tokens, 2, axis=0),
        length=jnp.repeat(root.length, 2, axis=0),
        done=jnp.zeros((2,), jnp.bool_),
    )
    aux_d = dense.init_aux(dup, (2, 1))
    toks = jnp.asarray([5, 9], jnp.int32)
    fed = jnp.asarray([True, True])
    aux_p2 = paged._advance(aux_p, toks, fed)
    aux_d2 = dense._advance(aux_d, toks, fed)
    # len 8, block 4: the write lands at position 8 — page 2, shared before
    # the write (refcount 2 on pages 0-1 only; page 2 is fresh for both).
    tab = np.asarray(aux_p2["table"])
    assert tab[0, 2] != tab[1, 2], "diverging siblings must not share page 2"
    np.testing.assert_allclose(
        np.asarray(aux_p2["pol"]["logits"], np.float32),
        np.asarray(aux_d2["pol"]["logits"], np.float32), **TOL,
    )
    _assert_conservation(paged, aux_p2)
    # Second write: position 9, offset 1 into the now-private page — the
    # COW case proper (write into a shared partial page never happens here
    # because page 2 was allocated privately; force it by re-sharing).
    aux_p3 = paged._advance(aux_p2, jnp.asarray([7, 7], jnp.int32), fed)
    aux_d3 = dense._advance(aux_d2, jnp.asarray([7, 7], jnp.int32), fed)
    np.testing.assert_allclose(
        np.asarray(aux_p3["pol"]["logits"], np.float32),
        np.asarray(aux_d3["pol"]["logits"], np.float32), **TOL,
    )
    _assert_conservation(paged, aux_p3)


def test_cow_on_shared_partial_page(lm):
    """A slot writing into a partial page it SHARES (refcount > 1) copies
    the block first: the sibling's view of the old block is untouched."""
    _, paged = _pair(lm)
    root = _ragged_states(lengths=(6,), seed=5)   # 6 = 1.5 pages of 4
    aux = paged.init_aux(root, (1, 2))
    rc0 = np.asarray(aux["refcount"])
    assert (rc0[rc0 > 0] == 2).all()              # pages 0,1 both shared
    # Advance ONLY slot 0: it writes position 6 = offset 2 of shared page 1
    # -> COW. Slot 1's table must keep the original block.
    tab0 = np.asarray(aux["table"]).copy()
    aux2 = paged._advance(
        aux, jnp.asarray([5, 0], jnp.int32), jnp.asarray([True, False])
    )
    tab2 = np.asarray(aux2["table"])
    assert tab2[0, 1] != tab0[0, 1], "writer should have COW'd page 1"
    assert tab2[1, 1] == tab0[1, 1], "non-writer must keep the shared block"
    np.testing.assert_array_equal(np.asarray(aux2["len"]), [7, 6])
    _assert_conservation(paged, aux2)


# ---------------------------------------------------------------------------
# Pool exhaustion.
# ---------------------------------------------------------------------------


def test_pool_exhaustion_raises(lm):
    cfg, params = lm
    tiny = PagedCachedModelEvaluator(
        cfg, params, top_k=4, eos_token=1, block_size=4, num_blocks=2,
    )
    with pytest.raises(PagePoolExhaustedError, match="num_blocks=2"):
        tiny.init_aux(_ragged_states(), (3, 1))


def test_advance_exhaustion_latches_and_raises(lm):
    cfg, params = lm
    tiny = PagedCachedModelEvaluator(
        cfg, params, top_k=4, eos_token=1, block_size=4, num_blocks=2,
    )
    aux = tiny.init_aux(_ragged_states(lengths=(8,), seed=3), (1, 1))
    aux2 = tiny._advance(aux, jnp.asarray([5], jnp.int32), jnp.asarray([True]))
    with pytest.raises(PagePoolExhaustedError):
        tiny.check_exhausted(aux2)


# ---------------------------------------------------------------------------
# End-to-end: both async engines, bit-identical search decisions.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("batch", [0, 2])
def test_paged_search_matches_dense_end_to_end(lm, batch):
    cfg, params = lm
    env = make_token_env(
        cfg, params, jnp.asarray([3, 5, 7], jnp.int32), max_len=14,
        top_k=4, eos_token=1,
    )
    dense, paged = _pair(lm, num_blocks=96)
    spec = SearchSpec(
        algo="wu_uct", engine="async", batch=batch, num_simulations=10,
        wave_size=3, max_depth=5, max_sim_steps=5, max_width=4, gamma=1.0,
    )
    key = jax.random.PRNGKey(2)
    if batch:
        roots = jax.vmap(env.init)(jax.random.split(key, batch))
        keys = jax.random.split(jax.random.PRNGKey(1), batch)
    else:
        roots, keys = env.init(key), key
    res_d = build_searcher(env, spec, evaluator=dense)(roots, keys)
    res_p = build_searcher(env, spec, evaluator=paged)(roots, keys)
    for f in ("action", "root_n", "tree_size", "ticks", "overflowed"):
        np.testing.assert_array_equal(
            np.asarray(getattr(res_d, f)), np.asarray(getattr(res_p, f)),
            err_msg=f"field {f}",
        )
    np.testing.assert_allclose(
        np.asarray(res_d.root_v), np.asarray(res_p.root_v), **TOL
    )


def test_trace_mode_reports_blocks_in_use(lm):
    """Trace snapshots carry the pool working set — the number the
    batch-ceiling benchmark rows are derived from."""
    from repro.core.async_search import run_async_search

    cfg, params = lm
    env = make_token_env(
        cfg, params, jnp.asarray([3, 5, 7], jnp.int32), max_len=14,
        top_k=4, eos_token=1,
    )
    _, paged = _pair(lm, num_blocks=96)
    spec = SearchSpec(
        algo="wu_uct", engine="async", num_simulations=10, wave_size=3,
        max_depth=5, max_sim_steps=5, max_width=4, gamma=1.0,
    )
    fn = jax.jit(functools.partial(
        run_async_search, env, spec.config, trace_ticks=40, evaluator=paged,
    ))
    _, trace = fn(env.init(jax.random.PRNGKey(2)), jax.random.PRNGKey(2))
    blocks = np.asarray(trace.blocks_in_use)
    alive = np.asarray(trace.alive)
    assert blocks.shape[0] == alive.shape[0]
    assert blocks[alive].max() > 0
    assert blocks[alive].max() <= paged.num_blocks


# ---------------------------------------------------------------------------
# Serving engine.
# ---------------------------------------------------------------------------


def test_serving_paged_matches_dense(lm):
    from repro.serving.engine import ServeConfig, ServingEngine

    cfg, params = lm
    prompts = [[3, 5, 7], [11, 13], [2, 9, 4, 6, 8], [17, 19, 23, 29]]
    dense = ServingEngine(
        cfg, params, ServeConfig(batch_slots=3, max_len=24, eos_token=1)
    )
    paged = ServingEngine(
        cfg, params,
        ServeConfig(batch_slots=3, max_len=24, eos_token=1,
                    paged=True, block_size=4),
    )
    out_d = dense.run(prompts, max_ticks=64)
    out_p = paged.run(prompts, max_ticks=64)
    assert out_d == out_p
    assert paged.blocks_in_use() == 0, "pages leaked after all slots freed"


def test_serving_tight_pool_admits_fewer(lm):
    from repro.serving.engine import ServeConfig, ServingEngine

    cfg, params = lm
    eng = ServingEngine(
        cfg, params,
        ServeConfig(batch_slots=3, max_len=24, eos_token=1,
                    paged=True, block_size=4, num_blocks=3),
    )
    # 1 + 1 + 2 pages wanted, 3 in the pool: the third prompt must wait.
    slots = eng.add_requests([[3, 5, 7], [11, 13], [2, 9, 4, 6, 8]])
    assert slots[0] is not None
    assert slots.count(None) >= 1, "tight pool must defer, not crash"
