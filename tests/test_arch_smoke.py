"""Per-architecture smoke tests (assignment deliverable f).

Every assigned arch instantiates a REDUCED same-family config and runs one
forward + one train step on CPU, asserting output shapes and no NaNs.  The
FULL configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation) — see tests/test_dryrun_small.py and launch/dryrun.py.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_reduced, list_archs
from repro.models import (
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
    prefill,
)
from repro.training import AdamWConfig, TrainConfig, adamw_init, make_train_step

ARCHS = list_archs()


def _batch(cfg, key, b=2, s=24):
    # One fold_in-derived key per consumer: reusing `key` across randint and
    # normal correlates the token and embedding streams (JX003).
    batch = {"tokens": jax.random.randint(
        jax.random.fold_in(key, 0), (b, s), 0, cfg.vocab_size
    )}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 1), (b, cfg.num_patches, cfg.d_model),
            jnp.float32,
        )
    if cfg.family == "encdec":
        batch["frame_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 1), (b, cfg.encoder_seq, cfg.d_model),
            jnp.float32,
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assignment dimensions."""
    cfg = get_config(arch)
    expected = {
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 151936),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 151936),
        "llama3-8b": (32, 4096, 32, 8, 128256),
        "phi3-medium-14b": (40, 5120, 40, 10, 100352),
        "deepseek-67b": (95, 8192, 64, 8, 102400),
        "qwen2.5-32b": (64, 5120, 40, 8, 152064),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 32000),
        "zamba2-7b": (81, 3584, 32, 32, 32000),
        "mamba2-2.7b": (64, 2560, 0, 0, 50280),
        "whisper-small": (12, 768, 12, 12, 51865),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.vocab_size)
    assert got == expected, (arch, got, expected)
    # family-specific extras
    if arch == "qwen2-moe-a2.7b":
        assert (cfg.num_experts, cfg.num_experts_per_tok) == (60, 4)
    if arch == "qwen3-moe-235b-a22b":
        assert (cfg.num_experts, cfg.num_experts_per_tok) == (128, 8)
    if arch in ("mamba2-2.7b",):
        assert cfg.ssm_state == 128
    if arch == "zamba2-7b":
        assert cfg.ssm_state == 64 and cfg.attn_every > 0
    if arch == "whisper-small":
        assert cfg.num_encoder_layers == 12


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = _batch(cfg, key)

    logits, aux = jax.jit(lambda p, b: forward(p, cfg, b))(params, batch)
    b, s = batch["tokens"].shape
    extra = cfg.num_patches if cfg.family == "vlm" else 0
    assert logits.shape == (b, s + extra, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    tc = TrainConfig(optimizer=AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10))
    step = jax.jit(make_train_step(cfg, tc))
    opt = adamw_init(params)
    params2, _, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    diffs = [
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2))
    ]
    assert max(diffs) > 0


@pytest.mark.parametrize(
    "arch",
    ["llama3-8b", "qwen2-moe-a2.7b", "mamba2-2.7b", "zamba2-7b",
     "whisper-small", "llava-next-mistral-7b"],
)
def test_prefill_decode_matches_forward(arch):
    """Serving path (prefill + N decode steps) == full forward, per family."""
    cfg = get_reduced(arch)
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # no token drops
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    b, s, extra_steps, max_len = 2, 16, 3, 32
    toks = jax.random.randint(key, (b, s + extra_steps), 0, cfg.vocab_size)
    full = _batch(cfg, key)
    full["tokens"] = toks
    pref = dict(full, tokens=toks[:, :s])

    logits_full, _ = jax.jit(lambda p, bb: forward(p, cfg, bb))(params, full)
    off = cfg.num_patches if cfg.family == "vlm" else 0

    cache = init_cache(cfg, b, max_len)
    lg, cache = jax.jit(lambda p, bb, c: prefill(p, cfg, bb, c))(params, pref, cache)
    np.testing.assert_allclose(
        np.asarray(lg, np.float32),
        np.asarray(logits_full[:, off + s - 1, :], np.float32),
        rtol=1e-4, atol=1e-4,
    )
    dstep = jax.jit(lambda p, t, c: decode_step(p, cfg, t, c))
    for i in range(extra_steps):
        lg, cache = dstep(params, toks[:, s + i], cache)
        np.testing.assert_allclose(
            np.asarray(lg, np.float32),
            np.asarray(logits_full[:, off + s + i, :], np.float32),
            rtol=1e-4, atol=1e-4,
        )
