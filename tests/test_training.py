"""Training substrate: optimizer, microbatching, data, checkpoints, compression."""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import init_params
from repro.training import (
    AdamWConfig,
    CheckpointManager,
    PackedShards,
    SyntheticStream,
    TrainConfig,
    adamw_init,
    make_train_step,
    write_token_shards,
)
from repro.training.optimizer import cosine_schedule


def _setup(arch="llama3-8b", **overrides):
    cfg = dataclasses.replace(get_reduced(arch), **overrides)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    return cfg, params, opt


def test_train_step_decreases_loss():
    cfg, params, opt = _setup()
    tc = TrainConfig(optimizer=AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=50))
    step = jax.jit(make_train_step(cfg, tc))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                          cfg.vocab_size)}
    losses = []
    for _ in range(8):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.1, losses


def test_microbatch_equivalence():
    """Grad accumulation over microbatches == single big batch step."""
    cfg, params, opt = _setup()
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (8, 16), 0,
                                          cfg.vocab_size)}
    oc = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    p1, _, m1 = jax.jit(make_train_step(cfg, TrainConfig(optimizer=oc)))(
        params, opt, batch
    )
    p2, _, m2 = jax.jit(
        make_train_step(cfg, TrainConfig(optimizer=oc, microbatches=4))
    )(params, opt, batch)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=2e-4, atol=2e-5,
        )


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    assert float(cosine_schedule(cfg, jnp.int32(0))) == 0.0
    assert abs(float(cosine_schedule(cfg, jnp.int32(10))) - 1.0) < 1e-6
    assert float(cosine_schedule(cfg, jnp.int32(100))) == pytest.approx(0.1, rel=1e-3)


def test_synthetic_stream_deterministic_and_sharded():
    s1 = SyntheticStream(100, batch_size=8, seq_len=16, seed=3, dp_rank=0, dp_world=2)
    s2 = SyntheticStream(100, batch_size=8, seq_len=16, seed=3, dp_rank=0, dp_world=2)
    s3 = SyntheticStream(100, batch_size=8, seq_len=16, seed=3, dp_rank=1, dp_world=2)
    b1, b2, b3 = s1.batch_at(7), s2.batch_at(7), s3.batch_at(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])  # deterministic
    assert b1["tokens"].shape == (4, 16)                        # local batch
    assert not np.array_equal(b1["tokens"], b3["tokens"])       # disjoint ranks


def test_packed_shards_roundtrip(tmp_path):
    path = str(tmp_path / "shards")
    write_token_shards(path, num_shards=2, tokens_per_shard=256, vocab_size=50)
    ds = PackedShards(path, batch_size=4, seq_len=16, dp_rank=1, dp_world=2)
    b0 = ds.batch_at(0)
    b0_again = ds.batch_at(0)
    np.testing.assert_array_equal(b0["tokens"], b0_again["tokens"])
    assert b0["tokens"].shape == (2, 16)
    assert b0["tokens"].max() < 50


def test_checkpoint_roundtrip_and_keep_k(tmp_path):
    cfg, params, opt = _setup()
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for step in (10, 20, 30):
        mgr.save(step, (params, opt), blocking=True)
    assert mgr.all_steps() == [20, 30]           # keep-k GC
    step, (p2, o2) = mgr.restore((params, opt))
    assert step == 30
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity(tmp_path):
    """A stale tmp dir never masks or corrupts the published checkpoint."""
    cfg, params, opt = _setup()
    mgr = CheckpointManager(str(tmp_path), keep=3)
    os.makedirs(str(tmp_path / "tmp.99"))        # simulated crashed save
    mgr.save(99, (params, opt), blocking=True)
    assert mgr.all_steps() == [99]
    _, restored = mgr.restore((params, opt))


def test_checkpoint_elastic_reshard(tmp_path):
    """Restore under a different device mesh (elastic restart)."""
    cfg, params, opt = _setup()
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, params, blocking=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    from jax.sharding import NamedSharding, PartitionSpec as P

    shardings = jax.tree.map(lambda _: NamedSharding(mesh, P()), params)
    step, restored = mgr.restore(params, shardings=shardings)
    assert step == 5
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_grad_compression_error_feedback():
    from repro.distributed.compress import compress_with_feedback

    g = {"w": jnp.linspace(-1.0, 1.0, 1024).reshape(32, 32)}
    err = None
    acc_true = np.zeros((32, 32))
    acc_q = np.zeros((32, 32))
    for _ in range(50):
        gq, err = compress_with_feedback(g, err)
        acc_true += np.asarray(g["w"])
        acc_q += np.asarray(gq["w"])
    # Error feedback keeps the long-run average unbiased.
    rel = np.abs(acc_q - acc_true).max() / np.abs(acc_true).max()
    assert rel < 0.01, rel


def test_train_with_compression_still_learns():
    cfg, params, opt = _setup()
    tc = TrainConfig(
        optimizer=AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=50),
        compress_grads=True,
    )
    step = jax.jit(make_train_step(cfg, tc))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                          cfg.vocab_size)}
    losses = []
    for _ in range(8):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.05, losses
