"""repro.analysis: the reprolint rule catalog + the retrace sanitizer.

Tentpole coverage: each JX rule fires on a known-bad fixture snippet, stays
silent on the repaired version, and honors inline suppression; the baseline
machinery diffs strictly (new findings AND stale entries fail); the repo
itself lints clean against the committed baseline.

Runtime sanitizer coverage: :func:`repro.analysis.retrace_guard` counts jit
cache misses, raises :class:`RetraceError` on variable-shape retraces, and
— the load-bearing assertion — pins ``traces == 1`` on the continuous
serving hot path's ``admit`` / ``evict`` / ``run_segment`` / ``result``
graphs across a ragged-arrival drain, dense and paged (PR 8's 30x
variable-shape-admit regression class, as a permanent red test).
"""

import dataclasses
import json
import pathlib

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import (
    Baseline,
    RetraceError,
    diff_baseline,
    lint_paths,
    lint_source,
    retrace_guard,
    rule_catalog,
)
from repro.analysis.lint import main as lint_main

REPO = pathlib.Path(__file__).resolve().parents[1]


def rules_fired(src, path="src/repro/core/fake.py"):
    return sorted({f.rule for f in lint_source(src, path)})


# ---------------------------------------------------------------------------
# JX001 — retrace hazard
# ---------------------------------------------------------------------------
JX001_BAD = """
import jax, jax.numpy as jnp
step = jax.jit(lambda x: x + 1)

def admit(prompts):
    rows = [p for p in prompts]
    return step(jnp.asarray(rows))
"""

JX001_GOOD = """
import jax, jax.numpy as jnp
step = jax.jit(lambda x: x + 1)

def admit_one(b):
    return step(jnp.asarray([b], jnp.int32))
"""


def test_jx001_fires_on_varying_shape_call():
    assert "JX001" in rules_fired(JX001_BAD)
    # len()-derived sizes are the other historical shape of the bug.
    assert "JX001" in rules_fired(
        "import jax, jax.numpy as jnp\n"
        "f = jax.jit(lambda x: x)\n"
        "def g(xs):\n"
        "    return f(jnp.zeros((len(xs), 4)))\n"
    )


def test_jx001_silent_on_fixed_shape_call():
    assert "JX001" not in rules_fired(JX001_GOOD)


def test_jx001_inline_suppression():
    suppressed = JX001_BAD.replace(
        "return step(jnp.asarray(rows))",
        "return step(jnp.asarray(rows))  # reprolint: disable=JX001",
    )
    assert "JX001" not in rules_fired(suppressed)


# ---------------------------------------------------------------------------
# JX002 — host sync in traced code / dispatch in hot loops
# ---------------------------------------------------------------------------
JX002_TRACED_BAD = """
import jax
import numpy as np

@jax.jit
def tick(x):
    return np.asarray(x).sum() + float(x)
"""

JX002_TRACED_GOOD = """
import jax, jax.numpy as jnp

@jax.jit
def tick(x):
    n = int(x.shape[0])  # static shape read, not a host sync
    return jnp.sum(x) / n
"""

JX002_LOOP_BAD = """
import jax.numpy as jnp

def master_tick(xs):
    out = []
    for x in xs:
        out.append(jnp.sum(x))
    return out
"""


def test_jx002_fires_on_host_sync_in_traced_scope():
    assert "JX002" in rules_fired(JX002_TRACED_BAD)
    assert "JX002" in rules_fired(
        "import jax\n@jax.jit\ndef f(x):\n    return x.item()\n"
    )


def test_jx002_silent_on_static_shape_reads():
    assert "JX002" not in rules_fired(JX002_TRACED_GOOD)


def test_jx002_fires_on_hot_loop_dispatch_in_core_paths_only():
    assert "JX002" in rules_fired(JX002_LOOP_BAD)
    # Same code outside core/serving, or in a non-hot-named function,
    # is not a tick path and stays silent.
    assert "JX002" not in rules_fired(
        JX002_LOOP_BAD, path="src/repro/models/fake.py"
    )
    assert "JX002" not in rules_fired(
        JX002_LOOP_BAD.replace("master_tick", "build_tables")
    )


def test_jx002_inline_suppression():
    suppressed = JX002_LOOP_BAD.replace(
        "        out.append(jnp.sum(x))",
        "        # reprolint: disable=JX002\n        out.append(jnp.sum(x))",
    )
    assert "JX002" not in rules_fired(suppressed)


# ---------------------------------------------------------------------------
# JX003 — RNG key discipline
# ---------------------------------------------------------------------------
JX003_DOUBLE = """
import jax

def f(seed):
    key = jax.random.PRNGKey(seed)
    a = jax.random.normal(key, (3,))
    b = jax.random.uniform(key, (3,))
    return a, b
"""

JX003_LOOP = """
import jax

def g(key, n):
    out = []
    for i in range(n):
        out.append(jax.random.normal(key, ()))
    return out
"""

JX003_PARENT = """
import jax

def h(seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, ())
    k2 = jax.random.fold_in(key, 1)
    return x, k2
"""

JX003_GOOD = """
import jax

def f(seed):
    key = jax.random.PRNGKey(seed)
    ka, kb = jax.random.split(key)
    a = jax.random.normal(ka, (3,))
    b = jax.random.uniform(kb, (3,))
    return a, b

def g(key, n):
    return [
        jax.random.normal(jax.random.fold_in(key, i), ()) for i in range(n)
    ]
"""


def test_jx003_fires_on_double_consumption():
    assert "JX003" in rules_fired(JX003_DOUBLE)


def test_jx003_fires_on_loop_reuse_of_outer_key():
    assert "JX003" in rules_fired(JX003_LOOP)


def test_jx003_fires_on_sampler_plus_parent_use():
    assert "JX003" in rules_fired(JX003_PARENT)


def test_jx003_silent_on_split_and_fold_in_discipline():
    assert "JX003" not in rules_fired(JX003_GOOD)


def test_jx003_inline_suppression():
    suppressed = JX003_DOUBLE.replace(
        "    b = jax.random.uniform(key, (3,))",
        "    b = jax.random.uniform(key, (3,))  # reprolint: disable=JX003",
    )
    assert "JX003" not in rules_fired(suppressed)


# ---------------------------------------------------------------------------
# JX004 — exception hygiene / silent clipping
# ---------------------------------------------------------------------------
def test_jx004_fires_on_bare_and_broad_except():
    assert "JX004" in rules_fired(
        "def f():\n    try:\n        g()\n    except:\n        pass\n"
    )
    assert "JX004" in rules_fired(
        "def f():\n    try:\n        g()\n"
        "    except Exception as e:\n        print(e)\n"
    )


def test_jx004_silent_on_specific_tuple_and_reraise():
    assert "JX004" not in rules_fired(
        "def f():\n    try:\n        g()\n"
        "    except (OSError, ValueError):\n        pass\n"
    )
    assert "JX004" not in rules_fired(
        "def f():\n    try:\n        g()\n"
        "    except Exception:\n        cleanup()\n        raise\n"
    )


def test_jx004_fires_on_silent_action_clip():
    bad = (
        "import jax.numpy as jnp\n"
        "def decide(action, k):\n"
        "    return jnp.clip(action, 0, k - 1)\n"
    )
    assert "JX004" in rules_fired(bad)
    # A validating function (it raises) may clip for padding rows.
    good = bad.replace(
        "    return jnp.clip(action, 0, k - 1)\n",
        "    if action.min() < 0:\n"
        "        raise ValueError('bad action')\n"
        "    return jnp.clip(action, 0, k - 1)\n",
    )
    assert "JX004" not in rules_fired(good)
    # Clipping non-user-facing values (kernel index clamps) is fine.
    assert "JX004" not in rules_fired(
        "import jax.numpy as jnp\n"
        "def gather(table, p):\n"
        "    return jnp.clip(table, 0, p - 1)\n"
    )


def test_jx004_inline_suppression_with_justification_comment():
    bad = (
        "import jax.numpy as jnp\n"
        "def decide(action, k):\n"
        "    # validated at the eager boundary\n"
        "    # reprolint: disable=JX004\n"
        "    return jnp.clip(action, 0, k - 1)\n"
    )
    assert "JX004" not in rules_fired(bad)


# ---------------------------------------------------------------------------
# JX005 — kernel ref-oracle contract (project rule, real file trees)
# ---------------------------------------------------------------------------
def _kernel_tree(tmp_path, *, ref=True, named=True):
    pkg = tmp_path / "src" / "repro" / "kernels" / "fused_topk"
    pkg.mkdir(parents=True)
    (pkg / "__init__.py").write_text("")
    (pkg / "fused_topk.py").write_text("def fused_topk():\n    pass\n")
    if ref:
        (pkg / "ref.py").write_text("def topk_ref():\n    pass\n")
    tests = tmp_path / "tests"
    tests.mkdir()
    body = "from x import fused_topk\n" if named else "import x\n"
    (tests / "test_kernels.py").write_text(body)
    return tmp_path


def test_jx005_clean_when_ref_and_parity_test_exist(tmp_path):
    root = _kernel_tree(tmp_path)
    found = lint_paths(["src", "tests"], root=str(root))
    assert not [f for f in found if f.rule == "JX005"]


def test_jx005_fires_on_missing_ref(tmp_path):
    root = _kernel_tree(tmp_path, ref=False)
    found = [f for f in lint_paths(["src", "tests"], root=str(root))
             if f.rule == "JX005"]
    assert found and "ref.py" in found[0].message


def test_jx005_fires_on_unnamed_kernel(tmp_path):
    root = _kernel_tree(tmp_path, named=False)
    found = [f for f in lint_paths(["src", "tests"], root=str(root))
             if f.rule == "JX005"]
    assert found and "parity test" in found[0].message


# ---------------------------------------------------------------------------
# Engine: baseline diff, CLI, repo-clean
# ---------------------------------------------------------------------------
def test_baseline_diff_strict(tmp_path):
    findings = lint_source(JX001_BAD, "src/repro/core/fake.py")
    assert findings
    entry = {
        "rule": findings[0].rule, "path": findings[0].path,
        "message": findings[0].message, "justification": "grandfathered",
    }
    stale_entry = dict(entry, rule="JX004", message="gone")
    new, stale = diff_baseline(findings, Baseline([entry, stale_entry]))
    assert not [f for f in new if f.key == findings[0].key]
    assert stale == [stale_entry]


def test_baseline_requires_justification(tmp_path):
    p = tmp_path / "b.json"
    p.write_text(json.dumps({"findings": [
        {"rule": "JX001", "path": "x.py", "message": "m"}
    ]}))
    with pytest.raises(ValueError, match="justification"):
        Baseline.load(str(p))


def test_cli_exit_codes(tmp_path, monkeypatch, capsys):
    pkg = tmp_path / "src" / "repro" / "core"
    pkg.mkdir(parents=True)
    (pkg / "engine.py").write_text(JX001_BAD)
    monkeypatch.chdir(tmp_path)
    assert lint_main(["src"]) == 1
    out = capsys.readouterr().out
    assert "JX001" in out
    # Baselining the finding makes the run green; a stale extra entry
    # fails it again (strict diff in both directions).
    code = lint_main(["src", "--json"])
    data = json.loads(capsys.readouterr().out)
    assert code == 1 and data["new"]
    entries = [dict(f, justification="known") for f in data["new"]]
    for e in entries:
        e.pop("line"), e.pop("col")
    base = tmp_path / "reprolint_baseline.json"
    base.write_text(json.dumps({"findings": entries}))
    assert lint_main(["src"]) == 0
    entries.append(dict(entries[0], message="no longer fires",
                        justification="stale"))
    base.write_text(json.dumps({"findings": entries}))
    assert lint_main(["src"]) == 1
    assert lint_main(["missing_dir"]) == 2


def test_rule_catalog_is_complete():
    ids = [r[0] for r in rule_catalog()]
    assert ids == ["JX001", "JX002", "JX003", "JX004", "JX005"]
    assert all(title and regression for _, title, regression in
               rule_catalog())


def test_repo_lints_clean_against_committed_baseline():
    """The acceptance gate, as a tier-1 test: the repo's own sources give
    zero diff against the committed baseline."""
    findings = lint_paths(["src", "tests"], root=str(REPO))
    baseline = Baseline.load(str(REPO / "reprolint_baseline.json"))
    new, stale = diff_baseline(findings, baseline)
    assert not new, [f.format() for f in new]
    assert not stale, stale


# ---------------------------------------------------------------------------
# retrace_guard: unit behavior
# ---------------------------------------------------------------------------
def test_retrace_guard_counts_and_passes_on_stable_shapes():
    f = jax.jit(lambda x: x * 2)
    with retrace_guard(f=f) as g:
        f(jnp.ones((4,)))
        f(jnp.zeros((4,)))  # same signature: no new trace
    assert g.counts() == {"f": 1}


def test_retrace_guard_raises_on_shape_driven_retrace():
    f = jax.jit(lambda x: x * 2)
    with pytest.raises(RetraceError, match="f: 2 traces"):
        with retrace_guard(f=f):
            f(jnp.ones((4,)))
            f(jnp.ones((5,)))  # second signature: retrace


def test_retrace_guard_max_traces_and_preexisting_cache():
    f = jax.jit(lambda x: x + 1)
    f(jnp.ones((2,)))  # traced before the guard: not counted
    with retrace_guard(max_traces=2, f=f) as g:
        f(jnp.ones((3,)))
        f(jnp.ones((4,)))
    assert g.counts() == {"f": 2}


def test_retrace_guard_rejects_unjitted_and_propagates_errors():
    with pytest.raises(TypeError, match="jitted"):
        retrace_guard(f=lambda x: x)
    # An exception inside the region is not masked by the exit check.
    f = jax.jit(lambda x: x)
    with pytest.raises(KeyError):
        with retrace_guard(f=f):
            f(jnp.ones((1,)))
            f(jnp.ones((2,)))
            raise KeyError("boom")


# ---------------------------------------------------------------------------
# retrace_guard: the serving hot path traces each graph exactly once
# ---------------------------------------------------------------------------
def _tiny_lm():
    from repro.configs import get_reduced
    from repro.models import init_params

    cfg = dataclasses.replace(
        get_reduced("llama3-8b"), vocab_size=64, num_layers=1,
        d_model=32, num_heads=2, num_kv_heads=1, head_dim=16, d_ff=64,
    )
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
def test_serving_poll_traces_each_graph_once(paged):
    """Ragged-arrival drain (R = 3B) through submit/poll/drain: the jitted
    admit / evict / run_segment / result graphs each compile exactly ONE
    signature.  A variable-shape admission batch would retrace per distinct
    row count — the PR 8 regression this test makes permanently red."""
    from repro.core import SearchSpec
    from repro.serving import SearchService

    cfg, params = _tiny_lm()
    spec = SearchSpec(
        algo="wu_uct", engine="async", batch=2, num_simulations=6,
        wave_size=2, max_depth=3, max_sim_steps=3, max_width=4, gamma=1.0,
    )
    svc = SearchService(
        cfg, params, spec, top_k=4, max_len=12, eos_token=1,
        paged=paged, block_size=4, ticks_per_round=4, fused=False,
    )
    svc._ensure_engine()
    prompts = [[3, 5], [2, 9, 4], [7], [1, 2, 3], [5, 5], [6]]
    with retrace_guard(
        admit=svc._admit_fn, evict=svc._evict_fn,
        segment=svc._segment, result=svc._result_fn,
    ) as g:
        rows = svc.serve(prompts)
    # Every graph was exercised (not just never called) and traced once.
    assert g.counts() == {"admit": 1, "evict": 1, "segment": 1, "result": 1}
    assert len(rows) == len(prompts)
    assert svc.stats.completed == len(prompts)


@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
def test_serving_fused_traces_each_graph_once(paged):
    """The device-resident ring path compiles exactly ONE signature per
    graph across a ragged 6-request drain: `stage` (fixed [1] request
    shape) and the fused `serve_segment` (harvest + ring admission inside
    the while_loop) — host-pacing's per-row admit/evict graphs never run."""
    from repro.core import SearchSpec
    from repro.serving import SearchService

    cfg, params = _tiny_lm()
    spec = SearchSpec(
        algo="wu_uct", engine="async", batch=2, num_simulations=6,
        wave_size=2, max_depth=3, max_sim_steps=3, max_width=4, gamma=1.0,
    )
    svc = SearchService(
        cfg, params, spec, top_k=4, max_len=12, eos_token=1,
        paged=paged, block_size=4, ticks_per_round=4,
    )
    svc._ensure_engine()
    prompts = [[3, 5], [2, 9, 4], [7], [1, 2, 3], [5, 5], [6]]
    with retrace_guard(
        stage=svc._stage_fn, segment=svc._serve_fn,
        admit=svc._admit_fn, evict=svc._evict_fn,
    ) as g:
        rows = svc.serve(prompts)
    assert g.counts() == {"stage": 1, "segment": 1, "admit": 0, "evict": 0}
    assert len(rows) == len(prompts)
    assert svc.stats.completed == len(prompts)
    # One host round per segment, not one per poll — and the drain needed
    # strictly fewer segments than requests.
    assert svc.stats.host_rounds >= 1
    assert svc.stats.admissions == len(prompts)
