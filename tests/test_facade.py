"""The search front door (core/api.py): golden equivalence + evaluators.

Three claim families:

* ``build_searcher(env, spec)`` reproduces the direct engine entry points
  *bit-exactly* for every ``(engine, batch, algo)`` cell — the facade is
  pure dispatch, never a different search;
* the deprecated pre-facade shims are gone from ``repro.core`` (their
  one-release grace period ended) while the engine modules stay importable;
* ``ModelEvaluator`` issues exactly ONE batched model forward per master
  tick on the async engines (counted with a traced callback), while
  reproducing the token environment's transition semantics.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as core
from repro.core import ModelEvaluator, RolloutEvaluator, SearchSpec, build_searcher
from repro.core.api import as_search_config, make_config
from repro.core.async_search import run_async_search
from repro.core.baselines import make_algorithm, run_leafp, run_rootp
from repro.core.batched_async_search import run_async_search_batched
from repro.core.batched_search import run_search_batched
from repro.core.wu_uct import run_search
from repro.envs import make_bandit_tree


@pytest.fixture(scope="module")
def env():
    return make_bandit_tree(depth=4, num_actions=3, seed=0)


def _spec(**kw) -> SearchSpec:
    base = dict(
        num_simulations=16, wave_size=4, max_depth=5, max_sim_steps=5,
        max_width=3, gamma=0.99,
    )
    base.update(kw)
    return SearchSpec(**base)


def _assert_results_equal(a, b, msg=""):
    assert type(a) is type(b)
    for f in a._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)),
            err_msg=f"{msg}: field {f}",
        )


# ---------------------------------------------------------------------------
# Golden bit-equivalence: facade vs direct engine call, per cell.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "algo", ["wu_uct", "uct", "treep", "treep_vc", "leafp", "rootp"]
)
def test_facade_matches_wave_single(env, algo):
    spec = _spec(algo=algo)
    cfg = as_search_config(spec)
    key = jax.random.PRNGKey(3)
    root = env.init(key)
    res = build_searcher(env, spec)(root, key)
    direct = {
        "leafp": lambda: run_leafp(env, cfg, root, key),
        "rootp": lambda: run_rootp(env, cfg, root, key),
    }.get(algo, lambda: run_search(env, cfg, root, key))
    _assert_results_equal(res, jax.jit(direct)(), f"wave/{algo}")


@pytest.mark.parametrize("algo", ["wu_uct", "uct"])
def test_facade_matches_async_single(env, algo):
    spec = _spec(algo=algo, engine="async")
    cfg = as_search_config(spec)
    key = jax.random.PRNGKey(4)
    root = env.init(key)
    res = build_searcher(env, spec)(root, key)
    direct = jax.jit(lambda s, k: run_async_search(env, cfg, s, k))(root, key)
    _assert_results_equal(res, direct, f"async/{algo}")


@pytest.mark.parametrize("algo", ["wu_uct", "treep", "treep_vc"])
def test_facade_matches_wave_batched(env, algo):
    B = 3
    spec = _spec(algo=algo, batch=B)
    cfg = as_search_config(spec)
    roots = jax.vmap(env.init)(jax.random.split(jax.random.PRNGKey(0), B))
    rngs = jax.random.split(jax.random.PRNGKey(1), B)
    res = build_searcher(env, spec)(roots, rngs)
    direct = jax.jit(
        lambda s, k: run_search_batched(env, cfg, s, k)
    )(roots, rngs)
    _assert_results_equal(res, direct, f"wave/batched/{algo}")
    assert res.action.shape == (B,)


def test_facade_matches_async_batched(env):
    B = 3
    spec = _spec(algo="wu_uct", engine="async", batch=B)
    cfg = as_search_config(spec)
    roots = jax.vmap(env.init)(jax.random.split(jax.random.PRNGKey(0), B))
    rngs = jax.random.split(jax.random.PRNGKey(1), B)
    res = build_searcher(env, spec)(roots, rngs)
    direct = jax.jit(
        lambda s, k: run_async_search_batched(env, cfg, s, k)
    )(roots, rngs)
    _assert_results_equal(res, direct, "async/batched")


def test_facade_accepts_typed_prng_keys(env):
    """New-style typed keys (jax.random.key) must work end to end — the
    single-tree traverse canonicalizes them before the batched B=1 walk."""
    spec = _spec(algo="wu_uct")
    typed = jax.random.key(7)
    root = env.init(typed)
    res = build_searcher(env, spec)(root, typed)
    raw = jax.random.PRNGKey(7)
    res_raw = build_searcher(env, spec)(env.init(raw), raw)
    _assert_results_equal(res, res_raw, "typed vs raw keys")


def test_use_kernel_false_reachable_and_equal(env):
    """spec.use_kernel=False must route single-tree selection through the
    jnp reference scorer — and agree with the Pallas kernel path."""
    for engine in ("wave", "async"):
        spec = _spec(algo="wu_uct", engine=engine)
        key = jax.random.PRNGKey(11)
        root = env.init(key)
        res_k = build_searcher(env, spec)(root, key)
        res_r = build_searcher(env, spec._replace(use_kernel=False))(root, key)
        _assert_results_equal(res_k, res_r, f"use_kernel {engine}")


def test_explicit_rollout_evaluator_is_default(env):
    spec = _spec(algo="wu_uct")
    key = jax.random.PRNGKey(9)
    root = env.init(key)
    res_default = build_searcher(env, spec)(root, key)
    res_explicit = build_searcher(
        env, spec, evaluator=RolloutEvaluator(env)
    )(root, key)
    _assert_results_equal(res_default, res_explicit, "explicit evaluator")


# ---------------------------------------------------------------------------
# Spec surface: validation, lowering, legacy builders.
# ---------------------------------------------------------------------------


def test_spec_validation(env):
    with pytest.raises(ValueError):
        build_searcher(env, _spec(algo="leafp", engine="async"))
    with pytest.raises(ValueError):
        build_searcher(env, _spec(algo="rootp", batch=2))
    with pytest.raises(ValueError):
        as_search_config(_spec(algo="nope"))
    with pytest.raises(ValueError):
        as_search_config(_spec(engine="nope"))
    with pytest.raises(ValueError):
        build_searcher(env, _spec(batch=-1))


def test_spec_lowering_modes():
    assert as_search_config(_spec(algo="wu_uct")).stat_mode == "wu"
    assert as_search_config(_spec(algo="treep")).stat_mode == "vl"
    assert as_search_config(_spec(algo="treep_vc")).stat_mode == "wu"
    cfg = as_search_config(_spec(algo="uct", wave_size=16))
    assert cfg.wave_size == 1 and cfg.stat_mode == "none"
    cfg = as_search_config(_spec(algo="treep", r_vl=0.25, beta=2.0))
    assert cfg.policy.kind == "treep"
    assert cfg.policy.r_vl == 0.25 and cfg.policy.beta == 2.0


def test_make_config_reexpressed_over_spec():
    kw = dict(num_simulations=32, wave_size=8, max_depth=6, max_sim_steps=6,
              max_width=4, gamma=0.9)
    for algo in ("wu_uct", "uct", "treep", "treep_vc", "leafp", "rootp"):
        assert make_config(algo, **kw) == as_search_config(
            SearchSpec(algo=algo, **kw)
        )
    # Legacy escape hatches still override.
    from repro.core import PolicyConfig
    cfg = make_config("wu_uct", policy=PolicyConfig(kind="uct"),
                      stat_mode="none", **kw)
    assert cfg.policy.kind == "uct" and cfg.stat_mode == "none"


def test_deprecated_shims_are_gone():
    """The pre-facade entry points finished their one-release deprecation
    window: `repro.core` no longer re-exports them (the engine modules keep
    the real functions for oracles/tests)."""
    for name in (
        "run_search", "run_search_batched", "run_async_search",
        "run_async_search_batched", "run_leafp", "run_treep", "run_rootp",
        "make_searcher", "make_async_searcher", "make_batched_searcher",
        "make_batched_async_searcher", "make_algorithm",
    ):
        assert not hasattr(core, name), f"shim {name} should be removed"
        assert name not in core.__all__


def test_make_algorithm_still_dispatches(env):
    # make_algorithm is the legacy multi-algo dispatcher; it must agree with
    # the facade on a baseline algo.
    spec = _spec(algo="leafp")
    cfg = as_search_config(spec)
    key = jax.random.PRNGKey(6)
    root = env.init(key)
    golden = build_searcher(env, spec)(root, key)
    res = make_algorithm("leafp", env, cfg)(root, key)
    _assert_results_equal(res, golden, "make_algorithm leafp")


# ---------------------------------------------------------------------------
# ModelEvaluator: one batched LM forward per master tick.
# ---------------------------------------------------------------------------


def _tiny_lm(vocab=64):
    from repro.configs import get_reduced
    from repro.models import init_params

    cfg = dataclasses.replace(
        get_reduced("llama3-8b"), vocab_size=vocab, num_layers=1,
        d_model=32, num_heads=2, num_kv_heads=1, head_dim=16, d_ff=64,
    )
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


def _counting_forward(calls):
    from repro.models import forward

    def fn(params, cfg, batch):
        jax.debug.callback(lambda: calls.append(1))
        return forward(params, cfg, batch)

    return fn


def test_model_evaluator_one_forward_per_tick():
    from repro.envs.token_env import make_token_env

    cfg, params = _tiny_lm()
    prompt = jnp.asarray([3, 5, 7], jnp.int32)
    env = make_token_env(cfg, params, prompt, max_len=12, top_k=4, eos_token=1)
    calls = []
    ev = ModelEvaluator(
        cfg, params, top_k=4, eos_token=1, forward_fn=_counting_forward(calls)
    )
    spec = SearchSpec(
        algo="wu_uct", engine="async", num_simulations=12, wave_size=4,
        max_depth=5, max_sim_steps=5, max_width=4, gamma=1.0,
    )
    search = build_searcher(env, spec, evaluator=ev)
    key = jax.random.PRNGKey(0)
    res = jax.block_until_ready(search(env.init(key), key))
    jax.effects_barrier()
    assert len(calls) == int(res.ticks), (len(calls), int(res.ticks))
    assert int(res.tree_size) > 1  # the search actually grew a tree


def test_model_evaluator_one_forward_per_tick_batched():
    from repro.envs.token_env import make_token_env

    cfg, params = _tiny_lm()
    prompt = jnp.asarray([3, 5, 7], jnp.int32)
    env = make_token_env(cfg, params, prompt, max_len=12, top_k=4, eos_token=1)
    calls = []
    ev = ModelEvaluator(
        cfg, params, top_k=4, eos_token=1, forward_fn=_counting_forward(calls)
    )
    B = 3
    spec = SearchSpec(
        algo="wu_uct", engine="async", batch=B, num_simulations=12,
        wave_size=4, max_depth=5, max_sim_steps=5, max_width=4, gamma=1.0,
    )
    search = build_searcher(env, spec, evaluator=ev)
    key = jax.random.PRNGKey(0)
    roots = jax.vmap(env.init)(jax.random.split(key, B))
    res = jax.block_until_ready(search(roots, jax.random.split(key, B)))
    jax.effects_barrier()
    # The master loop runs until the slowest tree finishes; every iteration
    # is exactly one [B·W] forward.
    assert len(calls) == int(np.asarray(res.ticks).max()), (
        len(calls), np.asarray(res.ticks),
    )


def test_model_evaluator_matches_token_env_transitions():
    """ModelEvaluator's batched transition == token_env.step per slot."""
    from repro.core.evaluators import SIM
    from repro.envs.token_env import make_token_env

    cfg, params = _tiny_lm()
    prompt = jnp.asarray([3, 5], jnp.int32)
    env = make_token_env(cfg, params, prompt, max_len=8, top_k=4, eos_token=1)
    ev = ModelEvaluator(cfg, params, top_k=4, eos_token=1)

    s0 = env.init(jax.random.PRNGKey(0))
    n = 3
    state = jax.tree.map(lambda x: jnp.stack([x] * n), s0)
    kind = jnp.full((n,), SIM, jnp.int32)
    act = jnp.arange(n, dtype=jnp.int32)  # ignored for SIM slots
    keys = jax.random.split(jax.random.PRNGKey(1), n)
    scfg = SearchSpec(gamma=1.0, max_sim_steps=4).config

    (new_state, r, done, acc, disc, steps, rdone), _ = ev.tick(
        scfg, kind, act, state,
        jnp.zeros((n,), jnp.bool_), jnp.zeros((n,), jnp.float32),
        jnp.ones((n,), jnp.float32), jnp.zeros((n,), jnp.int32), keys,
    )
    # Per slot: the sampled action, stepped through the *env*, must produce
    # the same state/reward the evaluator computed in one batched forward.
    for i in range(n):
        tok_i = new_state.tokens[i, s0.length]
        pol = ev._position_logits(
            params, cfg, state.tokens[i][None], state.length[i][None]
        )[0]
        _, top_idx = jax.lax.top_k(pol, 4)
        assert int(tok_i) in [int(t) for t in top_idx]
        # Reward equals the env's reward for that token's rank.
        rank = int(jnp.argmax(top_idx == tok_i))
        _, r_env, d_env = jax.jit(env.step)(
            jax.tree.map(lambda x: x[i], state), jnp.int32(rank)
        )
        np.testing.assert_allclose(float(r[i]), float(r_env), rtol=1e-5)
        assert bool(done[i]) == bool(d_env)
        assert int(steps[i]) == 1


def test_search_service_batched_decide():
    from repro.serving import SearchService

    cfg, params = _tiny_lm()
    service = SearchService(
        cfg, params,
        SearchSpec(algo="wu_uct", engine="async", batch=3, num_simulations=8,
                   wave_size=2, max_depth=4, max_sim_steps=4, max_width=4,
                   gamma=1.0),
        top_k=4, max_len=12, eos_token=1,
    )
    prompts = [[3, 5, 7], [2, 9]]
    tokens, res = service.decide(prompts, jax.random.PRNGKey(0))
    assert len(tokens) == 2
    assert all(0 <= t < cfg.vocab_size for t in tokens)
    assert res.action.shape == (3,)  # padded to spec.batch
