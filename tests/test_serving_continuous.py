"""Continuous batching for the search service + serving-layer bug sweep.

Tentpole coverage: the persistent :class:`BatchedAsyncEngine` behind
``SearchService.submit/poll/drain/serve`` — a ragged-arrival workload with
more requests than tree rows drains with per-request results, occupancy
counters stay sane, paged pools leak nothing, and (the load-bearing claim)
a request admitted into a recycled row mid-``while_loop`` reaches exactly
the search a fresh batch would have given it.

Satellite coverage: over-long prompt rejection (named error, dense +
paged), ``ServingEngine.run`` slot reuse under request pressure,
``decide``'s invalid-action surfacing, the benchmark-baseline lookup
(env override + warn-once fallback), and the trace-mode occupancy
counters.
"""

import dataclasses
import functools
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import PolicyConfig, SearchConfig, SearchSpec
from repro.core.batched_async_search import run_async_search_batched
from repro.envs import make_bandit_tree
from repro.models import init_params
from repro.serving import (
    InvalidSearchActionError,
    PromptTooLongError,
    SearchService,
    ServeConfig,
    ServingEngine,
)


def _tiny_lm(vocab=64):
    cfg = dataclasses.replace(
        get_reduced("llama3-8b"), vocab_size=vocab, num_layers=1,
        d_model=32, num_heads=2, num_kv_heads=1, head_dim=16, d_ff=64,
    )
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def tiny_lm():
    return _tiny_lm()


def _spec(batch=2):
    return SearchSpec(
        algo="wu_uct", engine="async", batch=batch, num_simulations=6,
        wave_size=2, max_depth=3, max_sim_steps=3, max_width=4, gamma=1.0,
    )


def _service(tiny_lm, paged, **kw):
    cfg, params = tiny_lm
    kw.setdefault("ticks_per_round", 4)
    return SearchService(
        cfg, params, _spec(), top_k=4, max_len=12, eos_token=1,
        paged=paged, block_size=4, **kw,
    )


PROMPTS = [[3, 5], [2, 9, 4], [7], [1, 2, 3], [5, 5], [6]]


# ---------------------------------------------------------------------------
# Tentpole: continuous serving through the persistent engine
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
def test_ragged_arrival_drains_with_per_request_results(tiny_lm, paged):
    """R = 3*B ragged arrivals all finish, each with its own result row."""
    svc = _service(tiny_lm, paged)
    rows = svc.serve(PROMPTS)
    assert len(rows) == len(PROMPTS)
    for r in rows:
        assert 0 <= int(r.action) < 4
        # A per-request row, not a batch: scalar action, [A] visit counts.
        assert r.action.ndim == 0 and r.root_n.shape == (4,)
        assert float(jnp.sum(r.root_n)) > 0
    st = svc.stats
    assert st.submitted == st.completed == st.admissions == len(PROMPTS)
    assert st.ticks > 0
    assert 0.0 <= st.slot_idle_frac < 1.0
    if paged:
        # Every drained request returned its pages: the pool is whole again.
        aux = svc._carry[7]
        assert int(jnp.sum(np.asarray(aux["refcount"]) > 0)) == 0


@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
def test_mid_run_admission_matches_fresh_batch(tiny_lm, paged):
    """A request spliced into a recycled row mid-while_loop must reach the
    same search as a fresh batch seeded with the same key: same action and
    (bit-exact here) the same root visit mass.  This is the engine-parity
    acceptance gate — admission fully re-seeds the row (tree, RNG lane,
    evaluator slot caches), so history cannot bleed into the new search."""
    cfg, params = tiny_lm
    keys = [jax.random.fold_in(jax.random.PRNGKey(42), i) for i in range(4)]
    svc = _service(tiny_lm, paged)
    rows = svc.serve(PROMPTS[:4], keys=keys)  # requests 2,3 admitted mid-run

    oracle = _service(tiny_lm, paged)
    res = oracle._search(oracle._roots(PROMPTS[2:4]), jnp.stack(keys[2:4]))
    for i, b in ((2, 0), (3, 1)):
        fresh = jax.tree.map(lambda x: x[b], res)
        assert int(rows[i].action) == int(fresh.action)
        np.testing.assert_allclose(
            np.asarray(rows[i].root_n), np.asarray(fresh.root_n), atol=1e-6
        )


def test_submit_poll_drain_incremental(tiny_lm):
    """The lower-level API: submit returns ids, poll makes progress,
    results accumulate, and late submissions reuse settled rows."""
    svc = _service(tiny_lm, paged=False)
    ids = [svc.submit(p) for p in PROMPTS[:3]]
    assert ids == [0, 1, 2]
    res = svc.drain()
    assert set(res) == {0, 1, 2}
    # The engine persists: another wave drains into the same carry.
    more = [svc.submit(p) for p in PROMPTS[3:]]
    res = svc.drain()
    assert set(res) == set(ids) | set(more)
    assert svc.stats.completed == len(PROMPTS)


def test_continuous_serving_needs_async_engine(tiny_lm):
    cfg, params = tiny_lm
    svc = SearchService(
        cfg, params,
        SearchSpec(algo="wu_uct", engine="wave", batch=2, num_simulations=4,
                   wave_size=2, max_depth=3, max_sim_steps=3, max_width=4,
                   gamma=1.0),
        top_k=4, max_len=12, eos_token=1,
    )
    svc.submit([3, 5])
    with pytest.raises(ValueError, match="async"):
        svc.drain()


# ---------------------------------------------------------------------------
# Satellite: over-long prompts rejected with a named error
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
def test_serving_engine_rejects_over_long_prompt(paged):
    cfg = get_reduced("llama3-8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(
        cfg, params,
        ServeConfig(batch_slots=2, max_len=8, eos_token=1,
                    paged=paged, block_size=4),
    )
    # len == max_len is already too long: the slot must fit the prompt PLUS
    # at least one generated token.
    with pytest.raises(PromptTooLongError, match="max_len"):
        engine.add_requests([[2, 3], list(range(2, 10))])
    # The batch was rejected atomically — no slot was consumed.
    assert not engine.active.any()
    if paged:
        assert engine.blocks_in_use() == 0
    with pytest.raises(ValueError, match="empty"):
        engine.add_requests([[]])
    # In-range prompts still admit afterwards.
    assert engine.add_requests([[2, 3, 4]]) == [0]


def test_search_service_rejects_over_long_prompt(tiny_lm):
    svc = _service(tiny_lm, paged=False)  # max_len=12
    with pytest.raises(PromptTooLongError):
        svc.submit(list(range(2, 14)))
    with pytest.raises(PromptTooLongError):
        svc.search([list(range(2, 14))], jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# Satellite: ServingEngine.run slot reuse under request pressure
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
def test_serving_engine_run_reuses_slots(paged):
    """R > batch_slots: freed slots serve later requests, and every
    request's output matches a solo single-slot run of the same prompt
    (greedy decode is deterministic, so any cross-wiring or dropped
    request shows up as a mismatch)."""
    cfg = get_reduced("llama3-8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    sc = ServeConfig(batch_slots=2, max_len=24, eos_token=1,
                     paged=paged, block_size=4)
    rng = np.random.default_rng(3)
    prompts = [
        list(rng.integers(2, cfg.vocab_size, size=n)) for n in (4, 7, 5, 6, 3)
    ]
    engine = ServingEngine(cfg, params, sc)
    outs = engine.run(prompts, max_ticks=200)
    assert all(len(o) > 0 for o in outs)
    for prompt, out in zip(prompts, outs):
        solo = ServingEngine(
            cfg, params, dataclasses.replace(sc, batch_slots=1)
        )
        (ref,) = solo.run([prompt], max_ticks=200)
        assert out == ref
    if paged:
        # Zero leaked pages once every request has finished.
        assert engine.blocks_in_use() == 0
        assert (engine._table == engine.num_blocks).all()


# ---------------------------------------------------------------------------
# Satellite: decide surfaces invalid actions instead of clipping
# ---------------------------------------------------------------------------
def test_decide_surfaces_invalid_action(tiny_lm, monkeypatch):
    svc = _service(tiny_lm, paged=False)
    real = svc._search

    def bad_search(roots, rngs):
        res = real(roots, rngs)
        return res._replace(action=jnp.full_like(res.action, -1))

    monkeypatch.setattr(svc, "_search", bad_search)
    with pytest.raises(InvalidSearchActionError, match="-1"):
        svc.decide([[3, 5]], jax.random.PRNGKey(0))


def test_decide_ignores_padding_rows(tiny_lm, monkeypatch):
    """Out-of-range actions on PADDING rows (beyond the request count)
    must not trip the validation — only real requests are checked."""
    svc = _service(tiny_lm, paged=False)
    real = svc._search

    def pad_bad_search(roots, rngs):
        res = real(roots, rngs)
        return res._replace(action=res.action.at[-1].set(-1))

    monkeypatch.setattr(svc, "_search", pad_bad_search)
    tokens, _ = svc.decide([[3, 5]], jax.random.PRNGKey(0))
    assert len(tokens) == 1


# ---------------------------------------------------------------------------
# Satellite: benchmark-baseline lookup (env override + fallback warning)
# ---------------------------------------------------------------------------
def test_pool_blocks_env_override(tmp_path, monkeypatch):
    from repro.serving import search_service as ss

    base = tmp_path / "BENCH_model_eval.json"
    base.write_text(json.dumps({"rows": [
        {"kind": "batch_ceiling", "ceiling_ratio": 2.0},
        {"kind": "batch_ceiling", "ceiling_ratio": 4.0},
    ]}))
    monkeypatch.setenv(ss.BENCH_BASELINE_ENV, str(base))
    assert ss._bench_baseline_path() == base
    # dense = 4 slots * 4 pages = 16; worst ratio 2.0 -> 16/2*1.25+1 = 11.
    assert ss._prefix_sharing_pool_blocks(4, 32, 8) == 11


def test_pool_blocks_falls_back_with_warning(tmp_path, monkeypatch):
    from repro.serving import search_service as ss

    base = tmp_path / "BENCH_model_eval.json"
    base.write_text(json.dumps({"rows": [{"kind": "other"}]}))
    monkeypatch.setenv(ss.BENCH_BASELINE_ENV, str(base))
    monkeypatch.setattr(ss, "_pool_fallback_warned", False)
    with pytest.warns(UserWarning, match="batch_ceiling"):
        assert ss._prefix_sharing_pool_blocks(4, 32, 8) == 16
    # Warn-once: the second fallback is silent.
    import warnings as _w

    with _w.catch_warnings():
        _w.simplefilter("error")
        assert ss._prefix_sharing_pool_blocks(4, 32, 8) == 16


def test_pool_blocks_unparseable_baseline_warns(tmp_path, monkeypatch):
    from repro.serving import search_service as ss

    base = tmp_path / "BENCH_model_eval.json"
    base.write_text("{not json")
    monkeypatch.setenv(ss.BENCH_BASELINE_ENV, str(base))
    with pytest.warns(UserWarning, match="could not parse"):
        assert ss._prefix_sharing_pool_blocks(4, 32, 8) == 16


# ---------------------------------------------------------------------------
# Satellite: trace-mode occupancy counters
# ---------------------------------------------------------------------------
def test_trace_occupancy_counters():
    env = make_bandit_tree(depth=3, num_actions=3, seed=7)
    cfg = SearchConfig(
        num_simulations=8, wave_size=3, max_depth=5, max_sim_steps=4,
        max_width=3, gamma=0.95, policy=PolicyConfig(kind="wu_uct"),
        stat_mode="wu",
    )
    B, K = 3, 60
    roots = jax.vmap(env.init)(jax.random.split(jax.random.PRNGKey(0), B))
    rngs = jax.random.split(jax.random.PRNGKey(1), B)
    fn = jax.jit(functools.partial(
        run_async_search_batched, env, cfg, trace_ticks=K
    ))
    _, trace = fn(roots, rngs)
    busy = np.asarray(trace.busy_slots)
    active = np.asarray(trace.active_trees)
    alive = np.asarray(trace.alive)
    assert busy.shape == (K, B) and active.shape == (K,)
    assert (busy >= 0).all() and (busy <= cfg.wave_size).all()
    # Settled trees count zero busy slots; active_trees is the alive count.
    assert (busy[~alive] == 0).all()
    np.testing.assert_array_equal(active, alive.sum(axis=1))
    # The engine actually worked: some tick had every tree busy.
    assert busy.sum() > 0


# ---------------------------------------------------------------------------
# Device-resident serving ring: the fused poll round
# ---------------------------------------------------------------------------
def _frontier_evaluator(tiny_lm, paged):
    from repro.core.evaluators import (
        FrontierModelEvaluator,
        PagedFrontierModelEvaluator,
    )

    cfg, params = tiny_lm
    if paged:
        return PagedFrontierModelEvaluator(
            cfg, params, top_k=4, eos_token=1, block_size=4, num_blocks=48,
        )
    return FrontierModelEvaluator(cfg, params, top_k=4, eos_token=1)


@pytest.mark.parametrize(
    "mode", ["dense", "paged", "frontier", "paged_frontier"]
)
def test_fused_ring_matches_host_paced_poll(tiny_lm, mode):
    """Every request served through the device-resident loop is
    bit-identical to the PR 8 host-paced poll path.

    Both paths fully re-seed a row at admission (tree, RNG lane, evaluator
    aux) and every per-row computation is row-independent, so WHEN a row
    was admitted relative to the others must not matter — in-loop ring
    admission included.  Dense, paged, and both frontier evaluators.
    """
    paged = mode in ("paged", "paged_frontier")
    kw = {}
    if "frontier" in mode:
        kw["evaluator"] = _frontier_evaluator(tiny_lm, paged)
    keys = [
        jax.random.fold_in(jax.random.PRNGKey(11), i)
        for i in range(len(PROMPTS))
    ]
    rows_fused = _service(tiny_lm, paged, fused=True, **kw).serve(
        PROMPTS, keys=keys
    )
    rows_host = _service(tiny_lm, paged, fused=False, **kw).serve(
        PROMPTS, keys=keys
    )
    for rf, rh in zip(rows_fused, rows_host):
        assert int(rf.action) == int(rh.action)
        np.testing.assert_array_equal(
            np.asarray(rf.root_n), np.asarray(rh.root_n)
        )
        np.testing.assert_allclose(
            np.asarray(rf.root_v), np.asarray(rh.root_v), atol=1e-6
        )
        assert int(rf.ticks) == int(rh.ticks)


def test_ring_churn_zero_leaked_pages(tiny_lm):
    """2x the prompt set through B=2 rows and a 3-slot ring: every pool
    page staged by the ring or held by a slot is back (refcount zero), no
    allocation ever failed, and every page table dropped to the sentinel."""
    svc = _service(tiny_lm, True, ring_capacity=3)
    prompts = PROMPTS + PROMPTS
    rows = svc.serve(prompts)
    assert len(rows) == len(prompts)
    assert svc.stats.completed == len(prompts)
    aux = svc._carry[7]
    p = svc.evaluator.num_blocks
    assert int(jnp.sum(aux["refcount"])) == 0
    assert int(aux["oom"]) == 0
    assert bool(jnp.all(aux["table"] == p))
    assert bool(jnp.all(svc._ring.aux["table"] == p))
    assert bool(jnp.all(svc._ring.aux["len"] == 0))
    assert int(svc._ring.count) == 0
    # The fused path really ran: admissions all flowed through the ring.
    assert svc.stats.admissions == len(prompts)
    assert svc.stats.ring_occupancy > 0.0


@pytest.mark.parametrize("fused", [True, False], ids=["fused", "host"])
def test_priority_orders_admission(tiny_lm, fused):
    """submit(priority=...) admits higher priorities first, FIFO within a
    priority class — on both the ring staging and host-paced admission
    paths.  B=1 serializes requests, so completion order IS admission
    order."""
    cfg, params = tiny_lm
    svc = SearchService(
        cfg, params, _spec(batch=1), top_k=4, max_len=12, eos_token=1,
        ticks_per_round=4, fused=fused,
    )
    for i, pri in enumerate([0, 5, 1, 5]):
        svc.submit(PROMPTS[i], priority=pri)
    svc.drain()
    # ids 1 and 3 share the top priority (FIFO between them), then 2, then 0.
    assert list(svc._results.keys()) == [1, 3, 2, 0]
