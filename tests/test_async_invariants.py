"""Unobserved-sample conservation invariants for the async-slot engines.

The paper's central bookkeeping claim (Sec. 3.1, Algorithms 2–3): ``O_s``
counts exactly the rollouts that have been *initiated but not completed* in
the subtree under ``s``.  Both async engines are run in trace mode (a
fixed-length scan that snapshots the tree after every master tick) and the
invariant is checked against ground truth reconstructed from the slot table:

* at every master tick, for **every** node ``s``, ``O_s`` equals the number
  of busy slots whose charged node's root-path passes through ``s`` (the
  root case: total in-flight mass equals the number of busy slots);
* at termination all ``O_s`` have returned to zero (every incomplete update
  was settled by exactly one complete update).

Property-based via hypothesis when installed (CI installs it); otherwise a
fixed seeded case sweep keeps the same checker running in minimal
environments.
"""

import functools

import jax
import numpy as np
import pytest

from repro.core import PolicyConfig, SearchConfig
from repro.core.async_search import run_async_search
from repro.core.batched_async_search import run_async_search_batched
from repro.core.async_search import FREE
from repro.envs import make_bandit_tree

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # minimal env: deterministic sweep below still runs
    HAVE_HYPOTHESIS = False


def _make(depth, actions, T, W, sim_steps, seed):
    env = make_bandit_tree(depth=depth, num_actions=actions, seed=seed)
    cfg = SearchConfig(
        num_simulations=T,
        wave_size=W,
        max_depth=depth + 2,
        max_sim_steps=sim_steps,
        max_width=actions,
        gamma=0.95,
        policy=PolicyConfig(kind="wu_uct"),
        stat_mode="wu",
    )
    return env, cfg


def _trace_bound(cfg) -> int:
    # Worst case is fully serial: every simulation pays one expansion tick
    # plus max_sim_steps rollout ticks before settling.
    return cfg.num_simulations * (cfg.max_sim_steps + 2) + 2


def _check_trace(trace, T, W):
    """Verify O_s conservation on a [K, B, ...] trace (ground truth: walk
    every busy slot's charged node to the root through that tick's parent
    snapshot)."""
    O = np.asarray(trace.O)
    parent = np.asarray(trace.parent)
    kind = np.asarray(trace.kind)
    sim_node = np.asarray(trace.sim_node)
    t_done = np.asarray(trace.t_done)
    K, B, M = O.shape

    assert (t_done[-1] == T).all(), (
        f"trace bound too small: t_done={t_done[-1]} != {T}"
    )
    for b in range(B):
        for k in range(K):
            counts = np.zeros(M, np.float32)
            for w in range(W):
                if kind[k, b, w] == FREE:
                    continue
                n = sim_node[k, b, w]
                while n >= 0:
                    counts[n] += 1.0
                    n = parent[k, b, n]
            np.testing.assert_array_equal(
                O[k, b], counts,
                err_msg=f"O_s != busy-slot subtree count (tree {b}, tick {k})",
            )
        # Termination: every incomplete update was settled exactly once.
        assert (O[-1, b] == 0).all(), f"O mass leaked at termination (tree {b})"


def _run_single(depth, actions, T, W, sim_steps, seed):
    env, cfg = _make(depth, actions, T, W, sim_steps, seed)
    root = env.init(jax.random.PRNGKey(seed))
    fn = jax.jit(
        functools.partial(
            run_async_search, env, cfg, trace_ticks=_trace_bound(cfg)
        )
    )
    res, trace = fn(root, jax.random.PRNGKey(seed + 1))
    # Single-engine trace is [K, ...]; give it a B=1 axis for the checker.
    trace = jax.tree.map(lambda x: np.asarray(x)[:, None], trace)
    _check_trace(trace, T, W)
    assert float(np.asarray(res.max_o)) <= W


def _run_batched(B, depth, actions, T, W, sim_steps, seed):
    env, cfg = _make(depth, actions, T, W, sim_steps, seed)
    roots = jax.vmap(env.init)(jax.random.split(jax.random.PRNGKey(seed), B))
    rngs = jax.random.split(jax.random.PRNGKey(seed + 1), B)
    fn = jax.jit(
        functools.partial(
            run_async_search_batched, env, cfg, trace_ticks=_trace_bound(cfg)
        )
    )
    res, trace = fn(roots, rngs)
    _check_trace(trace, T, W)
    assert (np.asarray(res.max_o) <= W).all()


# Fixed draws exercising the corners: W=1 (serial), W≥T (slot surplus),
# branching narrower/wider than the slot count, terminal-dense shallow trees.
CASES = [
    (3, 3, 12, 3, 4, 0),
    (4, 2, 16, 5, 3, 1),
    (2, 4, 8, 1, 6, 2),
    (2, 2, 6, 8, 2, 3),
]


@pytest.mark.parametrize("depth,actions,T,W,sim_steps,seed", CASES)
def test_single_async_o_conservation(depth, actions, T, W, sim_steps, seed):
    _run_single(depth, actions, T, W, sim_steps, seed)


@pytest.mark.parametrize("depth,actions,T,W,sim_steps,seed", CASES[:2])
@pytest.mark.parametrize("B", [1, 3])
def test_batched_async_o_conservation(B, depth, actions, T, W, sim_steps, seed):
    _run_batched(B, depth, actions, T, W, sim_steps, seed)


if HAVE_HYPOTHESIS:
    _params = dict(
        depth=st.integers(2, 4),
        actions=st.integers(2, 4),
        T=st.integers(4, 20),
        W=st.integers(1, 6),
        sim_steps=st.integers(2, 5),
        seed=st.integers(0, 2**16),
    )
    _prop = settings(
        max_examples=8,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
    )

    @_prop
    @given(**_params)
    def test_single_async_o_conservation_property(
        depth, actions, T, W, sim_steps, seed
    ):
        _run_single(depth, actions, T, W, sim_steps, seed)

    @_prop
    @given(B=st.integers(1, 4), **_params)
    def test_batched_async_o_conservation_property(
        B, depth, actions, T, W, sim_steps, seed
    ):
        _run_batched(B, depth, actions, T, W, sim_steps, seed)
