"""Equivalence of the JAX wave engine (W=1) against the pure-Python oracle.

With a deterministic setting (always-expand coin, first-untried expansion,
deterministic rollout policy) the sequential JAX search and the reference
implementation must produce *identical* trees — node-for-node statistics.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SearchConfig, PolicyConfig
from repro.core.wu_uct import run_search
from repro.core.ref_mcts import RefMCTS
from repro.envs import make_bandit_tree


class _PyBanditEnv:
    """Python-side wrapper delegating to the (deterministic) JAX env."""

    def __init__(self, env):
        self.env = env
        self.num_actions = env.num_actions
        self._step = jax.jit(env.step)

    def step(self, state, action):
        s, r, d = self._step(state, jnp.int32(action))
        return jax.device_get(s), float(r), bool(d)


@pytest.mark.parametrize("kind", ["uct", "wu_uct"])
@pytest.mark.parametrize("num_sims", [16, 64])
def test_sequential_matches_oracle(kind, num_sims):
    depth, num_actions, gamma = 4, 3, 0.9
    env = make_bandit_tree(depth=depth, num_actions=num_actions, seed=7)

    cfg = SearchConfig(
        num_simulations=num_sims,
        wave_size=1,
        max_depth=depth + 1,
        max_sim_steps=depth + 1,
        max_width=num_actions,
        gamma=gamma,
        policy=PolicyConfig(kind=kind, beta=1.0),
        stat_mode="wu" if kind == "wu_uct" else "none",
        expand_coin=1.0,              # always stop at a not-fully-expanded node
        deterministic_expansion=True,  # first untried action
    )
    # Deterministic rollout: always action 0.
    det_env = env.__class__(
        name=env.name,
        num_actions=env.num_actions,
        init=env.init,
        step=env.step,
        rollout_policy=lambda k, s: jnp.int32(0),
        value_fn=None,
        observe=env.observe,
    )

    key = jax.random.PRNGKey(0)
    root_state = env.init(key)
    res = jax.jit(lambda s, k: run_search(det_env, cfg, s, k))(root_state, key)

    # --- oracle ---
    py_env = _PyBanditEnv(env)
    oracle = RefMCTS(
        py_env,
        beta=1.0,
        gamma=gamma,
        max_depth=depth + 1,
        max_width=num_actions,
        use_o=(kind == "wu_uct"),
    )
    root = oracle.search(
        jax.device_get(root_state),
        num_sims,
        coin_fn=lambda: True,
        expand_fn=lambda node: min(
            a for a in range(num_actions) if a not in node.children
        ),
        policy_fn=lambda s: 0,
        max_sim_steps=depth + 1,
    )

    ref_n = np.zeros(num_actions)
    ref_v = np.full(num_actions, -np.inf)
    for a, c in root.children.items():
        ref_n[a] = c.N
        ref_v[a] = c.V

    np.testing.assert_allclose(np.asarray(res.root_n), ref_n, rtol=1e-5)
    mask = np.isfinite(ref_v)
    np.testing.assert_allclose(
        np.asarray(res.root_v)[mask], ref_v[mask], rtol=2e-5, atol=1e-5
    )
    assert int(res.action) == RefMCTS.best_action(root)


def test_wu_uct_eq4_reduces_to_eq2_when_o_zero():
    """With O==0 everywhere, eq. (4) == eq. (2) by construction."""
    from repro.core.policies import child_scores
    from repro.core import init_tree

    env = make_bandit_tree(depth=3, num_actions=4, seed=1)
    key = jax.random.PRNGKey(0)
    tree = init_tree(env.init(key), capacity=16, num_actions=4)
    # Fabricate visited children of the root.
    tree = tree._replace(
        children=tree.children.at[0].set(jnp.array([1, 2, 3, 4])),
        parent=tree.parent.at[1:5].set(0),
        N=tree.N.at[0].set(10.0).at[1:5].set(jnp.array([4.0, 3.0, 2.0, 1.0])),
        V=tree.V.at[1:5].set(jnp.array([0.5, 0.2, 0.9, 0.1])),
        size=jnp.int32(5),
    )
    s_wu = child_scores(tree, jnp.int32(0), PolicyConfig(kind="wu_uct", beta=1.0))
    s_uct = child_scores(tree, jnp.int32(0), PolicyConfig(kind="uct", beta=1.0))
    np.testing.assert_allclose(np.asarray(s_wu), np.asarray(s_uct), rtol=1e-6)
