"""CachedModelEvaluator: decode-cache correctness against the full forward.

Three claim families (ISSUE 5 satellite):

* **logits parity** — the logits a slot sees from its KV-cached
  ``decode_step`` chain equal (fp tolerance) the full-prefix ``forward`` the
  uncached :class:`~repro.core.evaluators.ModelEvaluator` runs, across
  ragged slot depths and after every tick of a chain;
* **prefix-rollback refill** — re-syncing a slot cache onto a new tree path
  via :meth:`refill_aux` (roll ``len`` back to the common prefix, decode
  the divergent suffix) is equivalent to a fresh re-prefill of that path,
  and decodes only the divergent suffix;
* **cache-depth invariant** — inside the real async engines (trace mode),
  every busy slot's ``cache['len']`` equals its token prefix length at
  every master tick, across settle/refill.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import (
    CachedModelEvaluator,
    ModelEvaluator,
    SearchSpec,
    build_searcher,
)
from repro.core.evaluators import FREE, SIM
from repro.envs.token_env import TokenEnvState, make_token_env
from repro.models import init_params

TOL = dict(rtol=2e-4, atol=2e-4)


@pytest.fixture(scope="module")
def lm():
    cfg = dataclasses.replace(
        get_reduced("llama3-8b"), vocab_size=64, num_layers=2,
        d_model=32, num_heads=2, num_kv_heads=1, head_dim=16, d_ff=64,
    )
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


def _ragged_states(max_len=16, lengths=(3, 5, 9), seed=7) -> TokenEnvState:
    n = len(lengths)
    toks = jax.random.randint(
        jax.random.PRNGKey(seed), (n, max_len), 2, 60, jnp.int32
    )
    pos = jnp.arange(max_len)
    lengths = jnp.asarray(lengths, jnp.int32)
    return TokenEnvState(
        tokens=jnp.where(pos[None, :] < lengths[:, None], toks, 0),
        length=lengths,
        done=jnp.zeros((n,), jnp.bool_),
    )


def _scfg():
    return SearchSpec(gamma=1.0, max_sim_steps=8).config


# ---------------------------------------------------------------------------
# Logits parity: decode_step chain vs full-prefix forward.
# ---------------------------------------------------------------------------


def test_init_aux_logits_match_full_forward(lm):
    cfg, params = lm
    ev_c = CachedModelEvaluator(cfg, params, top_k=4, eos_token=1)
    ev_u = ModelEvaluator(cfg, params, top_k=4, eos_token=1)
    state = _ragged_states()
    aux = ev_c.init_aux(state, (state.length.shape[0], 1))
    full = ev_u._position_logits(params, cfg, state.tokens, state.length)
    np.testing.assert_allclose(
        np.asarray(aux["pol"]["logits"], np.float32),
        np.asarray(full, np.float32), **TOL,
    )
    np.testing.assert_array_equal(np.asarray(aux["len"]), np.asarray(state.length))


def test_tick_chain_matches_uncached_evaluator(lm):
    """Chain SIM ticks: cached and uncached evaluators must produce the same
    transitions (same sampled tokens given the same keys — their logits agree
    to fp tolerance) and the cached logits must track the full forward."""
    cfg, params = lm
    ev_c = CachedModelEvaluator(cfg, params, top_k=4, eos_token=1)
    ev_u = ModelEvaluator(cfg, params, top_k=4, eos_token=1)
    scfg = _scfg()

    state_c = state_u = _ragged_states()
    n = state_c.length.shape[0]
    aux = ev_c.init_aux(state_c, (n, 1))
    kind = jnp.full((n,), SIM, jnp.int32)
    act = jnp.zeros((n,), jnp.int32)
    def carry0():
        return dict(
            rollout_done=jnp.zeros((n,), jnp.bool_),
            acc=jnp.zeros((n,), jnp.float32),
            disc=jnp.ones((n,), jnp.float32),
            steps=jnp.zeros((n,), jnp.int32),
        )

    cc, cu = carry0(), carry0()
    for step in range(4):
        keys = jax.random.split(jax.random.PRNGKey(step), n)
        (state_c, r_c, d_c, acc, disc, stp, rdone), aux = ev_c.tick(
            scfg, kind, act, state_c, cc["rollout_done"], cc["acc"],
            cc["disc"], cc["steps"], keys, aux,
        )
        cc = dict(rollout_done=rdone, acc=acc, disc=disc, steps=stp)
        (state_u, r_u, d_u, acc, disc, stp, rdone), _ = ev_u.tick(
            scfg, kind, act, state_u, cu["rollout_done"], cu["acc"],
            cu["disc"], cu["steps"], keys,
        )
        cu = dict(rollout_done=rdone, acc=acc, disc=disc, steps=stp)
        np.testing.assert_array_equal(
            np.asarray(state_c.tokens), np.asarray(state_u.tokens),
            err_msg=f"step {step}: cached/uncached sampled different tokens",
        )
        np.testing.assert_allclose(
            np.asarray(r_c, np.float32), np.asarray(r_u, np.float32), **TOL
        )
        # The stored logits equal the full-prefix forward at the new state.
        full = ev_u._position_logits(
            params, cfg, state_c.tokens, state_c.length
        )
        live = ~np.asarray(state_c.done)
        np.testing.assert_allclose(
            np.asarray(aux["pol"]["logits"], np.float32)[live],
            np.asarray(full, np.float32)[live], **TOL,
        )
        np.testing.assert_array_equal(
            np.asarray(aux["len"])[live], np.asarray(state_c.length)[live]
        )


def test_distinct_reward_model_cached(lm):
    """A distinct reward model rides a second cache; rewards must match the
    uncached evaluator's full-forward reward logits."""
    cfg, params = lm
    rew_params = init_params(cfg, jax.random.PRNGKey(9))
    ev_c = CachedModelEvaluator(
        cfg, params, top_k=4, eos_token=1, reward_params=rew_params
    )
    ev_u = ModelEvaluator(
        cfg, params, top_k=4, eos_token=1, reward_params=rew_params
    )
    scfg = _scfg()
    state = _ragged_states()
    n = state.length.shape[0]
    aux = ev_c.init_aux(state, (n, 1))
    kind = jnp.full((n,), SIM, jnp.int32)
    keys = jax.random.split(jax.random.PRNGKey(3), n)
    common = (jnp.zeros((n,), jnp.bool_), jnp.zeros((n,), jnp.float32),
              jnp.ones((n,), jnp.float32), jnp.zeros((n,), jnp.int32))
    (st_c, r_c, *_), aux = ev_c.tick(
        scfg, kind, jnp.zeros((n,), jnp.int32), state, *common, keys, aux
    )
    (st_u, r_u, *_), _ = ev_u.tick(
        scfg, kind, jnp.zeros((n,), jnp.int32), state, *common, keys
    )
    np.testing.assert_array_equal(np.asarray(st_c.tokens), np.asarray(st_u.tokens))
    np.testing.assert_allclose(
        np.asarray(r_c, np.float32), np.asarray(r_u, np.float32), **TOL
    )


# ---------------------------------------------------------------------------
# Prefix-rollback refill.
# ---------------------------------------------------------------------------


def _run_sim_ticks(ev, scfg, state, aux, steps, seed=11):
    n = state.length.shape[0]
    kind = jnp.full((n,), SIM, jnp.int32)
    rdone = jnp.zeros((n,), jnp.bool_)
    acc = jnp.zeros((n,), jnp.float32)
    disc = jnp.ones((n,), jnp.float32)
    stp = jnp.zeros((n,), jnp.int32)
    for s in range(steps):
        keys = jax.random.split(jax.random.PRNGKey(seed + s), n)
        (state, _, _, acc, disc, stp, rdone), aux = ev.tick(
            scfg, kind, jnp.zeros((n,), jnp.int32), state, rdone, acc, disc,
            stp, keys, aux,
        )
    return state, aux


def test_refill_rollback_matches_fresh_prefill(lm):
    """Roll a deep cache back onto a shallower divergent path: the result
    must equal a fresh init_aux at that path (logits + len)."""
    cfg, params = lm
    ev = CachedModelEvaluator(cfg, params, top_k=4, eos_token=1)
    scfg = _scfg()
    start = _ragged_states(lengths=(4, 4, 4))
    n = 3
    state, aux = _run_sim_ticks(ev, scfg, start, ev.init_aux(start, (n, 1)), 5)

    # New paths: row 0 shares prefix 4 + diverges after 2 rollout tokens;
    # row 1 rolls clean back to the prompt; row 2 a disjoint path (the
    # re-prefill fallback).
    new_tokens = np.asarray(state.tokens).copy()
    new_len = np.asarray([6, 4, 5])
    new_tokens[0, 6:] = 0
    new_tokens[1, 4:] = 0
    new_tokens[2] = 0
    new_tokens[2, :5] = [7, 11, 13, 17, 19]
    new_state = TokenEnvState(
        tokens=jnp.asarray(new_tokens, jnp.int32),
        length=jnp.asarray(new_len, jnp.int32),
        done=jnp.zeros((n,), jnp.bool_),
    )
    rows = jnp.arange(n)
    aux2, _ = ev.refill_aux(scfg, aux, rows, new_state, jnp.ones((n,), jnp.bool_))
    fresh = ev.init_aux(new_state, (n, 1))
    np.testing.assert_array_equal(np.asarray(aux2["len"]), new_len)
    np.testing.assert_allclose(
        np.asarray(aux2["pol"]["logits"], np.float32),
        np.asarray(fresh["pol"]["logits"], np.float32), **TOL,
    )
    # The caches agree wherever rows are valid (< len): decode from both.
    nxt = jnp.asarray([21, 23, 25], jnp.int32)
    l1, _ = ev.decode_fn(params, cfg, nxt, dict(aux2["pol"]["cache"], len=aux2["len"]))
    l2, _ = ev.decode_fn(params, cfg, nxt, dict(fresh["pol"]["cache"], len=fresh["len"]))
    np.testing.assert_allclose(
        np.asarray(l1, np.float32), np.asarray(l2, np.float32), **TOL
    )


@pytest.mark.parametrize("refill_chunk,expect_calls", [(1, 2), (2, 1), (8, 1)])
def test_refill_catches_up_in_chunks(lm, refill_chunk, expect_calls):
    """The rollback catch-up runs ceil(max divergence / refill_chunk)
    batched ``decode_chunk`` calls (counted with a traced callback) — one
    dispatch per chunk, not one per divergent token, and never the full
    re-prefill."""
    cfg, params = lm
    calls = []
    from repro.models import decode_chunk

    def counting_chunk(p, c, t, target, cache):
        jax.debug.callback(lambda: calls.append(1))
        return decode_chunk(p, c, t, target, cache)

    ev = CachedModelEvaluator(
        cfg, params, top_k=4, eos_token=1,
        chunk_fn=counting_chunk, refill_chunk=refill_chunk,
    )
    scfg = _scfg()
    start = _ragged_states(lengths=(10, 10))
    aux = ev.init_aux(start, (2, 1))
    # Row 0: same path, one token shorter (the settle→parent refill shape):
    # only the final prompt token re-decodes.  Row 1: diverges at position 7
    # → max divergence 2 tokens.
    new_tokens = np.asarray(start.tokens).copy()
    new_tokens[0, 9:] = 0
    new_tokens[1, 7] = 61
    new_tokens[1, 9:] = 0
    new_state = TokenEnvState(
        tokens=jnp.asarray(new_tokens, jnp.int32),
        length=jnp.asarray([9, 9], jnp.int32),
        done=jnp.zeros((2,), jnp.bool_),
    )
    calls.clear()
    aux2, _ = ev.refill_aux(
        scfg, aux, jnp.arange(2), new_state, jnp.ones((2,), jnp.bool_)
    )
    jax.effects_barrier()
    assert len(calls) == expect_calls, len(calls)
    np.testing.assert_array_equal(np.asarray(aux2["len"]), [9, 9])
    # The chunked catch-up lands on the same logits a fresh prefill gives.
    fresh = ev.init_aux(new_state, (2, 1))
    np.testing.assert_allclose(
        np.asarray(aux2["pol"]["logits"], np.float32),
        np.asarray(fresh["pol"]["logits"], np.float32), **TOL,
    )


# ---------------------------------------------------------------------------
# Engine integration: cache depth tracks slot depth across settle/refill.
# ---------------------------------------------------------------------------


def _token_search_pieces(lm, max_len=14, top_k=4):
    cfg, params = lm
    env = make_token_env(
        cfg, params, jnp.asarray([3, 5, 7], jnp.int32), max_len=max_len,
        top_k=top_k, eos_token=1,
    )
    ev = CachedModelEvaluator(cfg, params, top_k=top_k, eos_token=1)
    return env, ev


@pytest.mark.parametrize("batch", [0, 3])
def test_cache_len_tracks_slot_depth_under_trace(lm, batch):
    """ISSUE invariant: at every master tick, every busy slot of every
    still-running tree has cache['len'] == its token prefix length — the
    settle/refill rollback machinery never desyncs cache and state."""
    from repro.core.async_search import run_async_search
    from repro.core.batched_async_search import run_async_search_batched

    env, ev = _token_search_pieces(lm)
    spec = SearchSpec(
        algo="wu_uct", engine="async", batch=batch, num_simulations=10,
        wave_size=3, max_depth=5, max_sim_steps=5, max_width=4, gamma=1.0,
    )
    cfg = spec.config
    T = cfg.num_simulations
    trace_bound = 4 * T  # generous static bound
    key = jax.random.PRNGKey(0)
    if batch:
        roots = jax.vmap(env.init)(jax.random.split(key, batch))
        rngs = jax.random.split(jax.random.PRNGKey(1), batch)
        fn = jax.jit(functools.partial(
            run_async_search_batched, env, cfg, trace_ticks=trace_bound,
            evaluator=ev,
        ))
        res, trace = fn(roots, rngs)
        t_done = np.asarray(trace.t_done)            # [K, B]
    else:
        fn = jax.jit(functools.partial(
            run_async_search, env, cfg, trace_ticks=trace_bound, evaluator=ev,
        ))
        res, trace = fn(env.init(key), key)
        t_done = np.asarray(trace.t_done)[:, None]   # [K, 1]

    kind = np.asarray(trace.kind).reshape(t_done.shape[0], t_done.shape[1], -1)
    state_len = np.asarray(trace.state_len).reshape(kind.shape)
    cache_len = np.asarray(trace.cache_len).reshape(kind.shape)
    # alive is [K] for the single engine, [K, B] (per-tree) for the batched.
    alive = np.asarray(trace.alive).reshape(t_done.shape[0], -1)

    assert alive.any() and not alive.all(), "trace bound too tight"
    checked = 0
    for k in range(kind.shape[0]):
        if not alive[k].any():
            break
        for b in range(kind.shape[1]):
            if not alive[k, b % alive.shape[1]] or t_done[k, b] >= T:
                # This tree finished: its slots are frozen while the shared
                # aux keeps ticking, so the invariant only binds live trees.
                continue
            busy = kind[k, b] != FREE
            np.testing.assert_array_equal(
                cache_len[k, b][busy], state_len[k, b][busy],
                err_msg=f"tick {k} tree {b}: cache len != slot prefix len",
            )
            checked += busy.sum()
    assert checked > 0


def test_cached_search_one_prefill_then_decodes_only(lm):
    """The headline claim: after the single root prefill, the whole search
    runs on decode steps — the full-prefix forward is never entered."""
    cfg, params = lm
    from repro.models import decode_step, prefill_ragged

    prefills, decodes = [], []

    def counting_prefill(p, c, t, l, cache):
        jax.debug.callback(lambda: prefills.append(1))
        return prefill_ragged(p, c, t, l, cache)

    def counting_decode(p, c, t, cache):
        jax.debug.callback(lambda: decodes.append(1))
        return decode_step(p, c, t, cache)

    env = make_token_env(
        cfg, params, jnp.asarray([3, 5, 7], jnp.int32), max_len=14,
        top_k=4, eos_token=1,
    )
    ev = CachedModelEvaluator(
        cfg, params, top_k=4, eos_token=1,
        decode_fn=counting_decode, prefill_fn=counting_prefill,
    )
    spec = SearchSpec(
        algo="wu_uct", engine="async", num_simulations=10, wave_size=3,
        max_depth=5, max_sim_steps=5, max_width=4, gamma=1.0,
    )
    search = build_searcher(env, spec, evaluator=ev)
    key = jax.random.PRNGKey(0)
    res = jax.block_until_ready(search(env.init(key), key))
    jax.effects_barrier()
    assert len(prefills) == 1, len(prefills)
    # ≥ one decode per master tick (tick batch) plus refill catch-ups —
    # but O(ticks), never O(ticks·depth).
    assert len(decodes) >= int(res.ticks)
    assert int(res.tree_size) > 1


def test_cached_matches_uncached_end_to_end(lm):
    """Full async searches, cached vs uncached evaluator, same seeds: the
    logits agree to fp tolerance, so every discrete search decision (visits,
    tree shape, chosen action) matches on this seeded case and the value
    statistics agree to fp tolerance."""
    cfg, params = lm
    env, ev = _token_search_pieces(lm)
    ev_u = ModelEvaluator(cfg, params, top_k=4, eos_token=1)
    spec = SearchSpec(
        algo="wu_uct", engine="async", num_simulations=12, wave_size=4,
        max_depth=5, max_sim_steps=5, max_width=4, gamma=1.0,
    )
    key = jax.random.PRNGKey(2)
    root = env.init(key)
    res_c = build_searcher(env, spec, evaluator=ev)(root, key)
    res_u = build_searcher(env, spec, evaluator=ev_u)(root, key)
    for f in ("action", "root_n", "tree_size", "ticks", "overflowed"):
        np.testing.assert_array_equal(
            np.asarray(getattr(res_c, f)), np.asarray(getattr(res_u, f)),
            err_msg=f"field {f}",
        )
    np.testing.assert_allclose(
        np.asarray(res_c.root_v), np.asarray(res_u.root_v), **TOL
    )


def test_cached_evaluator_rejects_wave_engine(lm):
    cfg, params = lm
    env, ev = _token_search_pieces(lm)
    with pytest.raises(ValueError, match="async"):
        build_searcher(env, SearchSpec(algo="wu_uct", engine="wave"),
                       evaluator=ev)


def test_cached_evaluator_rejects_recurrent_families():
    cfg = dataclasses.replace(
        get_reduced("mamba2-2.7b"), vocab_size=64, num_layers=1, d_model=64,
    )
    with pytest.raises(ValueError, match="recurrent"):
        CachedModelEvaluator(cfg, {}, top_k=4)
