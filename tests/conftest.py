"""Test-collection config: skip property-based modules without hypothesis.

Four modules use hypothesis unconditionally for property-based sweeps.  It
is a dev-only dependency (see pyproject.toml ``[project.optional-dependencies]
dev``) that CI installs (.github/workflows/ci.yml); in minimal environments
the rest of the suite must still collect and run, so we drop those modules
from collection instead of erroring at import time.
``tests/test_async_invariants.py`` is NOT listed: it guards its hypothesis
import and falls back to a deterministic case sweep, so it always collects.
"""

import importlib.util

HYPOTHESIS_MODULES = [
    "test_core_invariants.py",
    "test_envs.py",
    "test_kernels.py",
    "test_policy_properties.py",
]

collect_ignore = (
    [] if importlib.util.find_spec("hypothesis") is not None
    else list(HYPOTHESIS_MODULES)
)
