"""Beyond-paper: exact-regret study of the Sec. 4 failure modes.

On bandit trees the optimum is computable in closed form, so we can measure
*exactly* what the paper argues qualitatively:

* collapse of exploration — duplicate stop-nodes per wave under naive
  parallelization (selection with stale eq. (2), no in-flight statistics)
  vs WU-UCT's eq. (4);
* exploitation failure — simple regret (V* − V(chosen arm)) of TreeP's
  virtual loss at increasing r_VL vs WU-UCT;
* the O_s mechanism's vanishing-penalty property: WU-UCT's visit share of
  the optimal arm approaches sequential UCT's as simulations grow.
"""

from __future__ import annotations

import numpy as np
import jax

from repro.core import SearchSpec, build_searcher
from repro.envs import make_bandit_tree
from repro.envs.bandit_tree import solve_bandit_tree

from .common import row


def run(
    depth: int = 5, actions: int = 4, workers: int = 16,
    num_simulations: int = 128, trials: int = 5,
) -> list[str]:
    env = make_bandit_tree(depth=depth, num_actions=actions, seed=11)
    _, opt_a, q_root = solve_bandit_tree(depth, actions, 11, gamma=1.0)
    rows = []

    variants = {
        "uct_seq": ("uct", {}),
        "naive_parallel": ("leafp", {}),       # stale-stats extreme
        "wu_uct": ("wu_uct", {}),
        "treep_r1": ("treep", dict(r_vl=1.0)),
        "treep_r5": ("treep", dict(r_vl=5.0)),
        "rootp": ("rootp", {}),
    }
    for name, (algo, kw) in variants.items():
        w = 1 if name == "uct_seq" else workers
        spec = SearchSpec(
            algo=algo, num_simulations=num_simulations, wave_size=w,
            max_depth=depth + 1, max_sim_steps=depth + 1,
            max_width=actions, gamma=1.0, **kw,
        )
        fn = build_searcher(env, spec)
        regrets, dups, opt_shares = [], [], []
        state = env.init(jax.random.PRNGKey(0))
        for t in range(trials):
            res = fn(state, jax.random.PRNGKey(500 + t))
            a = int(res.action)
            regrets.append(float(q_root.max() - q_root[a]))
            dups.append(float(res.dup_selections))
            n = np.asarray(res.root_n)
            opt_shares.append(float(n[opt_a] / max(n.sum(), 1)))
        rows.append(
            row(
                f"regret/{name}",
                0.0,
                f"simple_regret={np.mean(regrets):.4f};"
                f"opt_visit_share={np.mean(opt_shares):.3f};"
                f"dup_per_wave={np.mean(dups):.2f}",
            )
        )
    return rows
