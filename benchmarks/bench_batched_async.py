"""Batched async-slot search throughput: searches/sec vs batch size B.

The claim under test: ``run_async_search_batched`` (masked row updates,
flat [B·W] slot ticks, kernel-fused refill selection) beats ``jax.vmap`` of
the single async engine, whose per-slot ``lax.cond`` refills lower to selects
over the *entire* tree pytree under vmap — O(B·M·state) memory traffic per
slot, per tick.  Outputs are bit-identical (tests/test_batched_async_search),
so the speedup is pure scheduling/lowering, not a different search.

Rows: ``async_batched_B{n}`` / ``async_vmap_B{n}`` with derived searches/sec,
plus an exact-agreement row (must always read 1.00).
"""

from __future__ import annotations

import jax
import numpy as np

from repro.core import PolicyConfig, SearchConfig, SearchSpec, build_searcher
from repro.core.async_search import run_async_search  # vmap baseline
from repro.envs import make_bandit_tree

from .common import row, time_fn

BATCH_SIZES = (1, 8, 32)


def _cfg(num_simulations: int, wave_size: int) -> SearchConfig:
    return SearchConfig(
        num_simulations=num_simulations,
        wave_size=wave_size,
        max_depth=8,
        max_sim_steps=8,
        max_width=4,
        gamma=0.99,
        policy=PolicyConfig(kind="wu_uct"),
        stat_mode="wu",
    )


def run(
    num_simulations: int = 128,
    wave_size: int = 16,
    batch_sizes: tuple[int, ...] = BATCH_SIZES,
) -> list[str]:
    env = make_bandit_tree(depth=6, num_actions=4, seed=0)
    cfg = _cfg(num_simulations, wave_size)
    rows = []

    spec = SearchSpec(
        algo="wu_uct", engine="async", num_simulations=num_simulations,
        wave_size=wave_size, max_depth=cfg.max_depth,
        max_sim_steps=cfg.max_sim_steps, max_width=cfg.max_width,
        gamma=cfg.gamma,
    )
    vmapped = jax.jit(jax.vmap(lambda s, k: run_async_search(env, cfg, s, k)))

    for B in batch_sizes:
        batched = build_searcher(env, spec._replace(batch=B))
        roots = jax.vmap(env.init)(jax.random.split(jax.random.PRNGKey(0), B))
        rngs = jax.random.split(jax.random.PRNGKey(1), B)

        t_b = time_fn(batched, roots, rngs, warmup=1, iters=5)
        rows.append(row(f"async_batched_B{B}", t_b, f"{B / t_b:.1f} searches/s"))
        t_v = time_fn(vmapped, roots, rngs, warmup=1, iters=5)
        rows.append(row(f"async_vmap_B{B}", t_v, f"{B / t_v:.1f} searches/s"))

        res_b = batched(roots, rngs)
        res_v = vmapped(roots, rngs)
        agree = np.mean(np.asarray(res_b.root_n) == np.asarray(res_v.root_n))
        rows.append(
            row(f"async_agreement_B{B}", 0.0,
                f"{agree:.2f} root_n match; {t_v / t_b:.2f}x vs vmap")
        )
    return rows


def main() -> None:
    print("name,us_per_call,derived")
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
