"""Paper Fig. 4(c-d): performance (game steps) vs number of workers.

The paper's claim: WU-UCT suffers *negligible performance loss* as workers
increase (std of game steps 0.67/1.22 across worker counts).  We replay the
protocol on two tap-game levels (easy / hard) and report mean game steps per
wave size, plus the cross-W std — the reproduction statistic.
"""

from __future__ import annotations

import numpy as np
import jax

from repro.core import SearchSpec, play_episode
from repro.envs import make_tap_game

from .common import row

LEVELS = {
    "level_easy": dict(grid_size=6, num_colors=3, goal_count=8, step_budget=24),
    "level_hard": dict(grid_size=7, num_colors=5, goal_count=14, step_budget=30),
}


def run(
    waves=(1, 4, 16), episodes: int = 3, num_simulations: int = 32
) -> list[str]:
    rows = []
    for level, kw in LEVELS.items():
        env = make_tap_game(**kw)
        means = []
        for w in waves:
            cfg = SearchSpec(
                algo="wu_uct", num_simulations=num_simulations, wave_size=w,
                max_depth=10, max_sim_steps=15, max_width=5, gamma=1.0,
            ).config
            steps = []
            for ep in range(episodes):
                _, moves, done = play_episode(
                    env, cfg, jax.random.PRNGKey(1000 * w + ep),
                    max_moves=kw["step_budget"],
                )
                steps.append(moves)
            means.append(float(np.mean(steps)))
            rows.append(
                row(
                    f"worker_perf/{level}/W={w}",
                    0.0,
                    f"game_steps={np.mean(steps):.2f}±{np.std(steps):.2f}",
                )
            )
        rows.append(
            row(
                f"worker_perf/{level}/cross_W_std",
                0.0,
                f"std={np.std(means):.3f} (paper: 0.67/1.22)",
            )
        )
    return rows
