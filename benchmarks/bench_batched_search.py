"""Batched multi-root search throughput: searches/sec vs batch size B.

The claim under test (ROADMAP north star — throughput *across* searches):
running B independent trees in lockstep through the fused Pallas
``tree_select`` kernel amortizes master-side work over the batch, beating
``jax.vmap`` of the single-tree engine (whose per-node scalar ``while_loop``
selection cannot fuse the [B, A] score + argmax pass).

Rows: ``batched_B{n}`` / ``vmap_single_B{n}`` with derived searches/sec.
"""

from __future__ import annotations

import jax
import numpy as np

from repro.core import SearchConfig, PolicyConfig, SearchSpec, build_searcher
from repro.core.wu_uct import run_search  # vmap baseline: engine internals
from repro.envs import make_bandit_tree

from .common import row, time_fn

BATCH_SIZES = (1, 8, 32)


def _cfg(num_simulations: int, wave_size: int) -> SearchConfig:
    return SearchConfig(
        num_simulations=num_simulations,
        wave_size=wave_size,
        max_depth=8,
        max_sim_steps=8,
        max_width=4,
        gamma=0.99,
        policy=PolicyConfig(kind="wu_uct"),
        stat_mode="wu",
    )


def run(
    num_simulations: int = 64,
    wave_size: int = 8,
    batch_sizes: tuple[int, ...] = BATCH_SIZES,
) -> list[str]:
    env = make_bandit_tree(depth=6, num_actions=4, seed=0)
    cfg = _cfg(num_simulations, wave_size)
    rows = []

    spec = SearchSpec(
        algo="wu_uct", num_simulations=num_simulations,
        wave_size=cfg.wave_size, max_depth=cfg.max_depth,
        max_sim_steps=cfg.max_sim_steps, max_width=cfg.max_width,
        gamma=cfg.gamma,
    )
    vmapped = jax.jit(jax.vmap(lambda s, k: run_search(env, cfg, s, k)))

    for B in batch_sizes:
        batched = build_searcher(env, spec._replace(batch=B))
        roots = jax.vmap(env.init)(jax.random.split(jax.random.PRNGKey(0), B))
        rngs = jax.random.split(jax.random.PRNGKey(1), B)

        t_b = time_fn(batched, roots, rngs, warmup=1, iters=3)
        rows.append(row(f"batched_B{B}", t_b, f"{B / t_b:.1f} searches/s"))
        t_v = time_fn(vmapped, roots, rngs, warmup=1, iters=3)
        rows.append(row(f"vmap_single_B{B}", t_v, f"{B / t_v:.1f} searches/s"))

        res_b = batched(roots, rngs)
        res_v = vmapped(roots, rngs)
        agree = np.mean(
            np.asarray(res_b.action) == np.asarray(res_v.action)
        )
        rows.append(row(f"agreement_B{B}", 0.0, f"{agree:.2f} action match"))
    return rows


def main() -> None:
    print("name,us_per_call,derived")
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
