"""Beyond-paper: async-slot scheduler scaling (straggler mitigation).

The async engine (core/async_search.py, the faithful Algorithm-1 port)
completes T simulations in ~T·E[len]/W master ticks because slots refill the
moment their rollout ends.  A barrier (wave) schedule pays max-rollout-length
per wave instead.  We measure master ticks vs W on an env with heterogeneous
rollout lengths and report the async advantage — the quantity that becomes
wall-clock on a pod, where each tick is one lock-step device step.
"""

from __future__ import annotations

import jax

from repro.core import SearchSpec, build_searcher
from repro.envs import make_tap_game

from .common import row


def run(num_simulations: int = 64, waves=(1, 4, 16)) -> list[str]:
    env = make_tap_game(grid_size=6, num_colors=4, goal_count=10, step_budget=20)
    key = jax.random.PRNGKey(0)
    state = env.init(key)
    rows = []
    base_ticks = None
    for w in waves:
        spec = SearchSpec(
            algo="wu_uct", engine="async", num_simulations=num_simulations,
            wave_size=w, max_depth=10, max_sim_steps=15, max_width=5,
            gamma=1.0,
        )
        search = build_searcher(env, spec)
        res = search(state, key)
        ticks = float(res.ticks)
        if base_ticks is None:
            base_ticks = ticks
        barrier_bound = (num_simulations // w) * (spec.max_sim_steps + 1)
        rows.append(
            row(
                f"async_scaling/W={w}",
                0.0,
                f"ticks={ticks:.0f};speedup_x={base_ticks / ticks:.2f};"
                f"barrier_bound={barrier_bound}",
            )
        )
    return rows
