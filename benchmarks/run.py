# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness entry point.

Each module maps to one paper artifact (see DESIGN.md §7):
  bench_speedup         — Fig. 4(a-b) + Table 3 (speedup vs workers)
  bench_worker_perf     — Fig. 4(c-d)          (performance vs workers)
  bench_parallel_algos  — Table 1              (WU-UCT vs TreeP/LeafP/RootP)
  bench_treep_variants  — Table 5 / App. E     (virtual pseudo-count TreeP)
  bench_time_breakdown  — Fig. 2(b-c)          (phase time breakdown)
  bench_regret          — beyond-paper exact-regret study (Sec. 4 claims)
  bench_batched_search  — beyond-paper multi-root throughput (searches/sec vs B)
  bench_batched_async   — beyond-paper batched async-slot engine vs vmap baseline

Roofline tables come from ``python -m benchmarks.roofline`` (reads the
dry-run artifacts; see EXPERIMENTS.md §Roofline).
"""

from __future__ import annotations

import argparse
import json
import sys
import time
import warnings

# The failure modes one bench module can legitimately hit while the rest of
# the sweep should still run: bad shapes/params (ValueError/TypeError),
# compile/XLA errors (RuntimeError), missing record fields (KeyError/
# AttributeError/IndexError), overflow (ArithmeticError), optional deps
# (ImportError) and artifact IO (OSError).  A KeyboardInterrupt or a
# typo-level NameError still aborts the whole run — see JX004 in
# ``python -m repro.analysis.lint --rules``.
_BENCH_ERRORS = (
    RuntimeError, ValueError, TypeError, KeyError, AttributeError,
    IndexError, ArithmeticError, ImportError, NotImplementedError, OSError,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-list of module names")
    ap.add_argument("--fast", action="store_true", help="reduced budgets")
    ap.add_argument(
        "--json-dir", default=".",
        help="where BENCH_*.json perf baselines are written",
    )
    args = ap.parse_args()

    # Machine-readable perf baselines: modules listed here append structured
    # records which land in BENCH_<module>.json next to the CSV on stdout,
    # so later PRs can diff throughput against this run.
    json_records: dict[str, list] = {"model_eval": []}

    from . import (
        bench_async_scaling,
        bench_batched_async,
        bench_batched_search,
        bench_model_eval,
        bench_parallel_algos,
        bench_regret,
        bench_speedup,
        bench_time_breakdown,
        bench_treep_variants,
        bench_worker_perf,
    )

    modules = {
        "speedup": lambda: bench_speedup.run(
            num_simulations=32 if args.fast else 64,
            waves=(1, 4, 16) if args.fast else (1, 2, 4, 8, 16),
        ),
        "worker_perf": lambda: bench_worker_perf.run(
            episodes=1 if args.fast else 3,
            num_simulations=16 if args.fast else 32,
        ),
        "parallel_algos": lambda: bench_parallel_algos.run(
            episodes=1 if args.fast else 3,
            num_simulations=32 if args.fast else 64,
        ),
        "treep_variants": lambda: bench_treep_variants.run(
            episodes=1 if args.fast else 3,
            num_simulations=32 if args.fast else 64,
        ),
        "time_breakdown": lambda: bench_time_breakdown.run(),
        "regret": lambda: bench_regret.run(trials=2 if args.fast else 5),
        "async_scaling": lambda: bench_async_scaling.run(
            num_simulations=32 if args.fast else 64,
        ),
        "batched_search": lambda: bench_batched_search.run(
            num_simulations=32 if args.fast else 64,
            batch_sizes=(1, 8) if args.fast else (1, 8, 32),
        ),
        "batched_async": lambda: bench_batched_async.run(
            num_simulations=32 if args.fast else 128,
            wave_size=8 if args.fast else 16,
            batch_sizes=(1, 8) if args.fast else (1, 8, 32),
        ),
        "model_eval": lambda: bench_model_eval.run(
            num_simulations=8 if args.fast else 16,
            wave_size=4,
            batch_sizes=(1,) if args.fast else (1, 4),
            depths=(8,) if args.fast else (8, 64),
            serving_batch=2 if args.fast else 4,
            records=json_records["model_eval"],
        ),
    }
    selected = args.only.split(",") if args.only else list(modules)

    print("name,us_per_call,derived")
    for name in selected:
        t0 = time.time()
        ok = True
        try:
            for line in modules[name]():
                print(line, flush=True)
        except _BENCH_ERRORS as e:
            ok = False
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}", flush=True)
            # warnings dedups identical messages, so a module that fails
            # the same way in a loop of invocations warns once per process.
            warnings.warn(
                f"benchmark module {name!r} failed "
                f"({type(e).__name__}: {e}); its rows are omitted",
                stacklevel=2,
            )
        print(f"# {name} took {time.time() - t0:.1f}s", file=sys.stderr)
        # Only a COMPLETE run may become the committed perf baseline — a
        # partial sweep would silently read as a full one in future diffs.
        if ok and json_records.get(name):
            path = f"{args.json_dir}/BENCH_{name}.json"
            with open(path, "w") as f:
                json.dump(
                    {"fast": args.fast, "rows": json_records[name]}, f,
                    indent=2,
                )
            print(f"# wrote {path}", file=sys.stderr)


if __name__ == "__main__":
    main()
