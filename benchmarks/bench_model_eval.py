"""Model-backed evaluation throughput: decode-cached vs prefill-per-tick.

Two claims under test:

* ``ModelEvaluator`` (PR 4): every async master tick evaluates ALL ``[B·W]``
  in-flight slots with **one** batched full-prefix forward — vs the default
  rollout evaluation whose per-slot ``env.policy`` + ``env.step`` lower to
  three forwards per slot step.
* ``CachedModelEvaluator`` (PR 5): that one forward becomes a single
  batched ``decode_step`` against per-slot KV caches carried in the slot
  state — O(1) in prefix length instead of O(depth).  The ``--depth`` sweep
  makes the asymptotics visible: prefill-per-tick cost grows with
  ``max_depth`` (longer prefixes per forward) while the cached per-tick cost
  stays flat, so the speedup widens with depth.  (The early ``d8_B4``
  regression — cached slower than prefill at shallow depth — was refill
  catch-up dispatch: one ``decode_step`` launch per divergent token.  The
  chunked catch-up, one launch per ``refill_chunk`` tokens, removed it.)
* ``PagedCachedModelEvaluator`` (this PR): the dense ``[B·W, max_len]``
  slot caches become a shared block pool + page tables.  Per-tick cost must
  stay flat vs the dense cached rows, and the trace-mode
  ``blocks_in_use`` peak shows the real working set: sibling slots share
  prefix pages (copy-on-write), so the same HBM budget admits strictly more
  slots — the ``paged_ceiling`` rows derive that batch ceiling.

* ``FrontierModelEvaluator`` (this PR): EXPAND ticks score every candidate
  child in one tree-batched ``decode_frontier`` forward and snapshot the
  whole frontier into slot aux, so sibling/child refills are answered with
  ZERO model forwards.  The ``frontier_eval`` rows sweep the candidate
  width ``A`` against a MATCHED cached baseline (same env top-K / tree
  width) and report the absorbed refill hits from the trace counter.

* Continuous batching: a ragged-arrival request workload with
  ``R >> B`` drains through the persistent
  :class:`~repro.serving.SearchService` engine — settled tree rows are
  re-seeded with queued requests mid-``while_loop`` instead of idling until
  the batch's slowest search finishes.  The ``serving_eval`` rows report
  the host-paced poll path (requests/s, slot-idle fraction, host rounds);
  the ``serving_fused`` rows report the device-resident ring path
  (admission/eviction inside the jitted segment — one host sync per
  segment) with its host-round reduction and mean ring occupancy; the
  ``serving_speedup`` rows compare the fused drain against the one-shot
  path serving the same workload in sequential ``B``-sized batches.

Rows: ``prefill_eval_d{d}_B{n}`` / ``cached_eval_d{d}_B{n}`` /
``paged_eval_d{d}_B{n}`` with derived searches/sec and per-tick µs,
``cached_speedup_d{d}_B{n}``, ``paged_ceiling_d{d}_B{n}`` (peak pool blocks
→ max B·W at the dense layout's HBM budget),
``frontier_eval_d{d}_B{n}_A{a}`` / ``frontier_speedup_d{d}_B{n}_A{a}``
(frontier vs matched-width cached decode),
``serving_eval_{mode}_B{n}`` / ``serving_fused_{mode}_B{n}`` /
``serving_speedup_{mode}_B{n}``
(continuous drain of ``R = 3·B`` ragged arrivals — host-paced poll, fused
ring, and fused-vs-sequential-one-shot — dense and paged), plus the PR-4
``rollout_eval`` baseline at the first depth.  Forward/decode counting is
asserted in ``tests/test_facade.py`` / ``tests/test_cached_evaluator.py``;
this file measures the wall-clock consequence.  ``benchmarks/run.py`` dumps
the same measurements machine-readably to ``BENCH_model_eval.json``.
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp

import functools

import numpy as np

from repro.configs import get_reduced
from repro.core import (
    CachedModelEvaluator,
    FrontierModelEvaluator,
    ModelEvaluator,
    PagedCachedModelEvaluator,
    SearchSpec,
    build_searcher,
)
from repro.envs.token_env import make_token_env
from repro.models import init_params, num_pages

from .common import row, time_fn

BATCH_SIZES = (1, 4)
DEPTHS = (8, 64)
PROMPT = (3, 5, 7)
BLOCK_SIZE = 8
FRONTIER_WIDTHS = (4, 16)


def _tiny_lm(vocab: int = 64):
    cfg = dataclasses.replace(
        get_reduced("llama3-8b"), vocab_size=vocab, num_layers=1,
        d_model=32, num_heads=2, num_kv_heads=1, head_dim=16, d_ff=64,
    )
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


def run(
    num_simulations: int = 16,
    wave_size: int = 4,
    batch_sizes: tuple[int, ...] = BATCH_SIZES,
    top_k: int = 4,
    depths: tuple[int, ...] = DEPTHS,
    paged: bool = True,
    frontier_widths: tuple[int, ...] = FRONTIER_WIDTHS,
    serving_batch: int = 4,
    records: list | None = None,
) -> list[str]:
    cfg, params = _tiny_lm()
    prompt = jnp.asarray(PROMPT, jnp.int32)
    rows = []

    def record(name, seconds, B, depth, ticks, kind):
        per_tick = seconds / max(ticks, 1)
        if records is not None:
            records.append({
                "name": name, "kind": kind, "batch": B, "depth": depth,
                "seconds": seconds, "searches_per_sec": B / seconds,
                "ticks": ticks, "us_per_tick": per_tick * 1e6,
            })
        rows.append(
            row(name, seconds,
                f"{B / seconds:.2f} searches/s; {per_tick * 1e6:.0f} us/tick")
        )

    for di, depth in enumerate(depths):
        # Leave room for a full rollout below the deepest expansion.
        max_len = len(PROMPT) + 2 * depth + 2
        env = make_token_env(cfg, params, prompt, max_len=max_len,
                             top_k=top_k, eos_token=1)
        spec = SearchSpec(
            algo="wu_uct", engine="async", num_simulations=num_simulations,
            wave_size=wave_size, max_depth=depth, max_sim_steps=depth,
            max_width=top_k, gamma=1.0,
        )
        model_ev = ModelEvaluator(cfg, params, top_k=top_k, eos_token=1)
        cached_ev = CachedModelEvaluator(cfg, params, top_k=top_k, eos_token=1)

        for B in batch_sizes:
            bspec = spec._replace(batch=B) if B > 1 else spec
            if B > 1:
                roots = jax.vmap(env.init)(
                    jax.random.split(jax.random.PRNGKey(0), B)
                )
                rngs = jax.random.split(jax.random.PRNGKey(1), B)
            else:
                roots = env.init(jax.random.PRNGKey(0))
                rngs = jax.random.PRNGKey(1)

            def bench(search):
                # The first (warmup) call also yields the evaluator's own
                # tick count — different evaluators sample different tokens
                # and so tick different numbers of times.  Shallow-depth
                # searches finish in single-digit ms, where 3-iteration
                # medians were noisy enough to flip speedup rows across
                # runs — 7 iterations keeps the row stable.
                ticks = int(jnp.max(jnp.atleast_1d(search(roots, rngs).ticks)))
                return time_fn(search, roots, rngs, warmup=0, iters=7), ticks

            prefill_search = build_searcher(env, bspec, evaluator=model_ev)
            cached_search = build_searcher(env, bspec, evaluator=cached_ev)

            t_p, ticks_p = bench(prefill_search)
            record(f"prefill_eval_d{depth}_B{B}", t_p, B, depth, ticks_p,
                   "prefill_per_tick")
            t_c, ticks_c = bench(cached_search)
            record(f"cached_eval_d{depth}_B{B}", t_c, B, depth, ticks_c,
                   "cached_decode")
            if records is not None:
                records.append({
                    "name": f"cached_speedup_d{depth}_B{B}",
                    "kind": "speedup", "batch": B, "depth": depth,
                    "speedup": t_p / t_c,
                })
            rows.append(
                row(f"cached_speedup_d{depth}_B{B}", 0.0,
                    f"{t_p / t_c:.2f}x vs prefill-per-tick")
            )

            if paged:
                slots = max(B, 1) * wave_size
                # Dense-equivalent pool for the timing row: same HBM as the
                # dense slot caches, so any speed delta is pure layout cost.
                nb = slots * num_pages(max_len, BLOCK_SIZE)
                paged_ev = PagedCachedModelEvaluator(
                    cfg, params, top_k=top_k, eos_token=1,
                    block_size=BLOCK_SIZE, num_blocks=nb,
                )
                t_g, ticks_g = bench(
                    build_searcher(env, bspec, evaluator=paged_ev)
                )
                record(f"paged_eval_d{depth}_B{B}", t_g, B, depth, ticks_g,
                       "paged_decode")

                # Batch ceiling: the trace-mode blocks_in_use peak is the
                # real paged working set (prefix pages shared COW between
                # siblings + no dead [max_len] tails), so at the HBM budget
                # the dense layout spends on `slots` slots the pool can
                # carry `slots * dense/paged` of them.
                from repro.core.async_search import run_async_search
                from repro.core.batched_async_search import (
                    run_async_search_batched,
                )

                engine = (
                    run_async_search_batched if B > 1 else run_async_search
                )
                fn = jax.jit(functools.partial(
                    engine, env, bspec.config,
                    trace_ticks=4 * num_simulations, evaluator=paged_ev,
                ))
                _, trace = fn(roots, rngs)
                alive = np.asarray(trace.alive)
                alive = alive.reshape(alive.shape[0], -1).any(axis=1)
                peak = int(np.asarray(trace.blocks_in_use)[alive].max())
                dense_pos = slots * max_len
                paged_pos = peak * BLOCK_SIZE
                max_slots = slots * dense_pos // max(paged_pos, 1)
                if records is not None:
                    records.append({
                        "name": f"paged_ceiling_d{depth}_B{B}",
                        "kind": "batch_ceiling", "batch": B, "depth": depth,
                        "slots": slots, "max_len": max_len,
                        "block_size": BLOCK_SIZE, "peak_blocks": peak,
                        "dense_kv_positions": dense_pos,
                        "paged_kv_positions": paged_pos,
                        "max_slots_at_budget": max_slots,
                        "ceiling_ratio": dense_pos / max(paged_pos, 1),
                    })
                rows.append(row(
                    f"paged_ceiling_d{depth}_B{B}", 0.0,
                    f"{peak} blocks peak; {max_slots} slots fit the "
                    f"dense budget ({slots} dense)",
                ))

            # Frontier-speculative expansion: the candidate width A is the
            # env's top_k AND the tree's max_width, so each A gets its own
            # env/spec pair plus a MATCHED cached baseline — comparing a
            # frontier run at A=16 against the top-level cached row at
            # top_k=4 would conflate candidate width with tree shape
            # (wider trees tick more).  The trace run reports how many
            # refills the frontier snapshot absorbed (zero-forward hits).
            from repro.core.async_search import run_async_search
            from repro.core.batched_async_search import (
                run_async_search_batched,
            )

            engine = run_async_search_batched if B > 1 else run_async_search
            for a in frontier_widths:
                if a == top_k:
                    env_a, bspec_a = env, bspec
                    t_base, ticks_base = t_c, ticks_c
                else:
                    env_a = make_token_env(
                        cfg, params, prompt, max_len=max_len, top_k=a,
                        eos_token=1,
                    )
                    spec_a = SearchSpec(
                        algo="wu_uct", engine="async",
                        num_simulations=num_simulations,
                        wave_size=wave_size, max_depth=depth,
                        max_sim_steps=depth, max_width=a, gamma=1.0,
                    )
                    bspec_a = spec_a._replace(batch=B) if B > 1 else spec_a
                    cached_a = CachedModelEvaluator(
                        cfg, params, top_k=a, eos_token=1
                    )
                    t_base, ticks_base = bench(
                        build_searcher(env_a, bspec_a, evaluator=cached_a)
                    )
                frontier_ev = FrontierModelEvaluator(
                    cfg, params, top_k=a, eos_token=1
                )
                t_f, ticks_f = bench(
                    build_searcher(env_a, bspec_a, evaluator=frontier_ev)
                )
                fn = jax.jit(functools.partial(
                    engine, env_a, bspec_a.config,
                    trace_ticks=4 * num_simulations, evaluator=frontier_ev,
                ))
                _, ftrace = fn(roots, rngs)
                hits = int(np.asarray(ftrace.frontier_hits)[-1].sum())
                per_tick = t_f / max(ticks_f, 1)
                if records is not None:
                    records.append({
                        "name": f"frontier_eval_d{depth}_B{B}_A{a}",
                        "kind": "frontier_decode", "batch": B,
                        "depth": depth, "top_k": a, "seconds": t_f,
                        "searches_per_sec": B / t_f, "ticks": ticks_f,
                        "us_per_tick": per_tick * 1e6,
                        "frontier_hits": hits,
                        "expansions": B * num_simulations,
                    })
                    records.append({
                        "name": f"frontier_speedup_d{depth}_B{B}_A{a}",
                        "kind": "frontier_speedup", "batch": B,
                        "depth": depth, "top_k": a,
                        "speedup": t_base / t_f,
                        "cached_seconds": t_base,
                        "cached_ticks": ticks_base,
                    })
                rows.append(row(
                    f"frontier_eval_d{depth}_B{B}_A{a}", t_f,
                    f"{B / t_f:.2f} searches/s; {per_tick * 1e6:.0f} "
                    f"us/tick; {hits} refill hits",
                ))
                rows.append(row(
                    f"frontier_speedup_d{depth}_B{B}_A{a}", 0.0,
                    f"{t_base / t_f:.2f}x vs cached decode at A={a}",
                ))

            if di == 0:
                t_r, ticks_r = bench(build_searcher(env, bspec))
                record(f"rollout_eval_d{depth}_B{B}", t_r, B, depth, ticks_r,
                       "rollout")

    if serving_batch:
        rows += _serving_rows(
            cfg, params, num_simulations=num_simulations,
            wave_size=wave_size, top_k=top_k, depth=depths[0],
            batch=serving_batch, records=records,
        )
    return rows


def _serving_rows(
    cfg, params, *, num_simulations, wave_size, top_k, depth, batch,
    records,
):
    """Continuous-vs-one-shot serving throughput on a ragged workload.

    ``R = 3 * batch`` requests with uneven prompt lengths arrive one per
    searches settle at different ticks, so the one-shot path pays an idle
    tail per ``B``-batch while the persistent engine admits the next
    request into each settled row.  All three serving variants drain the
    same queued-up-front workload (submit all ``R``, then drain — the
    regime the one-shot baseline also gets), so the rows differ only in
    engine pacing, not arrival schedule.  Reported per mode (dense /
    paged KV):

    * ``serving_eval`` — the host-paced poll path (PR 8 behaviour,
      ``fused=False``): requests/s, slot-idle fraction, and its
      ``host_rounds`` (one dispatch + settled-mask sync per
      ``ticks_per_round`` ticks).
    * ``serving_fused`` — the device-resident ring path (``fused=True``,
      ring sized to the workload): requests/s, ``host_rounds`` (one per
      ``ticks_per_segment`` segment — admission/eviction happen inside
      the jitted ``while_loop``), host rounds per drained request, and
      mean ring occupancy, beside the host-paced ``host_rounds`` for the
      reduction ratio.
    * ``serving_speedup`` — the fused drain vs the same workload in
      sequential one-shot ``B``-batches.

    At this benchmark's toy model scale (~100 µs/tick) host round-trips
    dominate: the fused path's win is that the host syncs once per
    segment instead of once per poll round.  ``host_rounds_per_request``
    is the hardware-independent signal; wall-clock speedup transfers to
    real models where a tick costs milliseconds.
    """
    import time as _time

    from repro.core import SearchSpec
    from repro.serving import SearchService

    max_len = len(PROMPT) + 2 * depth + 2
    spec = SearchSpec(
        algo="wu_uct", engine="async", batch=batch,
        num_simulations=num_simulations, wave_size=wave_size,
        max_depth=depth, max_sim_steps=depth, max_width=top_k, gamma=1.0,
    )
    n_req = 3 * batch
    base_prompts = [(3, 5), (2, 9, 4), (7,), (1, 2, 3), (5, 5), (6, 8, 2, 4)]
    prompts = [list(base_prompts[i % len(base_prompts)]) for i in range(n_req)]
    keys = [jax.random.fold_in(jax.random.PRNGKey(7), i) for i in range(n_req)]
    out = []
    for mode in ("dense", "paged"):
        # Host-paced poll path (PR 8 behaviour): one dispatch + settled
        # sync per ticks_per_round ticks.
        svc = SearchService(
            cfg, params, spec, top_k=top_k, max_len=max_len, eos_token=1,
            paged=(mode == "paged"), block_size=BLOCK_SIZE, fused=False,
        )

        def timed_drain(service):
            # Warm the compiled stage/segment/admit/evict/result programs
            # so the timed drain measures steady-state serving, not
            # compilation, then drain the full queued-up-front workload.
            for i in range(batch):
                service.submit(prompts[i], key=keys[i])
            service.drain()
            st0 = dataclasses.replace(service.stats)
            t0 = _time.perf_counter()
            for i in range(n_req):
                service.submit(prompts[i], key=keys[i])
            res = service.drain()
            dt = _time.perf_counter() - t0
            assert len(res) >= n_req
            return dt, st0, service.stats

        t_cont, st0, st = timed_drain(svc)
        ticks = st.ticks - st0.ticks
        busy = st.busy_tree_ticks - st0.busy_tree_ticks
        idle_frac = 1.0 - busy / max(ticks * batch, 1)
        host_rounds_poll = st.host_rounds - st0.host_rounds

        # Fused device-resident ring path: admission/eviction inside the
        # jitted segment, one host sync per segment.  Ring sized to the
        # workload so the whole queue stages before the first segment.
        fsvc = SearchService(
            cfg, params, spec, top_k=top_k, max_len=max_len, eos_token=1,
            paged=(mode == "paged"), block_size=BLOCK_SIZE, fused=True,
            ring_capacity=n_req, ticks_per_segment=256,
        )
        t_fused, fst0, fst = timed_drain(fsvc)
        host_rounds_fused = fst.host_rounds - fst0.host_rounds
        ring_occ = (
            (fst.ring_occupancy_sum - fst0.ring_occupancy_sum)
            / max(host_rounds_fused, 1)
        )

        # One-shot baseline: the same workload in sequential B-batches,
        # each blocking on its slowest search (same compiled program as
        # SearchService.search, warmed by the first chunk).
        one_shot = SearchService(
            cfg, params, spec, top_k=top_k, max_len=max_len, eos_token=1,
            paged=(mode == "paged"), block_size=BLOCK_SIZE,
        )
        chunks = [prompts[i:i + batch] for i in range(0, n_req, batch)]
        one_shot.search(chunks[0], jax.random.PRNGKey(0))
        t0 = _time.perf_counter()
        for ci, chunk in enumerate(chunks):
            jax.block_until_ready(
                one_shot.search(chunk, jax.random.PRNGKey(ci))
            )
        t_seq = _time.perf_counter() - t0

        if records is not None:
            records.append({
                "name": f"serving_eval_{mode}_B{batch}",
                "kind": "serving_eval", "batch": batch, "depth": depth,
                "requests": n_req, "seconds": t_cont,
                "requests_per_sec": n_req / t_cont,
                "slot_idle_frac": idle_frac,
                "admissions": st.admissions - st0.admissions,
                "ticks": ticks,
                "host_rounds": host_rounds_poll,
            })
            records.append({
                "name": f"serving_fused_{mode}_B{batch}",
                "kind": "serving_fused", "batch": batch, "depth": depth,
                "requests": n_req, "seconds": t_fused,
                "requests_per_sec": n_req / t_fused,
                "host_rounds": host_rounds_fused,
                "host_rounds_per_request": host_rounds_fused / n_req,
                "ring_occupancy": ring_occ,
                "host_paced_host_rounds": host_rounds_poll,
                "host_rounds_reduction": (
                    host_rounds_poll / max(host_rounds_fused, 1)
                ),
            })
            records.append({
                "name": f"serving_speedup_{mode}_B{batch}",
                "kind": "serving_speedup", "batch": batch, "depth": depth,
                "requests": n_req, "speedup": t_seq / t_fused,
                "sequential_seconds": t_seq,
                "fused_seconds": t_fused,
                "host_paced_seconds": t_cont,
            })
        out.append(row(
            f"serving_eval_{mode}_B{batch}", t_cont,
            f"{n_req / t_cont:.2f} req/s; {idle_frac:.3f} slot-idle frac; "
            f"{host_rounds_poll} host rounds",
        ))
        out.append(row(
            f"serving_fused_{mode}_B{batch}", t_fused,
            f"{n_req / t_fused:.2f} req/s; {host_rounds_fused} host rounds "
            f"({host_rounds_poll / max(host_rounds_fused, 1):.1f}x fewer); "
            f"ring occ {ring_occ:.2f}",
        ))
        out.append(row(
            f"serving_speedup_{mode}_B{batch}", 0.0,
            f"{t_seq / t_fused:.2f}x vs sequential one-shot batches",
        ))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--depth", type=int, nargs="*", default=list(DEPTHS),
        help="max_depth sweep: prefill-per-tick cost grows with depth, "
        "cached decode stays flat",
    )
    ap.add_argument("--batch", type=int, nargs="*", default=list(BATCH_SIZES))
    ap.add_argument("--num-simulations", type=int, default=16)
    ap.add_argument(
        "--paged", dest="paged", action="store_true", default=True,
        help="include paged-evaluator timing + batch-ceiling rows (default)",
    )
    ap.add_argument("--no-paged", dest="paged", action="store_false")
    ap.add_argument(
        "--serving-batch", type=int, default=4,
        help="engine rows B for the continuous-serving rows (0 disables); "
        "the ragged workload is 3*B requests",
    )
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for r in run(
        num_simulations=args.num_simulations,
        batch_sizes=tuple(args.batch),
        depths=tuple(args.depth),
        paged=args.paged,
        serving_batch=args.serving_batch,
    ):
        print(r)


if __name__ == "__main__":
    main()
