"""Model-backed evaluation throughput: one LM forward per master tick.

The claim under test (the ROADMAP follow-up made real by
``core/evaluators.py``): with a :class:`~repro.core.evaluators.ModelEvaluator`
plugged into the async engines through ``build_searcher``, every master tick
evaluates ALL ``[B·W]`` in-flight rollout slots with **one** batched
policy-LM forward — versus the default rollout evaluation over the token
env, whose per-slot ``env.policy`` + ``env.step`` lower to three forwards
per slot step.

Rows: ``model_eval_B{n}`` / ``rollout_eval_B{n}`` with derived searches/sec,
plus a speedup row.  Exact forward-per-tick counting is asserted in
``tests/test_facade.py``; this file measures the wall-clock consequence.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.core import ModelEvaluator, SearchSpec, build_searcher
from repro.envs.token_env import make_token_env
from repro.models import init_params

from .common import row, time_fn

BATCH_SIZES = (1, 4)


def _tiny_lm(vocab: int = 64):
    cfg = dataclasses.replace(
        get_reduced("llama3-8b"), vocab_size=vocab, num_layers=1,
        d_model=32, num_heads=2, num_kv_heads=1, head_dim=16, d_ff=64,
    )
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


def run(
    num_simulations: int = 16,
    wave_size: int = 4,
    batch_sizes: tuple[int, ...] = BATCH_SIZES,
    top_k: int = 4,
) -> list[str]:
    cfg, params = _tiny_lm()
    prompt = jnp.asarray([3, 5, 7], jnp.int32)
    env = make_token_env(cfg, params, prompt, max_len=16, top_k=top_k,
                         eos_token=1)
    spec = SearchSpec(
        algo="wu_uct", engine="async", num_simulations=num_simulations,
        wave_size=wave_size, max_depth=6, max_sim_steps=6, max_width=top_k,
        gamma=1.0,
    )
    model_ev = ModelEvaluator(cfg, params, top_k=top_k, eos_token=1)
    rows = []

    for B in batch_sizes:
        bspec = spec._replace(batch=B) if B > 1 else spec
        model_search = build_searcher(env, bspec, evaluator=model_ev)
        rollout_search = build_searcher(env, bspec)
        if B > 1:
            roots = jax.vmap(env.init)(
                jax.random.split(jax.random.PRNGKey(0), B)
            )
            rngs = jax.random.split(jax.random.PRNGKey(1), B)
        else:
            roots = env.init(jax.random.PRNGKey(0))
            rngs = jax.random.PRNGKey(1)

        t_m = time_fn(model_search, roots, rngs, warmup=1, iters=3)
        rows.append(row(f"model_eval_B{B}", t_m, f"{B / t_m:.2f} searches/s"))
        t_r = time_fn(rollout_search, roots, rngs, warmup=1, iters=3)
        rows.append(
            row(f"rollout_eval_B{B}", t_r, f"{B / t_r:.2f} searches/s")
        )
        rows.append(
            row(f"model_eval_speedup_B{B}", 0.0, f"{t_r / t_m:.2f}x vs rollout")
        )
    return rows


def main() -> None:
    print("name,us_per_call,derived")
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
