"""Model-backed evaluation throughput: decode-cached vs prefill-per-tick.

Two claims under test:

* ``ModelEvaluator`` (PR 4): every async master tick evaluates ALL ``[B·W]``
  in-flight slots with **one** batched full-prefix forward — vs the default
  rollout evaluation whose per-slot ``env.policy`` + ``env.step`` lower to
  three forwards per slot step.
* ``CachedModelEvaluator`` (this PR): that one forward becomes a single
  batched ``decode_step`` against per-slot KV caches carried in the slot
  state — O(1) in prefix length instead of O(depth).  The ``--depth`` sweep
  makes the asymptotics visible: prefill-per-tick cost grows with
  ``max_depth`` (longer prefixes per forward) while the cached per-tick cost
  stays flat, so the speedup widens with depth.

Rows: ``prefill_eval_d{d}_B{n}`` / ``cached_eval_d{d}_B{n}`` with derived
searches/sec and per-tick µs, ``cached_speedup_d{d}_B{n}``, plus the PR-4
``rollout_eval`` baseline at the first depth.  Forward/decode counting is
asserted in ``tests/test_facade.py`` / ``tests/test_cached_evaluator.py``;
this file measures the wall-clock consequence.  ``benchmarks/run.py`` dumps
the same measurements machine-readably to ``BENCH_model_eval.json``.
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.core import (
    CachedModelEvaluator,
    ModelEvaluator,
    SearchSpec,
    build_searcher,
)
from repro.envs.token_env import make_token_env
from repro.models import init_params

from .common import row, time_fn

BATCH_SIZES = (1, 4)
DEPTHS = (8, 64)
PROMPT = (3, 5, 7)


def _tiny_lm(vocab: int = 64):
    cfg = dataclasses.replace(
        get_reduced("llama3-8b"), vocab_size=vocab, num_layers=1,
        d_model=32, num_heads=2, num_kv_heads=1, head_dim=16, d_ff=64,
    )
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


def run(
    num_simulations: int = 16,
    wave_size: int = 4,
    batch_sizes: tuple[int, ...] = BATCH_SIZES,
    top_k: int = 4,
    depths: tuple[int, ...] = DEPTHS,
    records: list | None = None,
) -> list[str]:
    cfg, params = _tiny_lm()
    prompt = jnp.asarray(PROMPT, jnp.int32)
    rows = []

    def record(name, seconds, B, depth, ticks, kind):
        per_tick = seconds / max(ticks, 1)
        if records is not None:
            records.append({
                "name": name, "kind": kind, "batch": B, "depth": depth,
                "seconds": seconds, "searches_per_sec": B / seconds,
                "ticks": ticks, "us_per_tick": per_tick * 1e6,
            })
        rows.append(
            row(name, seconds,
                f"{B / seconds:.2f} searches/s; {per_tick * 1e6:.0f} us/tick")
        )

    for di, depth in enumerate(depths):
        # Leave room for a full rollout below the deepest expansion.
        max_len = len(PROMPT) + 2 * depth + 2
        env = make_token_env(cfg, params, prompt, max_len=max_len,
                             top_k=top_k, eos_token=1)
        spec = SearchSpec(
            algo="wu_uct", engine="async", num_simulations=num_simulations,
            wave_size=wave_size, max_depth=depth, max_sim_steps=depth,
            max_width=top_k, gamma=1.0,
        )
        model_ev = ModelEvaluator(cfg, params, top_k=top_k, eos_token=1)
        cached_ev = CachedModelEvaluator(cfg, params, top_k=top_k, eos_token=1)

        for B in batch_sizes:
            bspec = spec._replace(batch=B) if B > 1 else spec
            if B > 1:
                roots = jax.vmap(env.init)(
                    jax.random.split(jax.random.PRNGKey(0), B)
                )
                rngs = jax.random.split(jax.random.PRNGKey(1), B)
            else:
                roots = env.init(jax.random.PRNGKey(0))
                rngs = jax.random.PRNGKey(1)

            def bench(search):
                # The first (warmup) call also yields the evaluator's own
                # tick count — different evaluators sample different tokens
                # and so tick different numbers of times.
                ticks = int(jnp.max(jnp.atleast_1d(search(roots, rngs).ticks)))
                return time_fn(search, roots, rngs, warmup=0, iters=3), ticks

            prefill_search = build_searcher(env, bspec, evaluator=model_ev)
            cached_search = build_searcher(env, bspec, evaluator=cached_ev)

            t_p, ticks_p = bench(prefill_search)
            record(f"prefill_eval_d{depth}_B{B}", t_p, B, depth, ticks_p,
                   "prefill_per_tick")
            t_c, ticks_c = bench(cached_search)
            record(f"cached_eval_d{depth}_B{B}", t_c, B, depth, ticks_c,
                   "cached_decode")
            if records is not None:
                records.append({
                    "name": f"cached_speedup_d{depth}_B{B}",
                    "kind": "speedup", "batch": B, "depth": depth,
                    "speedup": t_p / t_c,
                })
            rows.append(
                row(f"cached_speedup_d{depth}_B{B}", 0.0,
                    f"{t_p / t_c:.2f}x vs prefill-per-tick")
            )

            if di == 0:
                t_r, ticks_r = bench(build_searcher(env, bspec))
                record(f"rollout_eval_d{depth}_B{B}", t_r, B, depth, ticks_r,
                       "rollout")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--depth", type=int, nargs="*", default=list(DEPTHS),
        help="max_depth sweep: prefill-per-tick cost grows with depth, "
        "cached decode stays flat",
    )
    ap.add_argument("--batch", type=int, nargs="*", default=list(BATCH_SIZES))
    ap.add_argument("--num-simulations", type=int, default=16)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for r in run(
        num_simulations=args.num_simulations,
        batch_sizes=tuple(args.batch),
        depths=tuple(args.depth),
    ):
        print(r)


if __name__ == "__main__":
    main()
