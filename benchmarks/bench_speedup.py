"""Paper Fig. 4(a-b) + Table 3: speedup vs number of simulation workers.

Measures wall-time of a full WU-UCT search on the tap game at fixed
``num_simulations`` while sweeping the wave size (= in-flight workers W).

Two speedup notions are reported:
* ``rounds`` — master rounds T/W (the paper's idealized linear scaling; on a
  pod the wave dimension shards over the data axis, so rounds ≈ wall-time),
* ``wall`` — measured wall-time speedup on THIS host (single CPU core: waves
  are SIMD-vectorized by XLA, not parallelized, so wall < rounds; the
  dry-run proves the wave shards across 256/512 chips).
"""

from __future__ import annotations

import jax

from repro.core import SearchSpec, build_searcher
from repro.envs import make_tap_game

from .common import time_fn, row


def run(num_simulations: int = 64, waves=(1, 2, 4, 8, 16)) -> list[str]:
    env = make_tap_game(grid_size=6, num_colors=4, goal_count=10, step_budget=20)
    key = jax.random.PRNGKey(0)
    state = env.init(key)
    rows = []
    base_t = None
    for w in waves:
        spec = SearchSpec(
            algo="wu_uct", num_simulations=num_simulations, wave_size=w,
            max_depth=10, max_sim_steps=15, max_width=5, gamma=1.0,
        )
        search = build_searcher(env, spec)
        t = time_fn(search, state, key, warmup=1, iters=3)
        if base_t is None:
            base_t = t
        rounds_speedup = w
        wall_speedup = base_t / t
        rows.append(
            row(
                f"speedup/wu_uct/W={w}",
                t,
                f"wall_x={wall_speedup:.2f};rounds_x={rounds_speedup}",
            )
        )
    return rows
