"""Aggregate the dry-run JSON records into the EXPERIMENTS.md roofline table.

Usage:
    PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def load_records(d: str) -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def fmt_row(r: dict) -> str:
    if "skipped" in r:
        return (
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | — | "
            f"skipped: sub-quadratic-only cell | — |"
        )
    if "error" in r:
        return (
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ERR | | | | "
            f"{r['error'][:40]} | |"
        )
    rf = r["roofline"]
    mem = r["memory"]
    peak_gb = (mem.get("peak_bytes") or 0) / 2**30
    return (
        f"| {r['arch']} | {r['shape']} | {r['mesh']} "
        f"| {rf['compute_s']:.4g} | {rf['memory_s']:.4g} "
        f"| {rf['collective_s']:.4g} | **{rf['dominant']}** "
        f"| {rf['useful_flops_ratio']:.2f} | {peak_gb:.1f} |"
    )


def make_table(records: list[dict]) -> str:
    hdr = (
        "| arch | shape | mesh | compute (s) | memory (s) | collective (s) "
        "| dominant | 6ND/HLO | peak GiB/dev |\n"
        "|---|---|---|---|---|---|---|---|---|\n"
    )
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    records = sorted(
        records, key=lambda r: (r.get("mesh", ""), r["arch"], order.get(r["shape"], 9))
    )
    return hdr + "\n".join(fmt_row(r) for r in records)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    recs = load_records(args.dir)
    print(make_table(recs))
    ok = sum(1 for r in recs if "roofline" in r)
    skip = sum(1 for r in recs if "skipped" in r)
    err = sum(1 for r in recs if "error" in r)
    print(f"\n{ok} measured, {skip} skipped (per assignment), {err} errors")


if __name__ == "__main__":
    main()
