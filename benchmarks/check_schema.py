"""Schema guard for committed perf baselines (CI benchmark-smoke).

Wall-clock numbers drift with hardware, so CI cannot diff them — but the
*shape* of a baseline is load-bearing: later PRs join rows by ``kind`` and
read specific fields, and a silently renamed kind or dropped field turns
every downstream comparison into a no-op.  This checker compares a freshly
generated ``BENCH_<module>.json`` (typically from ``run.py --fast``)
against the committed baseline and fails on:

* kinds present in the baseline but missing from the fresh run (a bench
  path stopped producing them);
* per-kind field sets that no longer cover the baseline's fields;
* known kinds whose rows drop a REQUIRED field (``REQUIRED_FIELDS``) —
  downstream consumers read these by name (e.g.
  ``serving.search_service`` sizes paged pools from
  ``batch_ceiling.ceiling_ratio``; the frontier rows' ``top_k`` /
  ``frontier_hits`` feed the hit-rate comparison), so they are pinned
  explicitly rather than inferred from whatever the baseline happened
  to contain.

Fresh runs may ADD kinds/fields (that is how baselines grow); they may not
lose any.  Usage::

    python -m benchmarks.check_schema --baseline BENCH_model_eval.json \
        --fresh /tmp/bench/BENCH_model_eval.json
"""

from __future__ import annotations

import argparse
import json
import sys


# Fields that rows of a kind must ALWAYS carry, independent of what the
# committed baseline contains — these are read by name elsewhere in the
# repo, so losing one is a break even if the baseline predates it.
REQUIRED_FIELDS: dict[str, set[str]] = {
    "batch_ceiling": {"ceiling_ratio", "peak_blocks", "block_size"},
    "frontier_decode": {
        "top_k", "frontier_hits", "searches_per_sec", "us_per_tick",
    },
    "frontier_speedup": {"top_k", "speedup", "cached_seconds"},
    "serving_eval": {
        "requests", "batch", "requests_per_sec", "slot_idle_frac",
        "admissions", "ticks", "host_rounds",
    },
    "serving_fused": {
        "requests", "requests_per_sec", "host_rounds",
        "host_rounds_per_request", "ring_occupancy",
        "host_paced_host_rounds", "host_rounds_reduction",
    },
    "serving_speedup": {
        "requests", "speedup", "sequential_seconds", "fused_seconds",
    },
}


def field_sets(rows: list[dict]) -> dict[str, set[str]]:
    """kind -> union of field names over that kind's rows."""
    out: dict[str, set[str]] = {}
    for r in rows:
        out.setdefault(r.get("kind", "?"), set()).update(r.keys())
    return out


def check(baseline: dict, fresh: dict) -> list[str]:
    errors = []
    base, new = field_sets(baseline["rows"]), field_sets(fresh["rows"])
    for kind, fields in sorted(base.items()):
        if kind not in new:
            errors.append(f"kind {kind!r} missing from fresh run")
            continue
        lost = fields - new[kind]
        if lost:
            errors.append(f"kind {kind!r} lost fields {sorted(lost)}")
    for kind, required in sorted(REQUIRED_FIELDS.items()):
        if kind not in new:
            continue
        missing = required - new[kind]
        if missing:
            errors.append(
                f"kind {kind!r} is missing required fields "
                f"{sorted(missing)}"
            )
    if not fresh["rows"]:
        errors.append("fresh run produced no rows")
    return errors


def _load(path: str, role: str) -> dict:
    """Read one report, failing with a pointed message instead of a
    traceback — a missing/corrupt baseline is a usage error, not a crash
    (and never a silently-passing check)."""
    try:
        with open(path) as f:
            data = json.load(f)
    except OSError as e:
        print(f"cannot read {role} report {path}: {e}", file=sys.stderr)
        sys.exit(2)
    except json.JSONDecodeError as e:
        print(f"{role} report {path} is not valid JSON: {e}", file=sys.stderr)
        sys.exit(2)
    if not isinstance(data.get("rows"), list):
        print(f"{role} report {path} has no 'rows' list", file=sys.stderr)
        sys.exit(2)
    return data


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--fresh", required=True)
    args = ap.parse_args()
    baseline = _load(args.baseline, "baseline")
    fresh = _load(args.fresh, "fresh")
    errors = check(baseline, fresh)
    for e in errors:
        print(f"SCHEMA DRIFT: {e}", file=sys.stderr)
    if errors:
        sys.exit(1)
    kinds = sorted(field_sets(fresh["rows"]))
    print(f"schema ok: {len(fresh['rows'])} rows, kinds {kinds}")


if __name__ == "__main__":
    main()
