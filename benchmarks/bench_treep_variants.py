"""Paper Table 5 (App. E): WU-UCT vs TreeP with virtual loss + pseudo-count.

Replays the comparison against the eq. (7) TreeP variant for
r_VL = n_VL ∈ {1, 2, 3}, plus plain virtual-loss TreeP — demonstrating the
paper's point that TreeP needs per-task hyper-parameter tuning while WU-UCT
has no such knob.
"""

from __future__ import annotations

import numpy as np
import jax

from repro.core import SearchSpec, build_searcher, play_episode
from repro.envs import make_bandit_tree, make_tap_game

from .common import row


def run(workers: int = 16, num_simulations: int = 64, episodes: int = 3):
    envs = {
        "tap_easy": make_tap_game(grid_size=6, num_colors=3, goal_count=8,
                                  step_budget=24),
        "bandit_d6": make_bandit_tree(depth=6, num_actions=4, seed=3),
    }
    rows = []
    for env_name, env in envs.items():
        variants = {"wu_uct": SearchSpec(
            algo="wu_uct", num_simulations=num_simulations, wave_size=workers,
            max_depth=12, max_sim_steps=15,
            max_width=min(8, env.num_actions), gamma=0.99,
        )}
        for r in (1.0, 2.0, 3.0):
            variants[f"treep_vc_r{int(r)}"] = SearchSpec(
                algo="treep_vc", num_simulations=num_simulations,
                wave_size=workers, max_depth=12, max_sim_steps=15,
                max_width=min(8, env.num_actions), gamma=0.99,
                r_vl=r, n_vl=r,
            )
        for name, spec in variants.items():
            cfg = spec.config
            searcher = build_searcher(env, spec)
            rets = []
            for ep in range(episodes):
                ret, _, _ = play_episode(
                    env, cfg, jax.random.PRNGKey(300 + ep), max_moves=24,
                    searcher=searcher,
                )
                rets.append(ret)
            rows.append(
                row(
                    f"table5/{env_name}/{name}",
                    0.0,
                    f"return={np.mean(rets):.3f}±{np.std(rets):.3f}",
                )
            )
    return rows
