"""Shared benchmark utilities."""

from __future__ import annotations

import time

import jax
import numpy as np


def time_fn(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall-time (seconds) of a jitted callable."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def row(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds * 1e6:.1f},{derived}"
