"""Paper Table 1: WU-UCT vs TreeP / LeafP / RootP / sequential UCT.

Atari is unavailable offline; the protocol is replayed on a suite of
JAX-native environments spanning the same claim surface: episode return
under identical worker counts and simulation budgets.  Sequential UCT is the
upper-bound reference (as in the paper); the ordering
WU-UCT ≥ {TreeP, LeafP, RootP} is the reproduced claim.
"""

from __future__ import annotations

import numpy as np
import jax

from repro.core import SearchSpec, build_searcher, play_episode
from repro.envs import make_bandit_tree, make_random_mdp, make_tap_game

from .common import row

ALGOS = ["uct", "wu_uct", "treep", "leafp", "rootp"]


def _env_suite():
    return {
        "tap_easy": make_tap_game(grid_size=6, num_colors=3, goal_count=8,
                                  step_budget=24),
        "tap_hard": make_tap_game(grid_size=7, num_colors=5, goal_count=14,
                                  step_budget=30),
        "random_mdp": make_random_mdp(num_states=32, num_actions=4, horizon=16),
        "bandit_d6": make_bandit_tree(depth=6, num_actions=4, seed=3),
    }


def run(
    workers: int = 16, num_simulations: int = 64, episodes: int = 3
) -> list[str]:
    rows = []
    for env_name, env in _env_suite().items():
        returns = {}
        for algo in ALGOS:
            w = 1 if algo == "uct" else workers
            kw = dict(
                num_simulations=num_simulations, wave_size=w,
                max_depth=12, max_sim_steps=15,
                max_width=min(8, env.num_actions), gamma=0.99,
            )
            if algo == "treep":
                kw["r_vl"] = 1.0
            spec = SearchSpec(algo=algo, **kw)
            cfg = spec.config
            searcher = build_searcher(env, spec)
            rets = []
            for ep in range(episodes):
                ret, _, _ = play_episode(
                    env, cfg, jax.random.PRNGKey(100 + ep), max_moves=24,
                    searcher=searcher,
                )
                rets.append(ret)
            returns[algo] = (float(np.mean(rets)), float(np.std(rets)))
            rows.append(
                row(
                    f"table1/{env_name}/{algo}",
                    0.0,
                    f"return={np.mean(rets):.3f}±{np.std(rets):.3f}",
                )
            )
        parallel = {k: v for k, v in returns.items() if k != "uct"}
        best = max(parallel, key=lambda k: parallel[k][0])
        rows.append(
            row(f"table1/{env_name}/best_parallel", 0.0, f"winner={best}")
        )
    return rows
