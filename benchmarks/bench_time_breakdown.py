"""Paper Fig. 2(b-c): time-consumption breakdown of the system phases.

Times the three wave phases separately — selection (+ incomplete updates),
expansion+simulation (the parallel worker phase), and completion — to verify
the paper's architectural premise: expansion+simulation dominate, so they
are the two steps worth parallelizing, while the master-side bookkeeping and
"communication" (here: slot gather/scatter) is negligible.
"""

from __future__ import annotations

import jax

from repro.core import SearchSpec
from repro.core.wu_uct import _phase1_select, _phase2_work, _phase3_settle
from repro.core import tree as tree_lib
from repro.envs import make_tap_game

from .common import time_fn, row


def run(wave_size: int = 16, num_simulations: int = 64) -> list[str]:
    env = make_tap_game(grid_size=6, num_colors=4, goal_count=10, step_budget=20)
    cfg = SearchSpec(
        algo="wu_uct", num_simulations=num_simulations, wave_size=wave_size,
        max_depth=10, max_sim_steps=15, max_width=5, gamma=1.0,
    ).config
    key = jax.random.PRNGKey(0)
    state = env.init(key)
    capacity = cfg.num_simulations + cfg.wave_size + 1
    tree = tree_lib.init_tree(state, capacity, env.num_actions)

    p1 = jax.jit(lambda t, k: _phase1_select(t, k, cfg))
    tree1, slots, _ = p1(tree, key)
    p2 = jax.jit(lambda t, s, k: _phase2_work(env, cfg, t, s, k))
    out2 = p2(tree1, slots, key)
    p3 = jax.jit(
        lambda t, s, cs, re, dc, r: _phase3_settle(t, cfg, s, cs, re, dc, r)
    )

    t1 = time_fn(p1, tree, key)
    t2 = time_fn(p2, tree1, slots, key)
    t3 = time_fn(p3, tree1, slots, *out2)
    total = t1 + t2 + t3
    return [
        row("breakdown/selection", t1, f"frac={t1 / total:.2f}"),
        row("breakdown/expansion+simulation", t2, f"frac={t2 / total:.2f}"),
        row("breakdown/completion", t3, f"frac={t3 / total:.2f}"),
    ]
