"""Shared request-admission path for both serving engines.

Two engines admit prompts into slot-shaped KV state mid-flight:

* :class:`repro.serving.engine.ServingEngine` — plain continuous-batching
  decode: a freed slot takes the next queued prompt;
* the batched async search engine behind
  :class:`repro.serving.search_service.SearchService` — a settled root's
  ``B``-row takes the next queued *search* request, re-seeding its tree, its
  per-tree RNG and all ``W`` evaluator slot caches.

Both paths are the same three steps, implemented once here: **validate** the
prompt against the slot's ``[max_len]`` cache row, **prefill** the admitted
prompts in one right-padded ragged batched forward
(``models.prefill_ragged`` — each prompt's cache fills at its own length),
and **splice** the resulting rows into the live engine state (dense:
slot-axis scatter; paged: block scatter behind a page-table edit).  The
evaluator-side admission hooks (``Evaluator.admit_aux``) and the decode
engine's ``add_requests`` both route through these helpers, so the KV-cache
contract (garbage rows beyond ``len``; see README) is enforced in one place.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ModelConfig


class PromptTooLongError(ValueError):
    """A prompt does not fit its engine's ``[max_len]`` slot cache row.

    Admitting it anyway would write past the row in the dense layout (ragged
    prefill scatters at positions ``>= max_len``) and miscount pages in the
    paged layout — so admission rejects it up front, by name.
    """


def validate_prompts(
    prompts: Sequence[Sequence[int]], max_len: int
) -> None:
    """Reject prompts that cannot legally occupy a ``[max_len]`` slot.

    A prompt needs ``len(p) < max_len`` — room for at least one generated
    token — and at least one token of its own (an empty prompt has no
    position to prefill or decode from).
    """
    empty = [i for i, p in enumerate(prompts) if len(p) == 0]
    if empty:
        raise ValueError(f"prompts {empty} are empty")
    too_long = [i for i, p in enumerate(prompts) if len(p) >= max_len]
    if too_long:
        raise PromptTooLongError(
            f"prompts {too_long} have length >= max_len={max_len}; "
            "leave room for at least one generated token"
        )


def pack_prompts(
    prompts: Sequence[Sequence[int]], pad_to: Optional[int] = None
) -> tuple[np.ndarray, np.ndarray]:
    """Right-pad a prompt list into ``(tokens [R, S], lengths [R])``.

    ``S`` is the longest prompt, rounded up to a multiple of ``pad_to`` when
    given (paged admission pads to whole blocks so prefill rows reshape into
    pool pages exactly).
    """
    lengths = np.asarray([len(p) for p in prompts], np.int32)
    s = int(lengths.max())
    if pad_to is not None:
        s = -(-s // pad_to) * pad_to
    tokens = np.zeros((len(prompts), s), np.int32)
    for i, p in enumerate(prompts):
        tokens[i, : len(p)] = p
    return tokens, lengths


def ragged_prefill(
    params, cfg: ModelConfig, tokens, lengths, s_pad: int, prefill_fn=None
):
    """One ragged batched prefill into a fresh ``[R, s_pad]`` dense cache.

    Returns ``(logits [R, V], cache)`` — logits at each row's own last valid
    position, cache rows valid up to each row's length (garbage beyond, per
    the KV contract).  The one forward both admission paths share.
    """
    from ..models import init_cache, prefill_ragged

    if prefill_fn is None:
        prefill_fn = prefill_ragged
    r = jnp.shape(tokens)[0]
    return prefill_fn(
        params, cfg, jnp.asarray(tokens, jnp.int32),
        jnp.asarray(lengths, jnp.int32), init_cache(cfg, r, s_pad),
    )


def splice_dense_slots(cache, slots, cache_new):
    """Scatter freshly prefilled cache rows into an engine cache's slots.

    Layer-stacked leaves carry the slot axis at position 1 (``[L, N, ...]``);
    scalar leaves (a uniform ``len``) pass through.  ``slots`` is ``i32[R]``
    and ``cache_new`` leaves carry ``R`` at position 1.
    """
    return jax.tree.map(
        lambda f, o: (
            f.at[:, slots].set(o)
            if hasattr(f, "ndim") and f.ndim > 1 else f
        ),
        cache,
        cache_new,
    )


def splice_pool_pages(pool_k, pool_v, dense_k, dense_v, dst):
    """Scatter dense ragged-prefill rows into a shared KV block pool.

    ``dense_k/v``: ``[L, R, S_pad, Hkv, D]`` with ``S_pad`` a multiple of
    the pool's block size; ``dst``: ``i32[R, S_pad // block_size]`` block
    ids per logical page (sentinel ``num_blocks`` entries drop out of the
    scatter).  The page-table analogue of :func:`splice_dense_slots` — the
    caller owns the table edit and refcounts.
    """
    l_, r_, s_, hk, hd = dense_k.shape
    npg = dst.shape[1]
    bs = s_ // npg
    flat = dst.reshape(-1)
    kd = dense_k.reshape(l_, r_ * npg, bs, hk, hd)
    vd = dense_v.reshape(l_, r_ * npg, bs, hk, hd)
    return (
        pool_k.at[:, flat].set(kd.astype(pool_k.dtype), mode="drop"),
        pool_v.at[:, flat].set(vd.astype(pool_v.dtype), mode="drop"),
    )


def pages_needed(length: int, block_size: int) -> int:
    """Logical pages a prefix of ``length`` tokens occupies."""
    return -(-int(length) // block_size)
