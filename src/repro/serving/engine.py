"""Batched serving engine: slot-based continuous batching over decode_step.

The engine owns ``B`` request slots.  Incoming prompts are prefilling into
free slots (left-padded batch prefill); every tick runs one fused
``decode_step`` for all active slots; finished sequences (EOS / max length)
free their slot immediately — the serving-side analogue of the WU-UCT
async-slot scheduler (no slot ever waits for the longest request).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import decode_step, init_cache, prefill
from ..models.config import ModelConfig


@dataclasses.dataclass
class ServeConfig:
    batch_slots: int = 8
    max_len: int = 512
    temperature: float = 0.0     # 0 = greedy
    eos_token: int = 0


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, serve_cfg: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.sc = serve_cfg
        self.cache = init_cache(cfg, serve_cfg.batch_slots, serve_cfg.max_len)
        b = serve_cfg.batch_slots
        self.active = np.zeros(b, bool)
        self.lengths = np.zeros(b, np.int32)
        self.outputs: list[list[int]] = [[] for _ in range(b)]
        self._decode = jax.jit(
            lambda p, t, c: decode_step(p, cfg, t, c)
        )
        self._last_tokens = np.zeros(b, np.int32)

    # NOTE: the simple engine prefils one request at a time (slot-local
    # cache update); a production engine batches prefill — the dry-run's
    # prefill_32k cell exercises that path.
    def add_request(self, prompt_tokens: list[int]) -> Optional[int]:
        free = np.flatnonzero(~self.active)
        if len(free) == 0:
            return None
        slot = int(free[0])
        cfg, sc = self.cfg, self.sc
        cache1 = init_cache(cfg, 1, sc.max_len)
        batch = {"tokens": jnp.asarray(prompt_tokens, jnp.int32)[None]}
        logits, cache1 = jax.jit(lambda p, b, c: prefill(p, cfg, b, c))(
            self.params, batch, cache1
        )
        # splice the slot-local cache into the batch cache
        def splice(full, one):
            if full.ndim == 0 or one.ndim == 0:
                return full
            # layer-stacked arrays: batch dim is axis 1
            return full.at[:, slot].set(one[:, 0])

        self.cache = jax.tree.map(
            lambda f, o: splice(f, o) if hasattr(f, "ndim") and f.ndim > 1 else f,
            self.cache,
            cache1,
        )
        tok = int(jnp.argmax(logits[0]))
        self.active[slot] = True
        self.lengths[slot] = len(prompt_tokens)
        # Per-slot cache lengths (vector `len`): each slot decodes at its own
        # position — the continuous-batching requirement.
        self.cache["len"] = jnp.asarray(self.lengths, jnp.int32)
        self.outputs[slot] = [tok]
        self._last_tokens[slot] = tok
        return slot

    def step(self, rng: Optional[jax.Array] = None) -> dict[int, int]:
        """One decode tick for all active slots; returns {slot: new_token}."""
        if not self.active.any():
            return {}
        tokens = jnp.asarray(self._last_tokens, jnp.int32)
        self.cache["len"] = jnp.asarray(self.lengths, jnp.int32)
        logits, self.cache = self._decode(self.params, tokens, self.cache)
        if self.sc.temperature > 0 and rng is not None:
            toks = jax.random.categorical(rng, logits / self.sc.temperature)
        else:
            toks = jnp.argmax(logits, axis=-1)
        toks = np.asarray(toks, np.int32)
        emitted = {}
        for slot in np.flatnonzero(self.active):
            t = int(toks[slot])
            emitted[int(slot)] = t
            self.outputs[slot].append(t)
            self._last_tokens[slot] = t
            self.lengths[slot] += 1
            if t == self.sc.eos_token or self.lengths[slot] >= self.sc.max_len - 1:
                self.active[slot] = False
        return emitted

    def run(self, prompts: list[list[int]], max_ticks: int = 256):
        """Serve a list of prompts to completion; returns outputs per prompt."""
        pending = list(enumerate(prompts))
        slot_to_req: dict[int, int] = {}
        results: dict[int, list[int]] = {}
        ticks = 0
        while (pending or self.active.any()) and ticks < max_ticks:
            while pending:
                req_id, prompt = pending[0]
                slot = self.add_request(prompt)
                if slot is None:
                    break
                slot_to_req[slot] = req_id
                pending.pop(0)
            before = self.active.copy()
            self.step()
            ticks += 1
            for slot in np.flatnonzero(before & ~self.active):
                results[slot_to_req[int(slot)]] = list(self.outputs[int(slot)])
        for slot, req in slot_to_req.items():
            if req not in results:
                results[req] = list(self.outputs[slot])
        return [results.get(i, []) for i in range(len(prompts))]
