"""Batched serving engine: slot-based continuous batching over decode_step.

The engine owns ``B`` request slots.  Incoming prompts are admitted into
free slots by ONE right-padded ragged batch prefill (``prefill_ragged`` —
each slot's cache fills at its own length); every tick runs one fused
``decode_step`` for all active slots; finished sequences (EOS / max length)
free their slot immediately — the serving-side analogue of the WU-UCT
async-slot scheduler (no slot ever waits for the longest request).

The per-slot cache layout (``len`` vector; rows ``>= len`` garbage until
overwritten) is the contract shared with
:class:`repro.core.evaluators.CachedModelEvaluator` — see the README's
"KV-cache contract" section.  With ``ServeConfig.paged`` the slots draw
from a shared KV block pool (:mod:`repro.models.paged`) instead of each
owning a dense ``[max_len]`` row: admission becomes a page-table splice,
EOS returns the slot's pages to the pool, and the engine admits fewer
prompts (rather than failing) when the pool is tight.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import (
    KV_CACHE_FAMILIES,
    PagePoolExhaustedError,
    alloc_blocks,
    decode_step,
    init_cache,
    init_paged_cache,
    num_pages,
    paged_decode_step,
    prefill,
    prefill_ragged,
    release_pages,
)
from ..models.config import ModelConfig
from .admission import (
    PromptTooLongError,
    pack_prompts,
    splice_dense_slots,
    splice_pool_pages,
    validate_prompts,
)

__all__ = ["ServeConfig", "ServingEngine", "PromptTooLongError"]


@dataclasses.dataclass
class ServeConfig:
    batch_slots: int = 8
    max_len: int = 512
    temperature: float = 0.0     # 0 = greedy
    eos_token: int = 0
    # Paged KV (KV-cache families only): slots share one block pool instead
    # of each owning a dense [max_len] row, so the HBM high-water mark tracks
    # tokens actually in flight.  num_blocks=None sizes the pool at the
    # dense equivalent; shrink it to oversubscribe slots.
    paged: bool = False
    block_size: int = 16
    num_blocks: Optional[int] = None


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, serve_cfg: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.sc = serve_cfg
        b = serve_cfg.batch_slots
        if serve_cfg.paged:
            if cfg.family not in KV_CACHE_FAMILIES:
                raise ValueError(
                    f"paged serving needs a KV-cache family "
                    f"{KV_CACHE_FAMILIES}, not {cfg.family!r}"
                )
            bs = serve_cfg.block_size
            mp = num_pages(serve_cfg.max_len, bs)
            self.num_blocks = (
                serve_cfg.num_blocks
                if serve_cfg.num_blocks is not None
                else b * mp
            )
            self.cache = init_paged_cache(
                cfg, b, serve_cfg.max_len,
                block_size=bs, num_blocks=self.num_blocks,
            )
            # Device-side page accounting through the jitted allocator in
            # models.paged (alloc_blocks / release_pages): serving slots
            # never share blocks (independent requests), so every allocated
            # block sits at refcount 1 and the refcount vector doubles as
            # the free list.  Same allocator the batched search engine's
            # in-loop ring admission uses — no host numpy bookkeeping.
            self._table = jnp.full((b, mp), self.num_blocks, jnp.int32)
            self._refcount = jnp.zeros((self.num_blocks,), jnp.int32)
            self._paged_decode = jax.jit(
                lambda p, t, c: paged_decode_step(p, cfg, t, c)
            )
            self._splice = jax.jit(self._splice_pages)
            self._alloc_tables = jax.jit(
                self._alloc_tables_impl, static_argnames=("npg",)
            )
            self._step_prep = jax.jit(self._page_step_prep)
            self._release_rows = jax.jit(self._release_rows_impl)
        else:
            self.cache = init_cache(cfg, b, serve_cfg.max_len)
        self.active = np.zeros(b, bool)
        self.lengths = np.zeros(b, np.int32)
        self.outputs: list[list[int]] = [[] for _ in range(b)]
        self._decode = jax.jit(
            lambda p, t, c: decode_step(p, cfg, t, c)
        )
        # Jitted once per engine (retraces only on new admission-batch
        # shapes), not once per add_requests call.
        self._prefill_ragged = jax.jit(
            lambda p, t, l, c: prefill_ragged(p, cfg, t, l, c)
        )
        self._prefill_one = jax.jit(lambda p, b, c: prefill(p, cfg, b, c))
        self._last_tokens = np.zeros(b, np.int32)

    def blocks_in_use(self) -> int:
        """Pool blocks currently allocated (paged mode only)."""
        return int(jnp.sum(self._refcount > 0))

    def _splice_pages(self, pool_k, pool_v, dense_k, dense_v, dst):
        """Splice a dense ragged-prefill cache into the shared pool.

        Delegates to the shared admission path
        (:func:`repro.serving.admission.splice_pool_pages`) — the same
        scatter :class:`repro.core.evaluators.PagedCachedModelEvaluator`
        uses when the batched search engine admits a request mid-run.
        """
        return splice_pool_pages(pool_k, pool_v, dense_k, dense_v, dst)

    def _alloc_tables_impl(self, refcount, p_r, *, npg):
        """Admission page schedule: one jitted ``alloc_blocks`` sweep per
        page column hands each admitted prompt its first ``p_r[i]`` blocks
        (retraces only on a new admission-batch shape, like the prefill)."""
        r = p_r.shape[0]
        p = self.num_blocks
        dst = jnp.full((r, npg), p, jnp.int32)
        fails = jnp.int32(0)
        for pi in range(npg):
            need = pi < p_r
            blocks, refcount, n_fail = alloc_blocks(refcount, need)
            dst = dst.at[:, pi].set(jnp.where(need & (blocks < p), blocks, p))
            fails = fails + n_fail
        return dst, refcount, fails

    def _page_step_prep(self, table, refcount, lengths, active):
        """Per-tick paged bookkeeping, fused into one jitted dispatch:
        slots entering a fresh logical page allocate it (serving slots own
        their pages exclusively — off > 0 writes hit the current block, no
        COW), everyone else resolves its write target from the table.
        Exhaustion comes back as a latched count, raised eagerly by
        :meth:`step` alongside the token fetch."""
        b, mp = table.shape
        bs = self.sc.block_size
        p = self.num_blocks
        safe = jnp.clip(lengths, 0, self.sc.max_len - 1)
        bi, off = safe // bs, safe % bs
        bi = jnp.clip(bi, 0, mp - 1)
        rows = jnp.arange(b)
        need = active & (off == 0)
        blocks, refcount, n_fail = alloc_blocks(refcount, need)
        got = need & (blocks < p)
        cur = table[rows, bi]
        newb = jnp.where(got, blocks, cur)
        table = table.at[rows, bi].set(newb)
        wb = jnp.where(active, newb, p)
        return table, refcount, wb, off, safe, n_fail

    def _release_rows_impl(self, refcount, table, mask):
        """Return every block of the masked slots to the pool (refcount 1
        by construction, so one decref frees; sentinel entries drop out)."""
        mp = table.shape[1]
        hi = jnp.where(mask, mp, 0)
        refcount = release_pages(
            refcount, table, jnp.zeros_like(hi), hi
        )
        table = jnp.where(mask[:, None], self.num_blocks, table)
        return refcount, table

    def add_request(self, prompt_tokens: list[int]) -> Optional[int]:
        return self.add_requests([prompt_tokens])[0]

    def add_requests(
        self, prompts: list[list[int]]
    ) -> list[Optional[int]]:
        """Admit up to ``len(free slots)`` prompts with ONE batched prefill.

        KV-cache families right-pad the prompt batch to the longest prompt
        and run ``models.prefill_ragged`` — one forward fills every admitted
        slot's cache at its own length, and one scatter splices the slot
        block into the engine cache.  Recurrent-cache families (SSM/hybrid)
        cannot take right-padded ragged prefill (pad tokens would pollute
        the state), so they keep the per-prompt prefill loop.

        Returns one slot id (or ``None`` once slots ran out) per prompt, in
        order.  Prompts that cannot fit a ``[max_len]`` slot raise
        :class:`repro.serving.admission.PromptTooLongError` up front —
        admitting one would write past the dense cache row / miscount pages.
        """
        validate_prompts(prompts, self.sc.max_len)
        free = np.flatnonzero(~self.active)
        take = min(len(free), len(prompts))
        admitted: list[Optional[int]] = [None] * len(prompts)
        cfg, sc = self.cfg, self.sc
        if sc.paged and take:
            # Admit only what the block pool can hold right now (prompts
            # are admitted in order; the rest wait for pages to free).
            # One refcount scan is the only device sync of the admission.
            budget, n_fit = self.num_blocks - self.blocks_in_use(), 0
            for p in prompts[:take]:
                need = -(-len(p) // sc.block_size)
                if need > budget:
                    break
                budget -= need
                n_fit += 1
            take = n_fit
        if take == 0:
            return admitted
        slots = free[:take].astype(np.int32)
        if cfg.family in KV_CACHE_FAMILIES:
            toks, lengths = pack_prompts(
                prompts[:take],
                pad_to=sc.block_size if sc.paged else None,
            )
            s_pad = toks.shape[1] if sc.paged else sc.max_len
            logits, cache_n = self._prefill_ragged(
                self.params, jnp.asarray(toks), jnp.asarray(lengths),
                init_cache(cfg, take, s_pad),
            )
            if sc.paged:
                # Page-table splice: the jitted allocator hands each prompt
                # its pages, the dense prefill blocks scatter into the pool,
                # and the slots' table rows point at them — all device-side
                # (the budget pre-check above guarantees the alloc cannot
                # fail, so ``fails`` stays untouched).
                npg = s_pad // sc.block_size
                p_r = jnp.asarray(
                    [-(-int(lengths[i]) // sc.block_size)
                     for i in range(take)],
                    jnp.int32,
                )
                dst, self._refcount, _ = self._alloc_tables(
                    self._refcount, p_r, npg=npg
                )
                pk, pv = self._splice(
                    self.cache["k"], self.cache["v"],
                    cache_n["kv"]["k"], cache_n["kv"]["v"],
                    dst,
                )
                self.cache = dict(self.cache, k=pk, v=pv)
                self._table = self._table.at[
                    jnp.asarray(slots), :npg
                ].set(dst)
            else:
                # One scatter splices all admitted slots into the engine
                # cache (layer-stacked leaves carry the slot axis at
                # position 1) — the shared admission-path scatter.
                self.cache = splice_dense_slots(self.cache, slots, cache_n)
            first = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        else:
            first = np.zeros(take, np.int32)
            for i, p in enumerate(prompts[:take]):
                cache1 = init_cache(cfg, 1, sc.max_len)
                batch = {"tokens": jnp.asarray(p, jnp.int32)[None]}
                logits, cache1 = self._prefill_one(self.params, batch, cache1)
                slot = int(slots[i])
                self.cache = jax.tree.map(
                    lambda f, o: (
                        f.at[:, slot].set(o[:, 0])
                        if hasattr(f, "ndim") and f.ndim > 1 else f
                    ),
                    self.cache,
                    cache1,
                )
                first[i] = int(jnp.argmax(logits[0]))
        for i in range(take):
            slot = int(slots[i])
            tok = int(first[i])
            self.active[slot] = True
            self.lengths[slot] = len(prompts[i])
            self.outputs[slot] = [tok]
            self._last_tokens[slot] = tok
            admitted[i] = slot
        # Per-slot cache lengths (vector `len`): each slot decodes at its own
        # position — the continuous-batching requirement.
        self.cache["len"] = jnp.asarray(self.lengths, jnp.int32)
        return admitted

    def step(self, rng: Optional[jax.Array] = None) -> dict[int, int]:
        """One decode tick for all active slots; returns {slot: new_token}."""
        if not self.active.any():
            return {}
        tokens = jnp.asarray(self._last_tokens, jnp.int32)
        n_fail = None
        if self.sc.paged:
            # One jitted prep dispatch does the page bookkeeping the old
            # host loop did per slot (fresh-page allocation, write-target
            # resolution); exhaustion comes back latched and raises below,
            # fetched together with the tokens.
            self._table, self._refcount, wb, off, safe, n_fail = (
                self._step_prep(
                    self._table, self._refcount,
                    jnp.asarray(self.lengths, jnp.int32),
                    jnp.asarray(self.active),
                )
            )
            att_len = self.lengths + self.active.astype(np.int32)
            run_cache = dict(
                self.cache,
                table=self._table,
                len=jnp.asarray(att_len, jnp.int32),
                pos=safe,
                write_block=wb,
                write_off=off,
            )
            logits, run_cache = self._paged_decode(
                self.params, tokens, run_cache
            )
        else:
            self.cache["len"] = jnp.asarray(self.lengths, jnp.int32)
            logits, self.cache = self._decode(self.params, tokens, self.cache)
        if self.sc.temperature > 0 and rng is not None:
            toks = jax.random.categorical(rng, logits / self.sc.temperature)
        else:
            toks = jnp.argmax(logits, axis=-1)
        if n_fail is not None:
            toks, nf = jax.device_get((toks, n_fail))
            if int(nf):
                raise PagePoolExhaustedError(
                    f"no free KV block for {int(nf)} active slot(s) "
                    f"(num_blocks={self.num_blocks})"
                )
            # Commit the decode's pool writes only on a clean tick.
            self.cache = dict(
                self.cache, k=run_cache["k"], v=run_cache["v"]
            )
        toks = np.asarray(toks, np.int32)
        emitted = {}
        finished = np.zeros(self.active.shape, bool)
        for slot in np.flatnonzero(self.active):
            t = int(toks[slot])
            emitted[int(slot)] = t
            self.outputs[slot].append(t)
            self._last_tokens[slot] = t
            self.lengths[slot] += 1
            if t == self.sc.eos_token or self.lengths[slot] >= self.sc.max_len - 1:
                self.active[slot] = False
                finished[slot] = True
        if self.sc.paged and finished.any():
            # Masked jitted release: one dispatch frees every slot that
            # finished this tick.
            self._refcount, self._table = self._release_rows(
                self._refcount, self._table, jnp.asarray(finished)
            )
        return emitted

    def run(self, prompts: list[list[int]], max_ticks: int = 256):
        """Serve a list of prompts to completion; returns outputs per prompt."""
        pending = list(enumerate(prompts))
        slot_to_req: dict[int, int] = {}
        results: dict[int, list[int]] = {}
        ticks = 0
        while (pending or self.active.any()) and ticks < max_ticks:
            if pending:
                # One batched prefill admits every prompt a free slot can take.
                slots = self.add_requests([p for _, p in pending])
                n_admitted = 0
                for (req_id, _), slot in zip(pending, slots):
                    if slot is None:
                        break
                    slot_to_req[slot] = req_id
                    n_admitted += 1
                pending = pending[n_admitted:]
            before = self.active.copy()
            self.step()
            ticks += 1
            for slot in np.flatnonzero(before & ~self.active):
                results[slot_to_req[int(slot)]] = list(self.outputs[int(slot)])
        for slot, req in slot_to_req.items():
            if req not in results:
                results[req] = list(self.outputs[slot])
        return [results.get(i, []) for i in range(len(prompts))]
