"""Batched serving engine: slot-based continuous batching over decode_step.

The engine owns ``B`` request slots.  Incoming prompts are admitted into
free slots by ONE right-padded ragged batch prefill (``prefill_ragged`` —
each slot's cache fills at its own length); every tick runs one fused
``decode_step`` for all active slots; finished sequences (EOS / max length)
free their slot immediately — the serving-side analogue of the WU-UCT
async-slot scheduler (no slot ever waits for the longest request).

The per-slot cache layout (``len`` vector; rows ``>= len`` garbage until
overwritten) is the contract shared with
:class:`repro.core.evaluators.CachedModelEvaluator` — see the README's
"KV-cache contract" section.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import (
    KV_CACHE_FAMILIES,
    decode_step,
    init_cache,
    prefill,
    prefill_ragged,
)
from ..models.config import ModelConfig


@dataclasses.dataclass
class ServeConfig:
    batch_slots: int = 8
    max_len: int = 512
    temperature: float = 0.0     # 0 = greedy
    eos_token: int = 0


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, serve_cfg: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.sc = serve_cfg
        self.cache = init_cache(cfg, serve_cfg.batch_slots, serve_cfg.max_len)
        b = serve_cfg.batch_slots
        self.active = np.zeros(b, bool)
        self.lengths = np.zeros(b, np.int32)
        self.outputs: list[list[int]] = [[] for _ in range(b)]
        self._decode = jax.jit(
            lambda p, t, c: decode_step(p, cfg, t, c)
        )
        # Jitted once per engine (retraces only on new admission-batch
        # shapes), not once per add_requests call.
        self._prefill_ragged = jax.jit(
            lambda p, t, l, c: prefill_ragged(p, cfg, t, l, c)
        )
        self._prefill_one = jax.jit(lambda p, b, c: prefill(p, cfg, b, c))
        self._last_tokens = np.zeros(b, np.int32)

    def add_request(self, prompt_tokens: list[int]) -> Optional[int]:
        return self.add_requests([prompt_tokens])[0]

    def add_requests(
        self, prompts: list[list[int]]
    ) -> list[Optional[int]]:
        """Admit up to ``len(free slots)`` prompts with ONE batched prefill.

        KV-cache families right-pad the prompt batch to the longest prompt
        and run ``models.prefill_ragged`` — one forward fills every admitted
        slot's cache at its own length, and one scatter splices the slot
        block into the engine cache.  Recurrent-cache families (SSM/hybrid)
        cannot take right-padded ragged prefill (pad tokens would pollute
        the state), so they keep the per-prompt prefill loop.

        Returns one slot id (or ``None`` once slots ran out) per prompt, in
        order.
        """
        free = np.flatnonzero(~self.active)
        take = min(len(free), len(prompts))
        admitted: list[Optional[int]] = [None] * len(prompts)
        if take == 0:
            return admitted
        slots = free[:take].astype(np.int32)
        cfg, sc = self.cfg, self.sc
        if cfg.family in KV_CACHE_FAMILIES:
            lengths = np.asarray([len(p) for p in prompts[:take]], np.int32)
            max_p = int(lengths.max())
            toks = np.zeros((take, max_p), np.int32)
            for i, p in enumerate(prompts[:take]):
                toks[i, : len(p)] = p
            logits, cache_n = self._prefill_ragged(
                self.params, jnp.asarray(toks), jnp.asarray(lengths),
                init_cache(cfg, take, sc.max_len),
            )
            # One scatter splices all admitted slots into the engine cache
            # (layer-stacked leaves carry the slot axis at position 1).
            self.cache = jax.tree.map(
                lambda f, o: (
                    f.at[:, slots].set(o)
                    if hasattr(f, "ndim") and f.ndim > 1 else f
                ),
                self.cache,
                cache_n,
            )
            first = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        else:
            first = np.zeros(take, np.int32)
            for i, p in enumerate(prompts[:take]):
                cache1 = init_cache(cfg, 1, sc.max_len)
                batch = {"tokens": jnp.asarray(p, jnp.int32)[None]}
                logits, cache1 = self._prefill_one(self.params, batch, cache1)
                slot = int(slots[i])
                self.cache = jax.tree.map(
                    lambda f, o: (
                        f.at[:, slot].set(o[:, 0])
                        if hasattr(f, "ndim") and f.ndim > 1 else f
                    ),
                    self.cache,
                    cache1,
                )
                first[i] = int(jnp.argmax(logits[0]))
        for i in range(take):
            slot = int(slots[i])
            tok = int(first[i])
            self.active[slot] = True
            self.lengths[slot] = len(prompts[i])
            self.outputs[slot] = [tok]
            self._last_tokens[slot] = tok
            admitted[i] = slot
        # Per-slot cache lengths (vector `len`): each slot decodes at its own
        # position — the continuous-batching requirement.
        self.cache["len"] = jnp.asarray(self.lengths, jnp.int32)
        return admitted

    def step(self, rng: Optional[jax.Array] = None) -> dict[int, int]:
        """One decode tick for all active slots; returns {slot: new_token}."""
        if not self.active.any():
            return {}
        tokens = jnp.asarray(self._last_tokens, jnp.int32)
        self.cache["len"] = jnp.asarray(self.lengths, jnp.int32)
        logits, self.cache = self._decode(self.params, tokens, self.cache)
        if self.sc.temperature > 0 and rng is not None:
            toks = jax.random.categorical(rng, logits / self.sc.temperature)
        else:
            toks = jnp.argmax(logits, axis=-1)
        toks = np.asarray(toks, np.int32)
        emitted = {}
        for slot in np.flatnonzero(self.active):
            t = int(toks[slot])
            emitted[int(slot)] = t
            self.outputs[slot].append(t)
            self._last_tokens[slot] = t
            self.lengths[slot] += 1
            if t == self.sc.eos_token or self.lengths[slot] >= self.sc.max_len - 1:
                self.active[slot] = False
        return emitted

    def run(self, prompts: list[list[int]], max_ticks: int = 256):
        """Serve a list of prompts to completion; returns outputs per prompt."""
        pending = list(enumerate(prompts))
        slot_to_req: dict[int, int] = {}
        results: dict[int, list[int]] = {}
        ticks = 0
        while (pending or self.active.any()) and ticks < max_ticks:
            if pending:
                # One batched prefill admits every prompt a free slot can take.
                slots = self.add_requests([p for _, p in pending])
                n_admitted = 0
                for (req_id, _), slot in zip(pending, slots):
                    if slot is None:
                        break
                    slot_to_req[slot] = req_id
                    n_admitted += 1
                pending = pending[n_admitted:]
            before = self.active.copy()
            self.step()
            ticks += 1
            for slot in np.flatnonzero(before & ~self.active):
                results[slot_to_req[int(slot)]] = list(self.outputs[int(slot)])
        for slot, req in slot_to_req.items():
            if req not in results:
                results[req] = list(self.outputs[slot])
        return [results.get(i, []) for i in range(len(prompts))]
