"""Token-search service: many users' search requests, one batched program.

The serving-side consumer of the search front door: a batch of prompt
requests becomes ``B`` root states of one multi-root search
(``repro.core.build_searcher`` with ``spec.batch = B``), so every master
tick of the engine advances all users' searches together — and, with the
default :class:`~repro.core.evaluators.ModelEvaluator`, evaluates all their
in-flight rollout slots in **one** policy-LM forward (the flat ``[B·W]``
batch).  This is the WU-UCT analogue of continuous batching in
:mod:`repro.serving.engine`: throughput comes from batching across requests,
not from parallelizing one request harder.
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from ..core import SearchSpec, build_searcher
from ..core.evaluators import CachedModelEvaluator, Evaluator, ModelEvaluator
from ..envs.token_env import TokenEnvState, make_token_env
from ..models import forward
from ..models.config import ModelConfig


def _prefix_sharing_pool_blocks(
    slots: int, max_len: int, block_size: int
) -> int:
    """Default paged-pool size informed by measured prefix sharing.

    The dense-equivalent bound ``slots * num_pages`` assumes no page is ever
    shared, but the committed ``paged_ceiling_*`` benchmark rows measure the
    real peak working set of searches with sibling prefix sharing
    (``ceiling_ratio`` = dense positions / peak paged positions).  Size the
    pool to the dense bound shrunk by the WORST measured ratio, plus 25%
    headroom — shallow searches share the least, so the minimum ratio is the
    conservative choice.  Any failure to read the benchmark file falls back
    to the dense bound.
    """
    from ..models import num_pages

    dense = slots * num_pages(max_len, block_size)
    try:
        path = Path(__file__).resolve().parents[3] / "BENCH_model_eval.json"
        rows = json.loads(path.read_text())["rows"]
        ratio = min(
            r["ceiling_ratio"] for r in rows if r["kind"] == "batch_ceiling"
        )
        if not ratio > 1.0:
            return dense
        shrunk = int(dense / ratio * 1.25) + 1
        return max(1, min(dense, shrunk))
    except Exception:
        return dense


class SearchService:
    """Batched WU-UCT token search behind a prompt-in / token-out interface.

    ``spec.batch`` fixes the request-slot count (one compiled program);
    shorter request lists are padded with repeats and the padding results
    dropped.  ``evaluator=None`` builds the best evaluator the spec
    supports: a :class:`CachedModelEvaluator` on async engines with a
    KV-cache model family (every master tick costs one batched
    ``decode_step``, not one full-prefix forward), falling back to the
    uncached :class:`ModelEvaluator` otherwise — pass an explicit evaluator
    (e.g. a ``RolloutEvaluator`` over the token env) to switch evaluation
    modes without touching the engine.
    """

    def __init__(
        self,
        model_cfg: ModelConfig,
        params,
        spec: SearchSpec,
        *,
        top_k: int = 8,
        max_len: int = 64,
        eos_token: int = 0,
        reward_cfg: Optional[ModelConfig] = None,
        reward_params=None,
        evaluator: Optional[Evaluator] = None,
        paged: bool = False,
        block_size: int = 16,
        num_blocks: Optional[int] = None,
    ):
        if spec.batch <= 0:
            raise ValueError("SearchService needs a batched spec (batch > 0)")
        self.cfg = model_cfg
        self.params = params
        self.spec = spec
        self.top_k = top_k
        self.max_len = max_len
        # The env's prompt only seeds env.init, which the service bypasses
        # (roots are built from the request prompts directly).
        env = make_token_env(
            model_cfg, params, jnp.zeros((1,), jnp.int32), max_len=max_len,
            top_k=top_k, eos_token=eos_token,
            reward_cfg=reward_cfg, reward_params=reward_params,
        )
        if evaluator is None:
            families = {model_cfg.family} | (
                {reward_cfg.family} if reward_cfg is not None else set()
            )
            from ..models import KV_CACHE_FAMILIES

            cacheable = (
                spec.engine == "async" and families <= set(KV_CACHE_FAMILIES)
            )
            if paged and not cacheable:
                raise ValueError(
                    "paged=True needs an async-engine spec and a KV-cache "
                    f"model family, got engine={spec.engine!r} "
                    f"families={sorted(families)}"
                )
            kwargs = dict(
                top_k=top_k, eos_token=eos_token,
                reward_cfg=reward_cfg, reward_params=reward_params,
            )
            if paged:
                from ..core.evaluators import PagedCachedModelEvaluator

                slots = spec.batch * spec.wave_size
                if num_blocks is None:
                    # Prefix-sharing-aware default: the dense-equivalent
                    # bound shrunk by the measured paged_ceiling_* sharing
                    # ratio (with headroom); see _prefix_sharing_pool_blocks.
                    num_blocks = _prefix_sharing_pool_blocks(
                        slots, max_len, block_size
                    )
                evaluator = PagedCachedModelEvaluator(
                    model_cfg, params, block_size=block_size,
                    num_blocks=num_blocks, **kwargs,
                )
            else:
                ev_cls = CachedModelEvaluator if cacheable else ModelEvaluator
                evaluator = ev_cls(model_cfg, params, **kwargs)
        self.env = env
        self.evaluator = evaluator
        self._search = build_searcher(env, spec, evaluator=evaluator)

    def _roots(self, prompts: Sequence[Sequence[int]]) -> TokenEnvState:
        B = self.spec.batch
        if not prompts:
            raise ValueError("need at least one prompt")
        if len(prompts) > B:
            raise ValueError(f"got {len(prompts)} prompts for batch={B}")
        too_long = [i for i, p in enumerate(prompts) if len(p) >= self.max_len]
        if too_long:
            raise ValueError(
                f"prompts {too_long} have length >= max_len={self.max_len}; "
                "leave room for at least one generated token"
            )
        padded = list(prompts) + [prompts[0]] * (B - len(prompts))
        tokens = jnp.zeros((B, self.max_len), jnp.int32)
        lengths = []
        for i, p in enumerate(padded):
            tokens = tokens.at[i, : len(p)].set(jnp.asarray(p, jnp.int32))
            lengths.append(len(p))
        return TokenEnvState(
            tokens=tokens,
            length=jnp.asarray(lengths, jnp.int32),
            done=jnp.zeros((B,), jnp.bool_),
        )

    def search(self, prompts: Sequence[Sequence[int]], key: jax.Array):
        """Run one batched search; returns the ``SearchResult`` (leading
        ``[B]``; rows past ``len(prompts)`` are padding)."""
        roots = self._roots(prompts)
        return self._search(roots, jax.random.split(key, self.spec.batch))

    def decide(self, prompts: Sequence[Sequence[int]], key: jax.Array):
        """Search + decode: the searched next token for every prompt.

        Actions are ranks into the policy's top-K at each prompt's current
        position; one batched forward maps them back to vocabulary ids.
        """
        n = len(prompts)
        roots = self._roots(prompts)
        res = self._search(roots, jax.random.split(key, self.spec.batch))
        logits, _ = forward(self.params, self.cfg, {"tokens": roots.tokens})
        pos = jnp.maximum(roots.length - 1, 0)
        at_pos = jnp.take_along_axis(logits, pos[:, None, None], axis=1)[:, 0]
        _, top_idx = jax.lax.top_k(at_pos, self.top_k)
        ranks = jnp.clip(res.action, 0, self.top_k - 1)
        tokens = jnp.take_along_axis(top_idx, ranks[:, None], axis=1)[:, 0]
        return [int(t) for t in tokens[:n]], res
