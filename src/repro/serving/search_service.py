"""Token-search service: many users' search requests, one batched program.

The serving-side consumer of the search front door: a batch of prompt
requests becomes ``B`` root states of one multi-root search
(``repro.core.build_searcher`` with ``spec.batch = B``), so every master
tick of the engine advances all users' searches together — and, with the
default :class:`~repro.core.evaluators.ModelEvaluator`, evaluates all their
in-flight rollout slots in **one** policy-LM forward (the flat ``[B·W]``
batch).  This is the WU-UCT analogue of continuous batching in
:mod:`repro.serving.engine`: throughput comes from batching across requests,
not from parallelizing one request harder.

Two serving shapes:

* :meth:`SearchService.search` / :meth:`~SearchService.decide` — one-shot:
  admit a prompt batch, run it to completion, return.  Settled roots idle
  until the slowest finishes.
* :meth:`SearchService.submit` + :meth:`~SearchService.drain` (or
  :meth:`~SearchService.serve` over a request stream) — continuous: a
  persistent :class:`repro.core.batched_async_search.BatchedAsyncEngine`
  keeps all ``B`` tree rows searching, and whenever a row settles the next
  queued request is spliced into it mid-stream (tree reset, RNG lane, and
  evaluator KV slot caches re-seeded through the shared
  :mod:`repro.serving.admission` path).  :class:`ServeStats` reports the
  occupancy this buys — the slot-idle fraction the one-shot path wastes.
"""

from __future__ import annotations

import dataclasses
import heapq
import json
import os
import warnings
from pathlib import Path
from typing import Iterable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core import SearchResult, SearchSpec, build_searcher
from ..core.api import as_search_config
from ..core.evaluators import CachedModelEvaluator, Evaluator, ModelEvaluator
from ..envs.token_env import TokenEnvState, make_token_env
from ..models import forward
from ..models.config import ModelConfig
from .admission import pages_needed, validate_prompts

#: Environment variable overriding where the committed benchmark baseline
#: (``BENCH_model_eval.json``) is read from for the paged-pool default.
BENCH_BASELINE_ENV = "REPRO_BENCH_BASELINE"

_pool_fallback_warned = False


class InvalidSearchActionError(RuntimeError):
    """A search returned an action outside ``[0, top_k)``.

    Actions are ranks into the policy's top-K table; an out-of-range value
    (e.g. ``-1`` from a search that never visited the root's children) has
    no token to map to.  Surfacing it beats the old behaviour of clipping
    into range, which made a failed search indistinguishable from a
    confident greedy top-1 pick.
    """


def _bench_baseline_path() -> Optional[Path]:
    """Locate the committed ``BENCH_model_eval.json`` baseline.

    Order: the :data:`BENCH_BASELINE_ENV` env var (points at the file), then
    a walk up from this module's directory (the repo-checkout layout), then
    a walk up from the current working directory (installed/site-packages
    layouts running inside a checkout).  Returns ``None`` when nothing is
    found.
    """
    env_path = os.environ.get(BENCH_BASELINE_ENV)
    if env_path:
        p = Path(env_path)
        if p.is_file():
            return p
    seen = set()
    for base in (Path(__file__).resolve().parent, Path.cwd().resolve()):
        for parent in (base, *base.parents):
            if parent in seen:
                continue
            seen.add(parent)
            cand = parent / "BENCH_model_eval.json"
            if cand.is_file():
                return cand
    return None


def _prefix_sharing_pool_blocks(
    slots: int, max_len: int, block_size: int
) -> int:
    """Default paged-pool size informed by measured prefix sharing.

    The dense-equivalent bound ``slots * num_pages`` assumes no page is ever
    shared, but the committed ``paged_ceiling_*`` benchmark rows measure the
    real peak working set of searches with sibling prefix sharing
    (``ceiling_ratio`` = dense positions / peak paged positions).  Size the
    pool to the dense bound shrunk by the WORST measured ratio, plus 25%
    headroom — shallow searches share the least, so the minimum ratio is the
    conservative choice.  When the baseline file cannot be found or parsed
    (see :func:`_bench_baseline_path` for the lookup order), fall back to
    the dense bound and warn once.
    """
    global _pool_fallback_warned
    from ..models import num_pages

    dense = slots * num_pages(max_len, block_size)
    path = _bench_baseline_path()
    ratios = None
    if path is not None:
        try:
            rows = json.loads(path.read_text())["rows"]
            ratios = [
                float(r["ceiling_ratio"])
                for r in rows
                if r.get("kind") == "batch_ceiling" and "ceiling_ratio" in r
            ]
        except (OSError, ValueError, KeyError, TypeError) as e:
            warnings.warn(
                f"could not parse benchmark baseline {path}: {e!r}; "
                "using the dense paged-pool bound",
                stacklevel=2,
            )
            return dense
    if not ratios:
        if not _pool_fallback_warned:
            _pool_fallback_warned = True
            warnings.warn(
                "no BENCH_model_eval.json baseline with batch_ceiling rows "
                f"found (set ${BENCH_BASELINE_ENV} to point at one); using "
                "the dense paged-pool bound",
                stacklevel=2,
            )
        return dense
    ratio = min(ratios)
    if not ratio > 1.0:
        return dense
    shrunk = int(dense / ratio * 1.25) + 1
    return max(1, min(dense, shrunk))


@dataclasses.dataclass
class ServeStats:
    """Occupancy/admission counters for the continuous-serving path.

    ``busy_tree_ticks`` counts (tree row, master tick) pairs where the row
    was actively searching; ``ticks * batch`` is the capacity, so
    :attr:`slot_idle_frac` is the fraction of row-ticks spent idle — the
    quantity slot-level admission exists to minimize (a one-shot batch
    wastes the whole tail where settled roots wait for the slowest).
    """

    batch: int = 0
    submitted: int = 0
    completed: int = 0
    admissions: int = 0
    ticks: int = 0
    busy_tree_ticks: int = 0
    #: Host round-trips into the serving loop: one per :meth:`poll` on the
    #: host-paced path, one per fused ``serve_segment`` on the ring path —
    #: the quantity the device-resident loop exists to shrink.
    host_rounds: int = 0
    #: Sum over host rounds of the ring occupancy at segment dispatch
    #: (fused path only); :attr:`ring_occupancy` is the mean.
    ring_occupancy_sum: int = 0

    @property
    def slot_idle_frac(self) -> float:
        cap = self.ticks * self.batch
        if cap == 0:
            return 0.0
        return 1.0 - self.busy_tree_ticks / cap

    @property
    def ring_occupancy(self) -> float:
        """Mean staged requests per fused host round (0 when host-paced)."""
        if self.host_rounds == 0:
            return 0.0
        return self.ring_occupancy_sum / self.host_rounds


class SearchService:
    """Batched WU-UCT token search behind a prompt-in / token-out interface.

    ``spec.batch`` fixes the request-slot count (one compiled program);
    shorter request lists are padded with repeats and the padding results
    dropped.  ``evaluator=None`` builds the best evaluator the spec
    supports: a :class:`CachedModelEvaluator` on async engines with a
    KV-cache model family (every master tick costs one batched
    ``decode_step``, not one full-prefix forward), falling back to the
    uncached :class:`ModelEvaluator` otherwise — pass an explicit evaluator
    (e.g. a ``RolloutEvaluator`` over the token env) to switch evaluation
    modes without touching the engine.

    ``ticks_per_round`` paces the continuous path: each :meth:`poll` runs at
    most that many master ticks before the host harvests settled rows and
    admits queued requests (smaller = settled rows idle less, more host
    round-trips).
    """

    def __init__(
        self,
        model_cfg: ModelConfig,
        params,
        spec: SearchSpec,
        *,
        top_k: int = 8,
        max_len: int = 64,
        eos_token: int = 0,
        reward_cfg: Optional[ModelConfig] = None,
        reward_params=None,
        evaluator: Optional[Evaluator] = None,
        paged: bool = False,
        block_size: int = 16,
        num_blocks: Optional[int] = None,
        ticks_per_round: int = 8,
        fused: bool = True,
        ring_capacity: Optional[int] = None,
        ticks_per_segment: Optional[int] = None,
    ):
        if spec.batch <= 0:
            raise ValueError("SearchService needs a batched spec (batch > 0)")
        if ticks_per_round < 1:
            raise ValueError(
                f"ticks_per_round must be >= 1, got {ticks_per_round}"
            )
        self.cfg = model_cfg
        self.params = params
        self.spec = spec
        self.top_k = top_k
        self.max_len = max_len
        self.paged = paged
        self.ticks_per_round = ticks_per_round
        self.fused = fused
        self.ring_capacity = (
            int(ring_capacity) if ring_capacity is not None
            else max(1, spec.batch)
        )
        self.ticks_per_segment = (
            int(ticks_per_segment) if ticks_per_segment is not None
            else 8 * ticks_per_round
        )
        if self.ring_capacity < 1:
            raise ValueError(
                f"ring_capacity must be >= 1, got {ring_capacity}"
            )
        if self.ticks_per_segment < 1:
            raise ValueError(
                f"ticks_per_segment must be >= 1, got {ticks_per_segment}"
            )
        # The env's prompt only seeds env.init, which the service bypasses
        # (roots are built from the request prompts directly).
        env = make_token_env(
            model_cfg, params, jnp.zeros((1,), jnp.int32), max_len=max_len,
            top_k=top_k, eos_token=eos_token,
            reward_cfg=reward_cfg, reward_params=reward_params,
        )
        if evaluator is None:
            families = {model_cfg.family} | (
                {reward_cfg.family} if reward_cfg is not None else set()
            )
            from ..models import KV_CACHE_FAMILIES

            cacheable = (
                spec.engine == "async" and families <= set(KV_CACHE_FAMILIES)
            )
            if paged and not cacheable:
                raise ValueError(
                    "paged=True needs an async-engine spec and a KV-cache "
                    f"model family, got engine={spec.engine!r} "
                    f"families={sorted(families)}"
                )
            kwargs = dict(
                top_k=top_k, eos_token=eos_token,
                reward_cfg=reward_cfg, reward_params=reward_params,
            )
            if paged:
                from ..core.evaluators import PagedCachedModelEvaluator

                slots = spec.batch * spec.wave_size
                if num_blocks is None:
                    # Prefix-sharing-aware default: the dense-equivalent
                    # bound shrunk by the measured paged_ceiling_* sharing
                    # ratio (with headroom); see _prefix_sharing_pool_blocks.
                    num_blocks = _prefix_sharing_pool_blocks(
                        slots, max_len, block_size
                    )
                evaluator = PagedCachedModelEvaluator(
                    model_cfg, params, block_size=block_size,
                    num_blocks=num_blocks, **kwargs,
                )
            else:
                ev_cls = CachedModelEvaluator if cacheable else ModelEvaluator
                evaluator = ev_cls(model_cfg, params, **kwargs)
        self.env = env
        self.evaluator = evaluator
        self._search = build_searcher(env, spec, evaluator=evaluator)

        # --- continuous-serving state (built lazily on first submit) ------
        self.stats = ServeStats(batch=spec.batch)
        self._engine = None
        self._carry = None
        # Priority-then-FIFO heap of (-priority, req_id, prompt, key):
        # req_id is monotonic, so equal priorities pop in submission order.
        self._queue: list = []
        self._results: dict = {}           # req_id -> per-request SearchResult
        self._row_req: list = [None] * spec.batch
        self._next_req_id = 0
        self._base_key = jax.random.PRNGKey(0)
        # Fused-path host mirrors (exact: every device-side transition is
        # accounted from the per-round staged/admitted/completed counts).
        self._ring = None
        self._row_req_dev = None
        self._ring_free = self.ring_capacity
        self._inflight = 0

    # ------------------------------------------------------------------
    # Root-state packing
    # ------------------------------------------------------------------
    def _root_rows(self, prompts: Sequence[Sequence[int]]) -> TokenEnvState:
        """Pack ``R`` prompts into an ``[R]``-leading root-state batch."""
        validate_prompts(prompts, self.max_len)
        r = len(prompts)
        tokens = np.zeros((r, self.max_len), np.int32)
        lengths = np.zeros((r,), np.int32)
        for i, p in enumerate(prompts):
            tokens[i, : len(p)] = p
            lengths[i] = len(p)
        return TokenEnvState(
            tokens=jnp.asarray(tokens),
            length=jnp.asarray(lengths),
            done=jnp.zeros((r,), jnp.bool_),
        )

    def _roots(self, prompts: Sequence[Sequence[int]]) -> TokenEnvState:
        B = self.spec.batch
        if not prompts:
            raise ValueError("need at least one prompt")
        if len(prompts) > B:
            raise ValueError(f"got {len(prompts)} prompts for batch={B}")
        return self._root_rows(list(prompts) + [prompts[0]] * (B - len(prompts)))

    # ------------------------------------------------------------------
    # One-shot serving
    # ------------------------------------------------------------------
    def search(self, prompts: Sequence[Sequence[int]], key: jax.Array):
        """Run one batched search; returns the ``SearchResult`` (leading
        ``[B]``; rows past ``len(prompts)`` are padding)."""
        roots = self._roots(prompts)
        return self._search(roots, jax.random.split(key, self.spec.batch))

    def decide(self, prompts: Sequence[Sequence[int]], key: jax.Array):
        """Search + decode: the searched next token for every prompt.

        Actions are ranks into the policy's top-K at each prompt's current
        position; one batched forward maps them back to vocabulary ids.  A
        search that returns an out-of-range action (e.g. ``-1``) raises
        :class:`InvalidSearchActionError` — clipping it into range would
        silently serve the greedy top-1 token for a failed search.
        """
        n = len(prompts)
        roots = self._roots(prompts)
        res = self._search(roots, jax.random.split(key, self.spec.batch))
        actions = np.asarray(res.action)
        bad = [
            (i, int(actions[i]))
            for i in range(n)
            if not 0 <= int(actions[i]) < self.top_k
        ]
        if bad:
            raise InvalidSearchActionError(
                f"search returned out-of-range action(s) {bad}; actions are "
                f"ranks into the policy top-{self.top_k} table (the search "
                "may not have completed any simulation from these roots)"
            )
        logits, _ = forward(self.params, self.cfg, {"tokens": roots.tokens})
        pos = jnp.maximum(roots.length - 1, 0)
        at_pos = jnp.take_along_axis(logits, pos[:, None, None], axis=1)[:, 0]
        _, top_idx = jax.lax.top_k(at_pos, self.top_k)
        # Clip only for the gather: rows >= n are padding (never validated,
        # never returned); rows < n were validated in range above.
        ranks = jnp.clip(res.action, 0, self.top_k - 1)
        tokens = jnp.take_along_axis(top_idx, ranks[:, None], axis=1)[:, 0]
        return [int(t) for t in tokens[:n]], res

    # ------------------------------------------------------------------
    # Continuous serving: persistent engine + slot-level admission
    # ------------------------------------------------------------------
    def _ensure_engine(self):
        if self._engine is not None:
            return
        if self.spec.engine != "async":
            raise ValueError(
                "continuous serving (submit/poll/drain/serve) needs an "
                f"async-engine spec, got engine={self.spec.engine!r}"
            )
        from ..core.batched_async_search import BatchedAsyncEngine

        B = self.spec.batch
        engine = BatchedAsyncEngine(
            self.env, as_search_config(self.spec), B,
            evaluator=self.evaluator, use_kernel=self.spec.use_kernel,
        )
        # All rows born idle around a placeholder root; evict immediately so
        # paged placeholders hold no pool pages while waiting for requests.
        roots = self._root_rows([[0]] * B)
        carry = engine.init_carry(
            roots, jax.random.split(jax.random.PRNGKey(0), B),
            active=jnp.zeros((B,), bool),
        )
        carry = engine.evict(carry, jnp.arange(B, dtype=jnp.int32))
        self._engine = engine
        self._carry = carry
        self._segment = jax.jit(
            lambda c: engine.run_segment(c, self.ticks_per_round)
        )
        self._result_fn = jax.jit(engine.result)
        # The service always admits/evicts ONE row per call: `rows` keeps a
        # fixed [1] shape, so these trace exactly once — a variable-size
        # admission batch would recompile the whole splice (prefill included)
        # for every distinct batch size it ever saw.
        self._admit_fn = jax.jit(engine.admit)
        self._evict_fn = jax.jit(engine.evict)
        if self.fused:
            # Device-resident ring: stage() keeps a fixed [1] request shape
            # per call (same single-signature discipline as admit/evict);
            # serve_segment fuses harvest + admission into the while_loop,
            # so the host pays ONE dispatch + ONE sync per segment.
            self._ring = engine.init_ring(roots, self.ring_capacity)
            self._row_req_dev = jnp.full((B,), -1, jnp.int32)
            self._stage_fn = jax.jit(engine.stage)
            self._serve_fn = jax.jit(
                lambda c, g, q: engine.serve_segment(
                    c, g, q, self.ticks_per_segment
                )
            )

    def _free_pool_blocks(self) -> Optional[int]:
        """Free blocks in the paged evaluator's pool (None when dense)."""
        if not self.paged:
            return None
        aux = self._carry[7]
        return int(self.evaluator.num_blocks - jnp.sum(aux["refcount"] > 0))

    def submit(
        self,
        prompt: Sequence[int],
        key: Optional[jax.Array] = None,
        priority: int = 0,
    ):
        """Queue one search request; returns its request id.

        ``key`` seeds the request's tree row (defaults to a fold of the
        service key and the request id).  ``priority`` orders the queue:
        higher values admit first, ties break FIFO by submission order
        (the pre-existing behaviour is the all-zero default).  The request
        runs when a row settles — call :meth:`poll` to make progress or
        :meth:`drain` to block until everything queued has finished.
        """
        validate_prompts([prompt], self.max_len)
        req_id = self._next_req_id
        self._next_req_id += 1
        if key is None:
            key = jax.random.fold_in(self._base_key, req_id)
        heapq.heappush(
            self._queue, (-int(priority), req_id, list(prompt), key)
        )
        self.stats.submitted += 1
        return req_id

    def _settled(self) -> np.ndarray:
        """Host copy of the per-row settled mask (ONE device sync)."""
        return np.asarray(self._engine.settled(self._carry))

    def _harvest(self, settled: Optional[np.ndarray] = None) -> dict:
        """Collect results from settled occupied rows; free the rows."""
        carry = self._carry
        if settled is None:
            settled = self._settled()
        done_rows = [
            b for b in range(self.spec.batch)
            if settled[b] and self._row_req[b] is not None
        ]
        fresh = {}
        if done_rows:
            # One device->host transfer for the whole batch; per-request
            # rows are host-side slices.
            res = jax.tree.map(np.asarray, self._result_fn(carry))
            for b in done_rows:
                req_id = self._row_req[b]
                # Host-side slicing of an already-fetched numpy tree — no
                # device dispatch despite the jax.tree.map spelling.
                # reprolint: disable=JX002
                row = jax.tree.map(lambda x: x[b], res)
                self._results[req_id] = row
                fresh[req_id] = row
                self._row_req[b] = None
                self.stats.completed += 1
            # Return the rows' pages to the pool before anything new is
            # admitted (a no-op for dense caches).  One row per call keeps
            # the jitted evict at a single compiled shape.
            for b in done_rows:
                # Deliberate per-row dispatch: a fixed [1]-shape rows vector
                # keeps the jitted evict at ONE compiled signature (the
                # variable-shape alternative was PR 8's 30x regression), and
                # done_rows is bounded by the small host-side batch B.
                # reprolint: disable=JX002
                self._carry = self._evict_fn(
                    self._carry, jnp.asarray([b], jnp.int32)
                )
        return fresh

    def _admit_queued(self, settled: Optional[np.ndarray] = None) -> int:
        """Splice queued requests into free rows (paged: admit-fewer)."""
        if settled is None:
            settled = self._settled()
        free_rows = [
            b for b in range(self.spec.batch)
            if settled[b] and self._row_req[b] is None
        ]
        if not free_rows or not self._queue:
            return 0
        budget = self._free_pool_blocks()
        admitted = 0
        for b in free_rows:
            if not self._queue:
                break
            _, req_id, prompt, key = self._queue[0]
            if budget is not None:
                need = pages_needed(len(prompt), self.evaluator.block_size)
                if need > budget:
                    break  # wait for pages to free (admit in order)
                budget -= need
            heapq.heappop(self._queue)
            # Deliberate per-row admission dispatch (same reasoning as the
            # evict loop in _harvest): fixed [1]-shape rows keep the jitted
            # admit at one compiled signature; issubdtype is metadata-only.
            # reprolint: disable=JX002
            if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
                key = jax.random.key_data(key)
            self._carry = self._admit_fn(
                self._carry, jnp.asarray([b], jnp.int32),
                self._root_rows([prompt]), key[None],
            )
            self._row_req[b] = req_id
            admitted += 1
        if admitted and self.paged:
            # admit ran jitted, so pool exhaustion latched instead of
            # raising; surface it here at the eager boundary.
            self.evaluator.check_exhausted(self._carry[7])
        self.stats.admissions += admitted
        return admitted

    def poll(self) -> dict:
        """One serving round; returns the requests that finished in it
        (``{req_id: SearchResult row}``; results also accumulate in
        :attr:`results`).

        Host-paced (``fused=False``): harvest settled rows, admit queued
        requests, advance the engine up to ``ticks_per_round`` master ticks
        — several dispatches and syncs per round.  Fused (the default):
        stage queued requests into the device-resident ring, dispatch ONE
        ``serve_segment`` (up to ``ticks_per_segment`` ticks with harvest +
        admission inside the ``while_loop``), and drain the completion
        buffer — one host round per segment.
        """
        self._ensure_engine()
        if self.fused:
            return self._poll_fused()
        settled = self._settled()
        fresh = self._harvest(settled)
        # Harvest freed rows but left them settled; the same host mask
        # serves admission (one device sync per round, not three).
        self._admit_queued(settled)
        if any(r is not None for r in self._row_req):
            self._carry, t, busy = self._segment(self._carry)
            self.stats.ticks += int(t)
            self.stats.busy_tree_ticks += int(busy)
        self.stats.host_rounds += 1
        return fresh

    def _poll_fused(self) -> dict:
        """One fused round: refill the ring, run one segment, drain
        completions.  The only device syncs are the paged pool budget (when
        staging) and the single post-segment fetch."""
        budget = self._free_pool_blocks()
        while self._queue and self._ring_free > 0:
            _, req_id, prompt, key = self._queue[0]
            if budget is not None:
                need = pages_needed(len(prompt), self.evaluator.block_size)
                if need > budget:
                    break  # wait for pages to free (admit in order)
                budget -= need
            heapq.heappop(self._queue)
            # Deliberate per-request staging dispatch: a fixed [1]-shape
            # request keeps the jitted stage at ONE compiled signature (the
            # variable-shape alternative was PR 8's 30x regression), and
            # the loop is bounded by the small host-side ring capacity.
            # reprolint: disable=JX002
            if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
                key = jax.random.key_data(key)
            self._carry, self._ring = self._stage_fn(
                self._carry, self._ring, self._root_rows([prompt]),
                key[None], jnp.asarray([req_id], jnp.int32),
            )
            self._ring_free -= 1
        staged = self.ring_capacity - self._ring_free
        fresh = {}
        if staged > 0 or self._inflight > 0:
            out = self._serve_fn(self._carry, self._ring, self._row_req_dev)
            self._carry, self._ring, self._row_req_dev = out[:3]
            comp, t, busy = out[3:]
            oom = self._carry[7]["oom"] if self.paged else 0
            comp, t, busy, count_after, oom = jax.device_get(
                (comp, t, busy, self._ring.count, oom)
            )
            if self.paged:
                self.evaluator._maybe_raise(oom)
            n = int(comp.count)
            for i in range(n):
                req_id = int(comp.req_id[i])
                # Host-side slicing of the already-fetched completion buffer
                # (device_get above) — no device dispatch in this loop.
                # reprolint: disable=JX002
                row = SearchResult(
                    action=comp.action[i], root_n=comp.root_n[i],
                    root_v=comp.root_v[i], tree_size=comp.tree_size[i],
                    dup_selections=np.float32(0.0), max_o=comp.max_o[i],
                    overflowed=comp.overflowed[i], ticks=comp.ticks[i],
                )
                self._results[req_id] = row
                fresh[req_id] = row
            admitted = staged - int(count_after)
            self._ring_free = self.ring_capacity - int(count_after)
            self._inflight += admitted - n
            self.stats.admissions += admitted
            self.stats.completed += n
            self.stats.ticks += int(t)
            self.stats.busy_tree_ticks += int(busy)
        self.stats.host_rounds += 1
        self.stats.ring_occupancy_sum += staged
        return fresh

    def drain(self, max_rounds: int = 100_000) -> dict:
        """Poll until every submitted request has a result; return them all.

        ``max_rounds`` bounds the loop against a wedged engine (e.g. a
        paged pool too small for even one queued prompt)."""
        self._ensure_engine()
        for _ in range(max_rounds):
            if not self._queue and self._in_flight() == 0:
                break
            before = (len(self._queue), self._in_flight(), self.stats.ticks)
            self.poll()
            after = (len(self._queue), self._in_flight(), self.stats.ticks)
            if after == before:
                raise RuntimeError(
                    f"serving made no progress (queue={after[0]}, "
                    f"in flight={after[1]}); paged pool too small for the "
                    "queued prompts?"
                )
        else:
            raise RuntimeError(f"drain exceeded {max_rounds} rounds")
        if not self.fused:
            # One last harvest: the final segment may have settled rows.
            # (The fused loop harvests in-loop; its completions drained in
            # poll.)
            self._harvest()
        return dict(self._results)

    def _in_flight(self) -> int:
        """Requests past the queue but short of a result (host-side)."""
        if self.fused:
            staged = self.ring_capacity - self._ring_free
            return self._inflight + staged
        return sum(r is not None for r in self._row_req)

    def serve(
        self,
        prompt_stream: Iterable[Sequence[int]],
        keys: Optional[Sequence[jax.Array]] = None,
    ) -> list:
        """Serve a (possibly ragged) request stream to completion.

        Each prompt is submitted and a :meth:`poll` round runs between
        arrivals — requests admit into rows as earlier searches settle, so
        arrival order interleaves with completion order exactly like real
        traffic.  Returns per-request ``SearchResult`` rows in submission
        order.
        """
        ids = []
        for i, prompt in enumerate(prompt_stream):
            key = keys[i] if keys is not None else None
            ids.append(self.submit(prompt, key=key))
            self.poll()
        results = self.drain()
        return [results[i] for i in ids]

    @property
    def results(self) -> dict:
        """All completed requests so far (``{req_id: SearchResult row}``)."""
        return dict(self._results)
