from .admission import PromptTooLongError, pack_prompts, validate_prompts
from .engine import ServeConfig, ServingEngine
from .search_service import InvalidSearchActionError, SearchService, ServeStats

__all__ = [
    "InvalidSearchActionError",
    "PromptTooLongError",
    "SearchService",
    "ServeConfig",
    "ServeStats",
    "ServingEngine",
    "pack_prompts",
    "validate_prompts",
]
