from .engine import ServeConfig, ServingEngine
from .search_service import SearchService

__all__ = ["SearchService", "ServeConfig", "ServingEngine"]
