"""Batched async-slot WU-UCT: ``B`` independent async searches, one program.

:mod:`batched_search` batches the *wave* engine (barrier per wave); this
module batches :func:`repro.core.async_search.run_async_search` — the engine
that reproduces the paper's master–worker interleaving, where rollouts settle
at different ticks and a freed slot is refilled immediately.  ``B`` trees ×
``W`` async slots advance inside one jitted ``lax.while_loop``:

* **slot ticks** are vmapped over the flat ``[B·W]`` axis, so every busy
  slot's environment step forms a single batch — exactly the shape a future
  policy/value-network forward pass wants (one model call per master tick);
* **refills** route selection through the fused Pallas ``tree_select``
  kernel as ``[B, A]`` scoring calls (:func:`batched_search.traverse_batched`);
* **bookkeeping** uses the masked batched ``_mark_in_flight`` / ``_settle``
  variants in :mod:`batched_tree` — because settles land at different ticks
  per tree, every update carries a per-tree mask;
* **RNG streams** are carried per tree with the same split structure as the
  single engine, so the output is *bit-identical* to
  ``jax.vmap(run_async_search)`` (tested in
  ``tests/test_batched_async_search.py``).  The win over plain ``vmap`` is
  structural: ``vmap`` of the single engine turns every per-slot
  ``lax.cond`` into a select over the whole tree pytree (O(B·M) memory
  traffic per slot refill), while this engine performs masked row updates.

The engine is exposed two ways:

* :func:`run_async_search_batched` — the one-shot API: admit a batch of
  roots, run every tree to its simulation budget, return ``SearchResult[B]``;
* :class:`BatchedAsyncEngine` — the *persistent* form the serving layer
  drives: the same master tick, but the carry outlives any single request.
  When a tree settles (its ``t_done`` hits the budget) the engine's
  :meth:`~BatchedAsyncEngine.step` freezes that row; the host then splices a
  queued request into the row **mid-stream** via
  :meth:`~BatchedAsyncEngine.admit` — fresh tree, fresh per-tree RNG lane,
  fresh evaluator slot caches (``Evaluator.admit_aux``: dense KV re-prefill
  + cache splice, or paged page-table splice + refcount fan-out) — while the
  other ``B-1`` rows keep searching.  Because every per-row computation
  (traversal scoring, top-k, the Pallas ``[B, A]`` kernel, per-tree RNG
  splits) is row-independent, an admitted request's search is equivalent to
  the same request served in a fresh batch (``tests/test_serving_continuous``
  asserts visit-mass parity).

The flat ``[B·W]`` slot axis and the ``[B]`` tree axis both shard over the
``('pod', 'data')`` mesh axes — pass
:func:`repro.distributed.sharding.constrain_search_batch` as ``constrain``.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..envs.base import Environment
from . import batched_tree as btree
from .async_search import EXPAND, FREE, SIM, tick_snapshot
from .evaluators import Evaluator, RolloutEvaluator
from .batched_search import (
    _canonical_keys,
    _expansion_actions,
    _mark_in_flight,
    _settle,
    _split_each,
    traverse_batched,
)
from .batched_tree import init_batched_tree
from .wu_uct import SearchConfig, SearchResult

Pytree = Any


class _BatchedAsyncSlots(NamedTuple):
    kind: jax.Array          # i32[B, W]  FREE / EXPAND / SIM
    sim_node: jax.Array      # i32[B, W]  node being evaluated
    act: jax.Array           # i32[B, W]  expansion action (EXPAND phase)
    state: Pytree            # pytree[B, W, ...] current rollout env state
    rollout_done: jax.Array  # bool[B, W]
    acc: jax.Array           # f32[B, W]  discounted return accumulator
    disc: jax.Array          # f32[B, W]
    steps: jax.Array         # i32[B, W]  simulation steps taken


class RequestRing(NamedTuple):
    """Device-resident staging buffer of pre-prefilled requests.

    A fixed-capacity circular queue the host fills *between* jitted
    segments (:meth:`BatchedAsyncEngine.stage`) and the fused serving loop
    drains *inside* the ``while_loop`` (:meth:`BatchedAsyncEngine
    .serve_segment`): when a tree settles mid-segment, its row is re-seeded
    from the ring head without returning to Python.  ``aux`` holds the
    evaluator's staged per-request resources (dense: prefilled KV rows +
    root logits; paged: a page table whose pool pages are already written
    and held at refcount 1 by the ring).
    """

    req_id: jax.Array   # i32[C]   host-assigned id, -1 = empty slot
    states: Pytree      # pytree[C, ...] root env states
    rng: jax.Array      # u32[C, K] canonical per-request RNG lanes
    head: jax.Array     # i32[]    index of the oldest staged request
    count: jax.Array    # i32[]    staged-but-not-admitted requests
    aux: Pytree         # evaluator ring staging (see init_ring_aux)


class Completions(NamedTuple):
    """Device-side completion buffer one :meth:`serve_segment` fills.

    ``count`` rows are valid; each is the :class:`SearchResult` snapshot of
    one request taken at the tick its tree settled, tagged with the
    ``req_id`` the host staged it under.  Capacity is ``B + ring_capacity``
    — everything in flight plus everything staged can complete within one
    segment, so a segment can never overflow its own buffer.
    """

    req_id: jax.Array      # i32[C_out]
    action: jax.Array      # i32[C_out]
    root_n: jax.Array      # f32[C_out, A]
    root_v: jax.Array      # f32[C_out, A]
    tree_size: jax.Array   # i32[C_out]
    max_o: jax.Array       # f32[C_out]
    overflowed: jax.Array  # bool[C_out]
    ticks: jax.Array       # i32[C_out]
    count: jax.Array       # i32[]


def _freeze_done(alive: jax.Array, new: Pytree, old: Pytree) -> Pytree:
    """Per-tree carry select — the masking ``vmap`` applies to a batched
    ``while_loop`` body, done by hand.  Every leaf leads with ``[B]``."""
    return jax.tree.map(
        lambda a, b: jnp.where(
            alive.reshape(alive.shape + (1,) * (a.ndim - 1)), a, b
        ),
        new,
        old,
    )


class BatchedAsyncEngine:
    """``B``-tree async-slot WU-UCT with a carry that outlives requests.

    The master tick (``refill → tick → settle``) is identical to the
    one-shot :func:`run_async_search_batched` program — that function is a
    thin wrapper over this class, and the vmap-oracle bit-equivalence tests
    pin the tick.  What the class adds is slot-level request lifecycle:

    * :meth:`init_carry` — build the loop carry, optionally with some rows
      born *idle* (``active=False`` rows start with ``t_done == T``, so
      :meth:`step` freezes them until something is admitted);
    * :meth:`step` / :meth:`run_segment` — one / up to ``n`` frozen-masked
      master ticks (settled trees' slots are masked FREE so they stop
      feeding the evaluator);
    * :meth:`settled` / :meth:`result` — which rows finished their budget,
      and the ``SearchResult[B]`` snapshot to harvest them from;
    * :meth:`admit` — splice fresh requests into settled rows: tree reset
      (`init_batched_tree` rows scattered in), slot pool reset, per-tree RNG
      lane overwrite, counters zeroed, and the evaluator's
      ``admit_aux`` re-seeds the rows' ``W`` slot caches (ragged re-prefill
      + dense cache splice, or paged page-table splice + refcount fan-out —
      the shared :mod:`repro.serving.admission` path);
    * :meth:`evict` — release a settled row's evaluator-side resources
      (paged caches return their pages to the pool) without admitting a
      replacement.

    ``admit``/``evict``/``result`` are eager-boundary methods (the serving
    layer calls them between jitted segments); ``step``/``run_segment`` are
    pure and jit-safe.
    """

    def __init__(
        self,
        env: Environment,
        cfg: SearchConfig,
        batch: int,
        *,
        evaluator: Optional[Evaluator] = None,
        constrain: Optional[Callable[[Pytree], Pytree]] = None,
        use_kernel: bool = True,
    ):
        self.env = env
        self.cfg = cfg
        self.B = int(batch)
        self.W = cfg.wave_size
        self.T = cfg.num_simulations
        self.width = min(cfg.max_width, env.num_actions)
        self.capacity = cfg.num_simulations + cfg.wave_size + 1
        self.evaluator = (
            evaluator if evaluator is not None else RolloutEvaluator(env)
        )
        self.constrain = constrain
        self.use_kernel = use_kernel
        self._bidx = jnp.arange(self.B)
        # The single engine ignores deterministic_expansion (Algorithm 7).
        self._exp_cfg = cfg._replace(deterministic_expansion=False)

    # ------------------------------------------------------------------
    # Slot pool
    # ------------------------------------------------------------------
    def _slot_rows0(self, root_states, rows: int) -> _BatchedAsyncSlots:
        """Fresh slot-pool rows (all FREE) for ``rows`` trees."""
        proto = self.evaluator.init_state(
            jax.tree.map(lambda x: x[0], root_states), (rows, self.W)
        )
        return _BatchedAsyncSlots(
            kind=jnp.zeros((rows, self.W), jnp.int32),
            sim_node=jnp.zeros((rows, self.W), jnp.int32),
            act=jnp.zeros((rows, self.W), jnp.int32),
            state=proto,
            rollout_done=jnp.zeros((rows, self.W), jnp.bool_),
            acc=jnp.zeros((rows, self.W), jnp.float32),
            disc=jnp.ones((rows, self.W), jnp.float32),
            steps=jnp.zeros((rows, self.W), jnp.int32),
        )

    def _set_slot(
        self, slots: _BatchedAsyncSlots, j, mask, **kw
    ) -> _BatchedAsyncSlots:
        """Write slot column ``j`` for trees where ``mask`` holds."""
        B = self.B
        upd = {}
        for f in slots._fields:
            v = getattr(slots, f)
            if f in kw:
                if f == "state":
                    v = jax.tree.map(
                        lambda b, x: b.at[:, j].set(
                            jnp.where(
                                mask.reshape((B,) + (1,) * (x.ndim - 1)),
                                x,
                                b[:, j],
                            )
                        ),
                        v,
                        kw[f],
                    )
                else:
                    v = v.at[:, j].set(jnp.where(mask, kw[f], v[:, j]))
            upd[f] = v
        return _BatchedAsyncSlots(**upd)

    # ------------------------------------------------------------------
    # Master tick
    # ------------------------------------------------------------------
    def _refill(self, carry):
        """Fill each tree's FREE slots with fresh selections — slot ``j`` of
        all ``B`` trees fills simultaneously, one [B, A] kernel call per
        traversal level."""
        B, W, T, cfg = self.B, self.W, self.T, self.cfg
        bidx = self._bidx

        def body(j, c):
            tree, slots, rng, t_launch, t_done, aux, fr_hits = c
            rng, k_t, k_e = _split_each(rng, 3)
            want = (slots.kind[:, j] == FREE) & (t_launch < T)

            nodes = traverse_batched(tree, k_t, cfg, self.use_kernel)
            kids = tree.children[bidx, nodes]
            n_tried = jnp.sum((kids >= 0).astype(jnp.int32), axis=1)
            is_term = tree.terminal[bidx, nodes]
            at_depth = tree.depth[bidx, nodes] >= cfg.max_depth
            needs_exp = (
                jnp.logical_not(is_term)
                & jnp.logical_not(at_depth)
                & (n_tried < self.width)
            )
            act = _expansion_actions(tree, nodes, k_e, self._exp_cfg)
            tree, child, reserved = btree.reserve_children(
                tree, nodes, act, mask=want & needs_exp
            )
            needs_exp = needs_exp & reserved
            sim_node = jnp.where(needs_exp, child, nodes).astype(jnp.int32)
            tree = _mark_in_flight(tree, sim_node, cfg, mask=want)

            # Terminal hit: settle instantly, slot stays FREE (the paper
            # counts it as a completed simulation with return 0).
            tree = _settle(
                tree, sim_node, jnp.zeros((B,), jnp.float32), cfg,
                mask=want & is_term,
            )
            parent_state = btree.get_state(tree, nodes)
            # Re-sync the evaluator's slot caches: slot column j of every
            # tree lives at flat row b·W + j of the aux pool.
            aux, hit = self.evaluator.refill_aux(
                cfg, aux, bidx * W + j, parent_state,
                want & jnp.logical_not(is_term),
            )
            fr_hits = fr_hits + hit.astype(jnp.int32)
            slots = self._set_slot(
                slots,
                j,
                want,
                kind=jnp.where(
                    is_term, FREE, jnp.where(needs_exp, EXPAND, SIM)
                ).astype(jnp.int32),
                sim_node=sim_node,
                act=act,
                state=parent_state,
                rollout_done=tree.terminal[bidx, sim_node],
                acc=jnp.zeros((B,), jnp.float32),
                disc=jnp.ones((B,), jnp.float32),
                steps=jnp.zeros((B,), jnp.int32),
            )
            t_launch = t_launch + want.astype(jnp.int32)
            t_done = t_done + (want & is_term).astype(jnp.int32)
            return tree, slots, rng, t_launch, t_done, aux, fr_hits

        return jax.lax.fori_loop(0, W, body, carry)

    def _tick(self, slots: _BatchedAsyncSlots, rng, aux):
        """Advance every busy slot by one env step — vmapped over the flat
        [B·W] axis, forming one rollout batch (the future model-forward
        hook); shards over ('pod', 'data') via ``constrain``."""
        B, W = self.B, self.W
        keys = jax.vmap(lambda k: jax.random.split(k, W))(rng)   # [B, W, ...]

        def flat(x):
            return x.reshape((B * W,) + x.shape[2:])

        args = (
            flat(slots.kind), flat(slots.act),
            jax.tree.map(flat, slots.state),
            flat(slots.rollout_done), flat(slots.acc), flat(slots.disc),
            flat(slots.steps), flat(keys),
        )
        if self.constrain is not None:
            args = self.constrain(args)
        # aux stays outside `constrain`: model-cache leaves lead with the
        # layer axis, not the slot axis the hook shards.
        out, aux = self.evaluator.tick(self.cfg, *args, aux)
        if self.constrain is not None:
            out = self.constrain(out)
        out = jax.tree.map(lambda x: x.reshape((B, W) + x.shape[1:]), out)
        new_state, r_edge, done_edge, acc, disc, steps, rollout_done = out
        slots = slots._replace(
            state=new_state, acc=acc, disc=disc, steps=steps,
            rollout_done=rollout_done,
        )
        return slots, r_edge, done_edge, aux

    def _settle_finished(self, carry, r_edge, done_edge):
        """EXPAND→SIM transitions (finalize child) + completed rollouts."""
        cfg = self.cfg

        def body(j, c):
            tree, slots, t_done = c
            kind_j = slots.kind[:, j]
            is_exp = kind_j == EXPAND

            # EXPAND slots: their env step just produced the child state.
            st = jax.tree.map(lambda x: x[:, j], slots.state)
            tree = btree.finalize_children(
                tree, slots.sim_node[:, j], st, r_edge[:, j], done_edge[:, j],
                mask=is_exp,
            )
            kind2 = jnp.where(is_exp, SIM, kind_j).astype(jnp.int32)
            steps2 = jnp.where(is_exp, 0, slots.steps[:, j]).astype(jnp.int32)

            # SIM slots finished (episode done or step cap): complete update.
            fin = (kind2 == SIM) & (
                slots.rollout_done[:, j] | (steps2 >= cfg.max_sim_steps)
            )
            tree = _settle(tree, slots.sim_node[:, j], slots.acc[:, j], cfg,
                           mask=fin)
            slots = slots._replace(
                kind=slots.kind.at[:, j].set(
                    jnp.where(fin, FREE, kind2).astype(jnp.int32)
                ),
                steps=slots.steps.at[:, j].set(steps2),
            )
            return tree, slots, t_done + fin.astype(jnp.int32)

        return jax.lax.fori_loop(0, self.W, body, carry)

    def alive(self, carry) -> jax.Array:
        """bool[B] — trees still short of their simulation budget."""
        return carry[4] < self.T          # t_done, per tree

    def settled(self, carry) -> jax.Array:
        """bool[B] — trees whose search finished (harvest/admit targets)."""
        return carry[4] >= self.T

    def _master_iter(self, carry):
        tree, slots, rng, t_launch, t_done, ticks, max_o, aux, fr_hits = carry
        rng, k_tick = _split_each(rng, 2)
        tree, slots, rng, t_launch, t_done, aux, fr_hits = self._refill(
            (tree, slots, rng, t_launch, t_done, aux, fr_hits)
        )
        max_o = jnp.maximum(max_o, tree.O[:, 0])
        slots, r_edge, done_edge, aux = self._tick(slots, k_tick, aux)
        tree, slots, t_done = self._settle_finished(
            (tree, slots, t_done), r_edge, done_edge
        )
        return (
            tree, slots, rng, t_launch, t_done, ticks + 1, max_o, aux, fr_hits
        )

    def step(self, carry):
        """One master tick with finished trees frozen — the same per-lane
        masking ``vmap`` would apply to the single engine's while_loop.

        The evaluator aux rides outside the freeze: its leaves don't lead
        with ``[B]`` (model caches lead with the layer axis), and a finished
        tree's cache drift is unobservable — its slots are frozen, so
        nothing it decodes ever reaches the tree again.

        Finished trees' slot kinds are masked to FREE for the iteration so
        their dead slots stop FEEDING the evaluator: with a dense cache the
        drift was merely unobservable waste, but with a shared paged pool a
        dead tree's slots would keep allocating copy-on-write blocks every
        tick and starve the live trees.  Tree-side writes were already
        masked (``want`` is false once ``t_launch >= T``), slot outputs are
        frozen from ``carry``, and the RNG split structure is untouched, so
        the vmap-oracle bit-equivalence is preserved.  The same property
        makes settled rows safe *admission targets*: a frozen row's state is
        exactly its state at settle time, so the serving layer can harvest
        and overwrite it between any two ticks.
        """
        alive = self.alive(carry)
        slots_in = carry[1]
        masked = slots_in._replace(
            kind=jnp.where(alive[:, None], slots_in.kind, FREE).astype(
                jnp.int32
            )
        )
        new = self._master_iter((carry[0], masked) + carry[2:])
        # aux rides outside the freeze (above); the per-tree frontier-hit
        # counter rides after it and freezes with a plain where — its hits
        # are already masked by ``want``, so dead lanes never advance.
        return _freeze_done(alive, new[:-2], carry[:-2]) + (
            new[-2], jnp.where(alive, new[-1], carry[-1]),
        )

    # ------------------------------------------------------------------
    # Request lifecycle (the serving layer's surface)
    # ------------------------------------------------------------------
    def init_carry(self, root_states, rngs, active=None):
        """Build the master-loop carry for ``B`` root states.

        ``rngs`` is ``jax.random.split(key, B)``.  ``active`` (bool[B],
        optional) marks rows that carry a real request; inactive rows are
        born settled (``t_launch == t_done == T``) so :meth:`step` freezes
        them — they hold placeholder state until :meth:`admit` splices a
        request in.  Callers with idle paged rows should :meth:`evict` them
        after init so their placeholder prefill pages return to the pool.
        """
        B, W, T = self.B, self.W, self.T
        rngs = _canonical_keys(rngs)
        tree0 = init_batched_tree(
            root_states, self.capacity, self.env.num_actions
        )
        if active is None:
            start = jnp.zeros((B,), jnp.int32)
        else:
            start = jnp.where(jnp.asarray(active), 0, T).astype(jnp.int32)
        return (
            tree0, self._slot_rows0(root_states, B), rngs,
            start, start,
            jnp.zeros((B,), jnp.int32), jnp.zeros((B,), jnp.float32),
            self.evaluator.init_aux(root_states, (B, W)),
            jnp.zeros((B,), jnp.int32),
        )

    def admit(self, carry, rows, root_states, rngs):
        """Splice fresh requests into settled rows, mid-stream.

        ``rows`` is ``i32[R]`` (distinct, settled or idle); ``root_states``
        leaves lead with ``[R]``; ``rngs`` is ``jax.random.split(key, R)``.
        Resets the rows' trees, slot pools, RNG lanes and counters, and
        re-seeds their evaluator slot caches via ``Evaluator.admit_aux``
        (dense: one ragged re-prefill + slot-axis cache splice; paged:
        release + page-table splice + refcount fan-out to the ``W``
        siblings).  Rows not in ``rows`` are untouched — their searches
        continue across the splice.
        """
        tree, slots, rng, t_launch, t_done, ticks, max_o, aux, fr_hits = carry
        rows = jnp.asarray(rows, jnp.int32)
        r = rows.shape[0]
        tree_new = init_batched_tree(
            root_states, self.capacity, self.env.num_actions
        )
        tree = jax.tree.map(
            lambda f, n: f.at[rows].set(n), tree, tree_new
        )
        slots = jax.tree.map(
            lambda f, n: f.at[rows].set(n),
            slots, self._slot_rows0(root_states, r),
        )
        zero = jnp.zeros((r,), jnp.int32)
        return (
            tree, slots, rng.at[rows].set(_canonical_keys(rngs)),
            t_launch.at[rows].set(zero), t_done.at[rows].set(zero),
            ticks.at[rows].set(zero),
            max_o.at[rows].set(jnp.zeros((r,), jnp.float32)),
            self.evaluator.admit_aux(self.cfg, aux, rows, root_states, self.W),
            fr_hits.at[rows].set(zero),
        )

    def evict(self, carry, rows):
        """Release settled rows' evaluator-side resources without admitting.

        Paged evaluators return the rows' pages to the shared pool (their
        slots are frozen FREE, so nothing dereferences the dropped tables);
        dense evaluators are a no-op — an idle dense row costs nothing
        beyond its preallocated HBM.  Tree/slot/RNG state is left in place:
        :meth:`result` stays readable until the row is re-admitted.
        """
        rows = jnp.asarray(rows, jnp.int32)
        aux = self.evaluator.evict_aux(carry[7], rows, self.W)
        return carry[:7] + (aux,) + carry[8:]

    def run_segment(self, carry, num_ticks: int):
        """Up to ``num_ticks`` master ticks; stops early when all settled.

        Returns ``(carry, ticks_run, busy_tree_ticks)`` — the occupancy
        numerator/denominator the serving layer turns into its slot-idle
        fraction (a settled row's ``W`` slots idle for the rest of the
        segment; ``busy_tree_ticks`` counts row-ticks that searched).
        """
        def cond(c):
            carry, t, _ = c
            return (t < num_ticks) & jnp.any(self.alive(carry))

        def body(c):
            carry, t, busy = c
            busy = busy + jnp.sum(self.alive(carry).astype(jnp.int32))
            return self.step(carry), t + 1, busy

        carry, t, busy = jax.lax.while_loop(
            cond, body, (carry, jnp.int32(0), jnp.int32(0))
        )
        return carry, t, busy

    def result(self, carry) -> SearchResult:
        """``SearchResult[B]`` snapshot (meaningful on settled rows)."""
        tree = carry[0]
        root_n, root_v = btree.root_action_stats(tree)
        return SearchResult(
            action=btree.best_root_action(tree),
            root_n=root_n,
            root_v=root_v,
            tree_size=tree.size,
            dup_selections=jnp.zeros((self.B,), jnp.float32),
            max_o=carry[6],
            overflowed=tree.overflowed,
            ticks=carry[5],
        )

    # ------------------------------------------------------------------
    # Device-resident serving ring (the fused poll round)
    # ------------------------------------------------------------------
    def init_ring(self, proto_root_states, capacity: int) -> RequestRing:
        """Empty :class:`RequestRing` with room for ``capacity`` requests.

        ``proto_root_states`` (leaves leading with any batch axis) supplies
        only shapes/dtypes for the per-request root-state buffers.
        """
        cap = int(capacity)
        if cap < 1:
            raise ValueError(f"ring capacity must be >= 1, got {capacity}")
        states = jax.tree.map(
            lambda x: jnp.zeros(
                (cap,) + jnp.shape(jnp.asarray(x))[1:], jnp.asarray(x).dtype
            ),
            proto_root_states,
        )
        kd = jax.random.key_data(jax.random.PRNGKey(0))
        return RequestRing(
            req_id=jnp.full((cap,), -1, jnp.int32),
            states=states,
            rng=jnp.zeros((cap,) + kd.shape, kd.dtype),
            head=jnp.int32(0),
            count=jnp.int32(0),
            aux=self.evaluator.init_ring_aux(self.cfg, proto_root_states, cap),
        )

    def stage(self, carry, ring: RequestRing, root_states, rngs, req_ids):
        """Stage ``R`` requests at the ring tail (host-side, between
        segments; the serving layer calls it with ``R == 1`` so the jitted
        graph keeps one fixed shape).

        The evaluator's ``stage_ring_aux`` pre-prefills the requests into
        the ring's staging buffers — paged evaluators allocate their pool
        pages *now*, from the live carry refcounts (held at refcount 1 by
        the ring until admission), which is why the carry is threaded
        through.  The caller must guarantee ``count + R <= capacity``.
        """
        cap = ring.req_id.shape[0]
        req_ids = jnp.asarray(req_ids, jnp.int32)
        r = req_ids.shape[0]
        slots = (ring.head + ring.count + jnp.arange(r, dtype=jnp.int32)) % cap
        states = jax.tree.map(
            lambda buf, x: buf.at[slots].set(x), ring.states, root_states
        )
        aux, ring_aux = self.evaluator.stage_ring_aux(
            self.cfg, carry[7], ring.aux, slots, root_states
        )
        ring = ring._replace(
            req_id=ring.req_id.at[slots].set(req_ids),
            states=states,
            rng=ring.rng.at[slots].set(_canonical_keys(rngs)),
            count=ring.count + r,
            aux=ring_aux,
        )
        return carry[:7] + (aux,) + carry[8:], ring

    def _admit_from_ring(self, carry, ring: RequestRing, row_req, slot, mask):
        """Re-seed rows where ``mask`` holds from ring slots ``slot`` — the
        traceable counterpart of :meth:`admit` (masked select instead of
        scatter, evaluator splice via ``admit_aux_from_ring``)."""
        tree, slots_, rng, t_launch, t_done, ticks, max_o, aux, fr_hits = carry
        roots = jax.tree.map(lambda x: x[slot], ring.states)
        tree = _freeze_done(
            mask,
            init_batched_tree(roots, self.capacity, self.env.num_actions),
            tree,
        )
        slots_ = _freeze_done(mask, self._slot_rows0(roots, self.B), slots_)
        zero = jnp.zeros((self.B,), jnp.int32)
        aux, ring_aux = self.evaluator.admit_aux_from_ring(
            self.cfg, aux, ring.aux, slot, mask, self.W
        )
        carry = (
            tree, slots_,
            jnp.where(mask[:, None], ring.rng[slot], rng),
            jnp.where(mask, zero, t_launch),
            jnp.where(mask, zero, t_done),
            jnp.where(mask, zero, ticks),
            jnp.where(mask, 0.0, max_o),
            aux,
            jnp.where(mask, zero, fr_hits),
        )
        row_req = jnp.where(mask, ring.req_id[slot], row_req)
        return carry, ring._replace(aux=ring_aux), row_req

    def _serve_round(self, carry, ring: RequestRing, row_req, comp):
        """One in-loop harvest + admit round (traceable).

        Settled rows holding a request (``row_req >= 0``) append their
        :meth:`result` snapshot to the completion buffer and release their
        evaluator resources (``evict_aux_to_ring``); then as many settled
        rows as the ring holds requests are re-seeded from the ring head in
        row order, and the ring pointers advance.
        """
        cap = ring.req_id.shape[0]
        ccap = comp.req_id.shape[0]
        settled = self.settled(carry)

        done = settled & (row_req >= 0)
        rank = jnp.cumsum(done.astype(jnp.int32)) - 1
        dst = jnp.where(done, comp.count + rank, ccap)
        res = self.result(carry)
        comp = Completions(
            req_id=comp.req_id.at[dst].set(row_req, mode="drop"),
            action=comp.action.at[dst].set(res.action, mode="drop"),
            root_n=comp.root_n.at[dst].set(res.root_n, mode="drop"),
            root_v=comp.root_v.at[dst].set(res.root_v, mode="drop"),
            tree_size=comp.tree_size.at[dst].set(res.tree_size, mode="drop"),
            max_o=comp.max_o.at[dst].set(res.max_o, mode="drop"),
            overflowed=comp.overflowed.at[dst].set(
                res.overflowed, mode="drop"
            ),
            ticks=comp.ticks.at[dst].set(res.ticks, mode="drop"),
            count=comp.count + jnp.sum(done.astype(jnp.int32)),
        )
        aux = self.evaluator.evict_aux_to_ring(carry[7], done, self.W)
        carry = carry[:7] + (aux,) + carry[8:]
        row_req = jnp.where(done, -1, row_req)

        take = jnp.cumsum(settled.astype(jnp.int32)) - 1
        do_admit = settled & (take < ring.count)
        slot = (ring.head + jnp.clip(take, 0, cap - 1)) % cap
        carry, ring, row_req = self._admit_from_ring(
            carry, ring, row_req, slot, do_admit
        )
        n_adm = jnp.sum(do_admit.astype(jnp.int32))
        ring = ring._replace(
            head=(ring.head + n_adm) % cap, count=ring.count - n_adm
        )
        return carry, ring, row_req, comp

    def serve_segment(self, carry, ring: RequestRing, row_req, num_ticks: int):
        """Up to ``num_ticks`` master ticks with harvest + ring admission
        *inside* the loop — the fused poll round.

        ``row_req`` is ``i32[B]``, the request id each row is serving
        (``-1`` = idle).  Each iteration first runs a harvest/admit round
        (gated behind a ``cond`` so tick cost is untouched while nothing is
        settled), then one frozen-masked master tick.  A final round after
        the loop harvests rows that settled on the last tick.  Exits early
        when every row is idle and the ring is empty.  Returns
        ``(carry, ring, row_req, completions, ticks_run, busy_tree_ticks)``.
        """
        ccap = self.B + ring.req_id.shape[0]
        proto = self.result(carry)

        def buf(x):
            return jnp.zeros((ccap,) + x.shape[1:], x.dtype)

        comp = Completions(
            req_id=jnp.full((ccap,), -1, jnp.int32),
            action=buf(proto.action), root_n=buf(proto.root_n),
            root_v=buf(proto.root_v), tree_size=buf(proto.tree_size),
            max_o=buf(proto.max_o), overflowed=buf(proto.overflowed),
            ticks=buf(proto.ticks), count=jnp.int32(0),
        )

        def maybe_round(carry, ring, row_req, comp):
            settled = self.settled(carry)
            want = jnp.any(settled & (row_req >= 0)) | (
                (ring.count > 0) & jnp.any(settled)
            )
            return jax.lax.cond(
                want,
                self._serve_round,
                lambda c, g, q, m: (c, g, q, m),
                carry, ring, row_req, comp,
            )

        def cond(c):
            carry, ring, row_req, _, t, _ = c
            more = jnp.any(self.alive(carry)) | (ring.count > 0)
            return (t < num_ticks) & more

        def body(c):
            carry, ring, row_req, comp, t, busy = c
            carry, ring, row_req, comp = maybe_round(
                carry, ring, row_req, comp
            )
            busy = busy + jnp.sum(self.alive(carry).astype(jnp.int32))
            return self.step(carry), ring, row_req, comp, t + 1, busy

        carry, ring, row_req, comp, t, busy = jax.lax.while_loop(
            cond, body,
            (carry, ring, row_req, comp, jnp.int32(0), jnp.int32(0)),
        )
        # Harvest rows that settled on the loop's last tick without paying
        # a masked tick for them (admission here also primes the next
        # segment's first tick).
        carry, ring, row_req, comp = maybe_round(carry, ring, row_req, comp)
        return carry, ring, row_req, comp, t, busy

    # ------------------------------------------------------------------
    # One-shot runs (the pre-existing API)
    # ------------------------------------------------------------------
    def run(self, root_states, rngs, trace_ticks: int = 0):
        """Admit ``B`` roots, run every tree to budget, return results."""
        init = self.init_carry(root_states, rngs)
        if trace_ticks > 0:
            def scan_body(carry, _):
                alive = self.alive(carry)
                new = self.step(carry)
                ev_len = self.evaluator.aux_len(new[7])
                if ev_len is not None:
                    ev_len = ev_len.reshape(self.B, self.W)
                return new, tick_snapshot(
                    new, alive, ev_len, self.evaluator.aux_blocks(new[7]),
                    frontier_hits=new[8],
                )

            final, trace = jax.lax.scan(
                scan_body, init, None, length=trace_ticks
            )
            return self.result(final), trace
        final = jax.lax.while_loop(
            lambda c: jnp.any(self.alive(c)), self.step, init
        )
        return self.result(final)


def run_async_search_batched(
    env: Environment,
    cfg: SearchConfig,
    root_states: Pytree,
    rngs: jax.Array,
    constrain: Optional[Callable[[Pytree], Pytree]] = None,
    use_kernel: bool = True,
    trace_ticks: int = 0,
    evaluator: Optional[Evaluator] = None,
) -> SearchResult:
    """Run ``B`` independent async-slot searches; every field of the returned
    :class:`SearchResult` carries a leading ``[B]`` axis.

    ``root_states`` is a pytree whose leaves lead with ``[B]``; ``rngs`` is
    ``jax.random.split(key, B)``.  With ``trace_ticks > 0`` returns
    ``(SearchResult, AsyncTickTrace)`` with a ``[K, B, ...]`` trace (see
    :func:`repro.core.async_search.run_async_search`).  ``evaluator`` owns
    the flat ``[B·W]`` slot stepping — with
    :class:`repro.core.evaluators.ModelEvaluator`, every master tick is one
    batched model forward over all in-flight slots.
    """
    rngs = _canonical_keys(rngs)
    engine = BatchedAsyncEngine(
        env, cfg, rngs.shape[0],
        evaluator=evaluator, constrain=constrain, use_kernel=use_kernel,
    )
    return engine.run(root_states, rngs, trace_ticks)


def make_batched_async_searcher(
    env: Environment,
    cfg: SearchConfig,
    constrain: Optional[Callable[[Pytree], Pytree]] = None,
    jit: bool = True,
    use_kernel: bool = True,
    evaluator: Optional[Evaluator] = None,
):
    """Build ``search(root_states[B], rngs[B]) -> SearchResult[B]``."""
    fn = functools.partial(
        run_async_search_batched, env, cfg,
        constrain=constrain, use_kernel=use_kernel, evaluator=evaluator,
    )
    return jax.jit(fn) if jit else fn
