"""Baseline parallel MCTS algorithms from the paper (Sec. 4, App. B).

* sequential UCT     — eq. (2), one rollout at a time (Algorithm 1 w/ W=1).
* LeafP  (Alg. 4)    — one selection, ``W`` simulations of the same node.
* TreeP  (Alg. 5)    — shared tree + virtual loss ``r_VL``.
* TreeP-VC (App. E)  — virtual loss + virtual pseudo-count, eq. (7).
* RootP  (Alg. 6)    — ``K`` independent trees; root statistics merged.

All reuse the wave engine in :mod:`wu_uct` so that speed and performance
comparisons isolate the *algorithm*, exactly as the paper does (App. D:
"building all algorithms in the same package ... eliminates other factors").
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..envs.base import Environment
from . import tree as tree_lib
from .batched_search import run_search_batched
from .evaluators import Evaluator, RolloutEvaluator
from .policies import PolicyConfig, expansion_action
from .tree import Tree
from .wu_uct import (
    KIND_EXPAND,
    KIND_TERMINAL,
    SearchConfig,
    SearchResult,
    _phase2_work,
    _Slots,
    run_search,
    traverse,
)

Pytree = Any


# ---------------------------------------------------------------------------
# LeafP — Algorithm 4.  One traversal per round; all W workers simulate the
# same expanded node; each return is backpropagated individually.
# ---------------------------------------------------------------------------


def run_leafp(
    env: Environment,
    cfg: SearchConfig,
    root_state: Pytree,
    rng: jax.Array,
    evaluator: Optional[Evaluator] = None,
    use_kernel: bool = True,
) -> SearchResult:
    W = cfg.wave_size
    if cfg.num_simulations % W != 0:
        raise ValueError("num_simulations must be divisible by wave_size")
    num_rounds = cfg.num_simulations // W
    capacity = num_rounds + 2
    width = min(cfg.max_width, env.num_actions)
    evaluator = evaluator if evaluator is not None else RolloutEvaluator(env)
    tree = tree_lib.init_tree(root_state, capacity, env.num_actions)
    # LeafP scores with plain UCT — no in-flight statistics exist.
    cfg = cfg._replace(policy=cfg.policy._replace(kind="uct"), stat_mode="none")

    def round_body(i, carry):
        tree, rng = carry
        rng, k_t, k_e, k_sim = jax.random.split(rng, 4)
        node = traverse(tree, k_t, cfg, use_kernel)
        kids = tree.children[node]
        n_tried = jnp.sum((kids >= 0).astype(jnp.int32))
        is_term = tree.terminal[node]
        needs_expand = (
            jnp.logical_not(is_term)
            & (tree.depth[node] < cfg.max_depth)
            & (n_tried < width)
        )
        act = expansion_action(tree, node, k_e)

        def do_expand(t):
            t, child, ok = tree_lib.reserve_child(t, node, act)
            st = tree_lib.get_state(t, node)
            child_state, r_edge, done = env.step(st, act)
            t = jax.lax.cond(
                ok,
                lambda tt: tree_lib.finalize_child(
                    tt, child, child_state, r_edge, done
                ),
                lambda tt: tt,
                t,
            )
            return t, child

        tree, sim_node = jax.lax.cond(
            needs_expand, do_expand, lambda t: (t, node), tree
        )

        # All W workers simulate the same node (this is LeafP's defining —
        # and failure-inducing — property).
        start_state = tree_lib.get_state(tree, sim_node)
        start_done = tree.terminal[sim_node]
        rets = jax.vmap(
            lambda k: evaluator.rollout(cfg, start_state, start_done, k)
        )(jax.random.split(k_sim, W))

        def bp_body(j, t):
            return tree_lib.backprop_update(t, sim_node, rets[j], cfg.gamma)

        tree = jax.lax.fori_loop(0, W, bp_body, tree)
        return tree, rng

    tree, _ = jax.lax.fori_loop(0, num_rounds, round_body, (tree, rng))
    root_n, root_v = tree_lib.root_action_stats(tree)
    return SearchResult(
        action=tree_lib.best_root_action(tree),
        root_n=root_n,
        root_v=root_v,
        tree_size=tree.size,
        dup_selections=jnp.float32(W - 1),  # by construction
        max_o=jnp.float32(0.0),
        overflowed=tree.overflowed,
        ticks=jnp.int32(num_rounds),
    )


# ---------------------------------------------------------------------------
# TreeP — Algorithm 5 — is the wave engine with stat_mode='vl'.
# ---------------------------------------------------------------------------


def run_treep(
    env, cfg, root_state, rng, constrain=None, evaluator=None,
    use_kernel=True,
) -> SearchResult:
    if cfg.stat_mode != "vl":
        cfg = cfg._replace(stat_mode="vl", policy=cfg.policy._replace(kind="treep"))
    return run_search(
        env, cfg, root_state, rng, constrain=constrain, evaluator=evaluator,
        use_kernel=use_kernel,
    )


# ---------------------------------------------------------------------------
# RootP / Ensemble-UCT — Algorithm 6.  K independent sequential-UCT trees over
# the same root state (different chance keys), statistics merged at move time.
# Implemented as one K-batched forest on the multi-root engine, so the root
# committee advances in lockstep through the fused tree_select kernel
# (Mirsoleimani et al.; "Ensemble UCT Needs High Exploitation").
# ---------------------------------------------------------------------------


def run_rootp(
    env: Environment,
    cfg: SearchConfig,
    root_state: Pytree,
    rng: jax.Array,
    use_kernel: bool = True,
    evaluator: Optional[Evaluator] = None,
) -> SearchResult:
    K = cfg.wave_size
    if cfg.num_simulations % K != 0:
        raise ValueError("num_simulations must be divisible by wave_size (=K)")
    sub_cfg = cfg._replace(
        num_simulations=cfg.num_simulations // K,
        wave_size=1,
        stat_mode="none",
        policy=cfg.policy._replace(kind="uct"),
    )
    roots = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (K,) + jnp.shape(x)), root_state
    )
    sub = run_search_batched(
        env, sub_cfg, roots, jax.random.split(rng, K),
        use_kernel=use_kernel, evaluator=evaluator,
    )
    n_tot = jnp.sum(sub.root_n, axis=0)
    v_tot = jnp.where(
        n_tot > 0,
        jnp.sum(sub.root_n * jnp.where(jnp.isfinite(sub.root_v), sub.root_v, 0.0),
                axis=0) / jnp.maximum(n_tot, 1e-9),
        -jnp.inf,
    )
    action = jnp.argmax(n_tot).astype(jnp.int32)
    return SearchResult(
        action=action,
        root_n=n_tot,
        root_v=v_tot,
        tree_size=jnp.sum(sub.tree_size),
        dup_selections=jnp.float32(0.0),
        max_o=jnp.float32(0.0),
        overflowed=jnp.any(sub.overflowed),
        ticks=jnp.max(sub.ticks),
    )


ALGORITHMS = {
    "wu_uct": lambda env, cfg, s, r, **kw: run_search(env, cfg, s, r, **kw),
    "uct": lambda env, cfg, s, r, **kw: run_search(env, cfg, s, r, **kw),
    "leafp": lambda env, cfg, s, r, **kw: run_leafp(env, cfg, s, r, **kw),
    "treep": run_treep,
    "treep_vc": lambda env, cfg, s, r, **kw: run_search(env, cfg, s, r, **kw),
    "rootp": lambda env, cfg, s, r, **kw: run_rootp(env, cfg, s, r, **kw),
}


def make_config(algorithm: str, **kw) -> SearchConfig:
    """Per-algorithm :class:`SearchConfig` builder, re-expressed over the
    :class:`repro.core.api.SearchSpec` lowering (one source of truth for
    policy kind + stat-mode per algorithm)."""
    from .api import make_config as _make_config  # api imports this module

    return _make_config(algorithm, **kw)


def make_algorithm(algorithm: str, env: Environment, cfg: SearchConfig, jit=True):
    fn = functools.partial(ALGORITHMS[algorithm], env, cfg)
    return jax.jit(fn) if jit else fn
