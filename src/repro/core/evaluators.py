"""Evaluators: the pluggable leaf-evaluation side of parallel MCTS.

"On Effective Parallelization of Monte Carlo Tree Search" frames parallel
MCTS as two separable concerns — tree statistics (the master's bookkeeping,
which WU-UCT keeps principled via ``O_s``) and leaf evaluation (the expensive
expansion/simulation work farmed out to workers).  This module owns the
second concern: every engine in :mod:`repro.core` drives its in-flight slots
through an :class:`Evaluator` instead of hard-wiring ``env.policy`` /
``env.step`` into its loop body.

Two implementations ship:

* :class:`RolloutEvaluator` — the classic random/scripted-policy rollout
  (``env.policy`` chooses simulation actions; ``env.step`` advances).  This
  is a *bit-identical* port of the per-slot stepping that previously lived
  as ``wu_uct.rollout_return`` and ``async_search.slot_tick_step``.
* :class:`ModelEvaluator` — policy/value-LM evaluation over the token
  environment (:mod:`repro.envs.token_env`): all in-flight slots of a master
  tick are scored by **one** batched model forward (``models.forward``)
  instead of three per-slot forwards hidden inside ``env.policy`` +
  ``env.step``.  Plugged into the async engines' flat ``[B·W]`` tick batch,
  this realizes the ROADMAP follow-up: every master tick feeds one model
  forward pass.
* :class:`CachedModelEvaluator` — the same contract with a per-slot KV
  decode cache carried in the engines' slot-aux state, so the one forward
  per master tick is a single batched ``models.decode_step`` (O(1) in
  prefix length) instead of a full-prefix ``models.forward`` (O(depth)).
  Slot refills roll the cache back to the common prefix with the newly
  assigned tree path and re-decode only the divergent suffix.

The evaluator contract (``init_state`` / ``tick`` / ``rollout`` / ``value``
plus the slot-aux hooks ``init_aux`` / ``refill_aux``) is identical across
implementations, so engines stay evaluator-agnostic and
:func:`repro.core.api.build_searcher` can swap them freely.
"""

from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from ..envs.base import Environment

Pytree = Any

# Slot phases, shared with the async engines (async_search re-exports them).
FREE, EXPAND, SIM = 0, 1, 2


def slot_accounting(gamma, kind, nxt, state, r, done, rollout_done, acc, disc,
                    steps):
    """Per-slot discounted-return bookkeeping after one environment step.

    The one accounting rule every evaluator must apply identically for the
    engines' vmap bit-equivalence to hold: only live SIM slots accumulate,
    FREE slots freeze their state, EXPAND slots report the edge transition.
    Shape-polymorphic (scalar per-slot or leading batch axes) so the same
    code serves ``RolloutEvaluator._one_step`` and the batched
    ``ModelEvaluator.tick``.
    """
    is_sim = kind == SIM
    live = is_sim & jnp.logical_not(rollout_done)
    acc = acc + jnp.where(live, disc * r, 0.0)
    disc = jnp.where(live, disc * gamma, disc)
    steps = steps + jnp.where(kind != FREE, 1, 0)
    busy = kind != FREE
    new_state = jax.tree.map(
        lambda a_, b_: jnp.where(
            busy.reshape(busy.shape + (1,) * (a_.ndim - busy.ndim)), a_, b_
        ),
        nxt,
        state,
    )
    rollout_done = jnp.where(
        kind == EXPAND, done, rollout_done | (is_sim & done)
    )
    return new_state, r, done, acc, disc, steps, rollout_done


def _flat_slot_rows(rows, w: int) -> jax.Array:
    """Flat aux rows ``[R·w]`` covering tree rows' ``w`` sibling slots.

    Slot ``j`` of tree ``b`` lives at flat aux row ``b·w + j`` — the layout
    both async engines address ``refill_aux`` with; admission/eviction hooks
    expand their per-tree ``rows`` through this.
    """
    rows = jnp.asarray(rows, jnp.int32)
    return (
        rows[:, None] * w + jnp.arange(w, dtype=jnp.int32)[None, :]
    ).reshape(-1)


class Evaluator:
    """Protocol for environment/model evaluation inside a search engine.

    Engines call four methods; ``cfg`` is the engine's ``SearchConfig``
    (only ``gamma`` / ``max_sim_steps`` / ``value_mix`` are read):

    * ``init_state(example_state, prefix)`` — allocate zeroed per-slot env
      state buffers with leading ``prefix`` axes (the async slot pools);
    * ``tick(cfg, kind, act, state, rollout_done, acc, disc, steps, keys,
      aux)`` — advance a whole batch of in-flight slots by one environment
      step.  Leading axis is *all* in-flight slots of a master tick: ``[W]``
      for the single async engine, the flat ``[B·W]`` for the batched one.
      Returns ``((new_state, r, done, acc, disc, steps, rollout_done),
      aux)``;
    * ``rollout(cfg, state, already_done, rng)`` — full discounted
      simulation return from one state (the wave engines vmap this per
      slot);
    * ``value(state)`` — bootstrap value ``V(s)`` for truncated rollouts.

    **Slot aux** is evaluator-owned per-slot state the async engines carry
    *alongside* the env-state slot pools but never write into the tree (the
    KV decode cache of :class:`CachedModelEvaluator` — node states must stay
    compact).  Engines thread it unconditionally; the default hooks make it
    an empty pytree so stateless evaluators cost nothing:

    * ``init_aux(root_states, prefix)`` — build the flat ``[N]`` aux pool
      (``N = prod(prefix)``; ``root_states`` leaves lead with
      ``prefix[:-1]`` and broadcast over the trailing slot axis);
    * ``refill_aux(cfg, aux, rows, new_state, mask)`` — re-sync aux rows
      ``rows`` (flat ``i32[R]`` indices) with the freshly assigned
      ``new_state`` (leaves lead with ``[R]``) where ``mask`` holds.
      Returns ``(aux, hits)`` where ``hits`` (``bool``, shaped like
      ``rows``) flags rows served entirely from a speculative frontier
      cache — no model forward dispatched (always ``False`` for evaluators
      without a frontier cache; the engines surface the count in trace
      mode as ``frontier_hits``);
    * ``aux_len(aux)`` — the per-slot cache depth vector for trace-mode
      invariant checking (``None`` when the evaluator carries no cache).
    """

    env: Optional[Environment] = None

    def init_aux(self, root_states: Pytree, prefix: tuple) -> Pytree:
        del root_states, prefix
        return ()

    def refill_aux(self, cfg, aux, rows, new_state, mask):
        del cfg, new_state, mask
        return aux, jnp.zeros(jnp.shape(rows), jnp.bool_)

    def admit_aux(self, cfg, aux, rows, root_states, w):
        """Re-seed the slot caches of freshly admitted *tree* rows.

        The engine-side half of continuous batching: when the serving layer
        splices a new request into settled tree row ``b``, flat aux rows
        ``b·w .. b·w + w - 1`` must be rebuilt from the request's root state
        (``rows`` is ``i32[R]`` tree rows; ``root_states`` leaves lead with
        ``[R]``; ``w`` is the engine's slot count per tree).  Cached
        evaluators re-prefill the roots and splice the rows in via the
        shared :mod:`repro.serving.admission` path; stateless evaluators
        need nothing.  Called at an eager boundary (between jitted
        segments), so paged implementations may surface pool exhaustion.
        """
        del cfg, rows, root_states, w
        return aux

    def evict_aux(self, aux, rows, w):
        """Release aux resources held by settled tree rows ``rows``.

        Paged caches return the rows' pages to the shared pool; evaluators
        without pooled resources need nothing (a dense row's HBM is
        preallocated either way).
        """
        del rows, w
        return aux

    # -- device-resident serving ring (traceable admit/evict variants) --
    def init_ring_aux(self, cfg, proto_root_states, capacity: int) -> Pytree:
        """Empty per-request staging buffers for a ``capacity``-slot ring.

        The fused serving loop (``BatchedAsyncEngine.serve_segment``) admits
        requests *inside* the jitted ``while_loop``; anything the eager
        ``admit_aux`` would compute per admission (prefilled KV, root
        logits, a page table) must instead be staged here ahead of time by
        :meth:`stage_ring_aux`.  Evaluators without per-request resources
        stage nothing.
        """
        del cfg, proto_root_states, capacity
        return ()

    def stage_ring_aux(self, cfg, aux, ring_aux, slots, root_states):
        """Pre-compute ring slots ``slots``'s admission resources.

        Runs at an eager boundary (host staging between segments) but must
        be traceable with fixed shapes — the serving layer jits it once per
        request shape.  Returns ``(aux, ring_aux)``: paged evaluators
        allocate pool pages from the live ``aux`` refcounts (the ring holds
        them at refcount 1 until admission), so the slot aux is threaded
        through.
        """
        del cfg, slots, root_states
        return aux, ring_aux

    def admit_aux_from_ring(self, cfg, aux, ring_aux, slot, mask, w):
        """Traceable twin of :meth:`admit_aux`: splice staged ring slots
        ``slot`` (``i32[B]``) into the rows where ``mask`` (``bool[B]``)
        holds — a masked select over pre-staged buffers instead of a fresh
        prefill, so it runs *inside* the fused serving ``while_loop``.
        Returns ``(aux, ring_aux)`` — consumed ring slots are cleared so a
        later re-staging never double-frees their resources.
        """
        del cfg, slot, mask, w
        return aux, ring_aux

    def evict_aux_to_ring(self, aux, mask, w):
        """Traceable twin of :meth:`evict_aux` over a row *mask* instead of
        row indices: release evaluator resources of every tree row where
        ``mask`` (``bool[B]``) holds.  Must never raise under trace — paged
        implementations latch ``oom`` instead.
        """
        del mask, w
        return aux

    def aux_len(self, aux) -> Optional[jax.Array]:
        del aux
        return None

    def aux_last_logits(self, aux) -> Optional[jax.Array]:
        """Most recent per-slot policy logits ``[N, V]``, when the evaluator
        surfaces them on slot-aux (policy-prior groundwork; the frontier
        cache reads the same slab).  ``None`` for logit-free evaluators."""
        del aux
        return None

    def aux_blocks(self, aux) -> Optional[jax.Array]:
        """Pool blocks currently allocated (paged caches only) — trace-mode
        snapshots it so benchmarks can read the peak working set."""
        del aux
        return None

    def init_state(self, example_state: Pytree, prefix: tuple) -> Pytree:
        """Zeroed per-slot state buffers shaped ``prefix + leaf.shape``."""
        return jax.tree.map(
            lambda x: jnp.zeros(
                tuple(prefix) + jnp.shape(x), jnp.asarray(x).dtype
            ),
            example_state,
        )

    def tick(self, cfg, kind, act, state, rollout_done, acc, disc, steps, keys,
             aux=()):
        raise NotImplementedError

    def value(self, state: Pytree) -> jax.Array:
        return jnp.float32(0.0)

    def has_value(self) -> bool:
        """Whether :meth:`value` is a real estimator; gates the rollout's
        truncation bootstrap and ``value_mix`` blending (a zero-constant
        value must not rescale returns)."""
        return False

    def rollout(self, cfg, state, already_done, rng) -> jax.Array:
        """Default full rollout: tick a single SIM slot until done/step cap.

        Implementations with a cheaper native rollout (the classic env
        rollout) override this; model-backed evaluators get it for free —
        under the wave engines' slot ``vmap`` the per-step forward becomes a
        batched forward over all slots.
        """

        def cond(c):
            _, done, _, _, _, steps = c
            return jnp.logical_not(done[0]) & (steps[0] < cfg.max_sim_steps)

        def body(c):
            st, done, acc, disc, rng, steps = c
            rng, k = jax.random.split(rng)
            (st, _, _, acc, disc, steps, done), _ = self.tick(
                cfg,
                jnp.full((1,), SIM, jnp.int32),
                jnp.zeros((1,), jnp.int32),
                st, done, acc, disc, steps, k[None],
            )
            return st, done, acc, disc, rng, steps

        init = (
            jax.tree.map(lambda x: x[None], state),
            jnp.asarray(already_done, jnp.bool_)[None],
            jnp.zeros((1,), jnp.float32),
            jnp.ones((1,), jnp.float32),
            rng,
            jnp.zeros((1,), jnp.int32),
        )
        st, done, acc, disc, _, _ = jax.lax.while_loop(cond, body, init)
        ret = acc[0]
        if self.has_value():
            final = jax.tree.map(lambda x: x[0], st)
            ret = ret + disc[0] * jnp.where(done[0], 0.0, self.value(final))
            if cfg.value_mix > 0.0:
                v0 = jnp.where(already_done, 0.0, self.value(state))
                ret = (1.0 - cfg.value_mix) * ret + cfg.value_mix * v0
        return ret


# ---------------------------------------------------------------------------
# RolloutEvaluator — today's env.policy behavior, bit-identical.
# ---------------------------------------------------------------------------


class RolloutEvaluator(Evaluator):
    """Classic rollout evaluation: ``env.policy`` acts, ``env.step`` advances.

    The per-slot stepping and discounted-return accounting are verbatim the
    code that previously lived inside the engines, so every engine's default
    behavior (and RNG stream) is unchanged.
    """

    def __init__(self, env: Environment):
        self.env = env

    def _one_step(self, gamma: float) -> Callable:
        """Per-slot one-env-step transition (the parallel part of a master
        tick) — shared by the single engine (vmapped over ``[W]``) and the
        batched engine (vmapped over the flat ``[B·W]`` axis)."""
        env = self.env

        def one(kind, act, state, rollout_done, acc, disc, steps, key):
            pol_act = env.policy(key, state)
            a = jnp.where(kind == EXPAND, act, pol_act)
            nxt, r, done = env.step(state, a)
            return slot_accounting(
                gamma, kind, nxt, state, r, done, rollout_done, acc, disc,
                steps,
            )

        return one

    def tick(self, cfg, kind, act, state, rollout_done, acc, disc, steps, keys,
             aux=()):
        out = jax.vmap(self._one_step(cfg.gamma))(
            kind, act, state, rollout_done, acc, disc, steps, keys
        )
        return out, aux

    def rollout(self, cfg, state, already_done, rng) -> jax.Array:
        """Discounted simulation return with optional value bootstrap/mixing
        (paper Fig. 1(a) "simulation"; App. D truncation bootstrap)."""
        env = self.env

        def cond(carry):
            _, done, _, _, _, steps = carry
            return jnp.logical_not(done) & (steps < cfg.max_sim_steps)

        def body(carry):
            state, done, acc, disc, rng, steps = carry
            rng, k = jax.random.split(rng)
            a = env.policy(k, state)
            nxt, r, d = env.step(state, a)
            acc = acc + disc * r
            disc = disc * cfg.gamma
            return nxt, done | d, acc, disc, rng, steps + 1

        init = (
            state,
            jnp.asarray(already_done, jnp.bool_),
            jnp.float32(0.0),
            jnp.float32(1.0),
            rng,
            jnp.int32(0),
        )
        final_state, done, acc, disc, _, _ = jax.lax.while_loop(
            cond, body, init
        )

        if env.value_fn is not None:
            # Truncation bootstrap: R_simu = Σ γ^i r_i + γ^T V(s_T) (App. D).
            acc = acc + disc * jnp.where(done, 0.0, env.value_fn(final_state))
            if cfg.value_mix > 0.0:
                v0 = jnp.where(already_done, 0.0, env.value_fn(state))
                acc = (1.0 - cfg.value_mix) * acc + cfg.value_mix * v0
        return acc

    def value(self, state: Pytree) -> jax.Array:
        if self.env.value_fn is None:
            return jnp.float32(0.0)
        return self.env.value_fn(state)

    def has_value(self) -> bool:
        return self.env.value_fn is not None


# ---------------------------------------------------------------------------
# ModelEvaluator — one batched policy/value LM forward per master tick.
# ---------------------------------------------------------------------------


class ModelEvaluator(Evaluator):
    """LM-backed evaluation over :mod:`repro.envs.token_env` state batches.

    The token environment's per-slot ``step`` runs one forward for the
    rollout policy plus two inside the transition (policy top-K + reward
    log-prob).  This evaluator instead runs **one** forward over the whole
    in-flight slot batch per tick and derives all three quantities from the
    same logits: the top-K table (action decoding), the sampled simulation
    action, and the reward log-prob (when the reward model is the policy
    model; a distinct reward model adds exactly one more forward).

    Paired with ``engine='async'`` searchers, whose master tick advances all
    ``[W]`` (or flat ``[B·W]``) slots at once, this yields exactly one model
    forward per master tick — asserted by ``tests/test_facade.py`` with a
    traced call counter, and measured by ``benchmarks/bench_model_eval.py``.

    Transitions apply :func:`repro.envs.token_env.apply_token` — the same
    transition core the env's ``step`` uses — so a search with this
    evaluator explores the same MDP by construction.
    """

    def __init__(
        self,
        model_cfg,
        params,
        *,
        top_k: int,
        eos_token: int = 0,
        reward_cfg=None,
        reward_params=None,
        forward_fn: Optional[Callable] = None,
        value_fn: Optional[Callable] = None,
    ):
        if forward_fn is None:
            from ..models import forward as forward_fn  # circular-safe
        self.model_cfg = model_cfg
        self.params = params
        self.top_k = top_k
        self.eos_token = eos_token
        self.reward_cfg = reward_cfg if reward_cfg is not None else model_cfg
        self.reward_params = reward_params
        self.forward_fn = forward_fn
        self.value_fn = value_fn

    def _position_logits(self, params, cfg, tokens, lengths) -> jax.Array:
        """Logits at each slot's current position — ONE forward for [N]."""
        logits, _ = self.forward_fn(params, cfg, {"tokens": tokens})
        pos = jnp.maximum(lengths - 1, 0)
        return jnp.take_along_axis(logits, pos[:, None, None], axis=1)[:, 0]

    def _transition(self, cfg, kind, act, state, rollout_done, acc, disc,
                    steps, keys, pol_logits, rew_logits):
        """Logits → (action, token, reward) → env transition → accounting.

        The piece shared with :class:`CachedModelEvaluator`: everything
        after the logits are in hand is identical, so cached and uncached
        evaluation explore the same MDP by construction.
        """
        n = state.length.shape[0]
        idx = jnp.arange(n)
        top_vals, top_idx = jax.lax.top_k(pol_logits, self.top_k)
        ranks = jax.vmap(jax.random.categorical)(keys, top_vals)
        a = jnp.where(kind == EXPAND, act, ranks).astype(jnp.int32)
        token = top_idx[idx, jnp.clip(a, 0, self.top_k - 1)]
        logp = jax.nn.log_softmax(rew_logits.astype(jnp.float32))[idx, token]

        # The env's own transition core, applied to the whole slot batch.
        # Deferred import: token_env pulls in the models stack, which a
        # model-free `import repro.core` must not pay for.
        from ..envs.token_env import apply_token

        nxt, r, done = apply_token(state, token, logp, self.eos_token)
        out = slot_accounting(
            cfg.gamma, kind, nxt, state, r, done, rollout_done, acc, disc,
            steps,
        )
        return out, token

    def init_aux(self, root_states: Pytree, prefix: tuple) -> Pytree:
        """Per-slot ``last_logits`` slab — the logits each tick computes are
        kept on aux instead of discarded after value extraction."""
        del root_states
        n = 1
        for p in prefix:
            n *= int(p)
        return {
            "last_logits": jnp.zeros((n, self.model_cfg.vocab_size),
                                     jnp.float32)
        }

    def aux_last_logits(self, aux) -> Optional[jax.Array]:
        if isinstance(aux, dict) and "last_logits" in aux:
            return aux["last_logits"]
        return None

    def tick(self, cfg, kind, act, state, rollout_done, acc, disc, steps, keys,
             aux=()):
        # --- the one batched forward of this master tick -------------------
        pol = self._position_logits(
            self.params, self.model_cfg, state.tokens, state.length
        )
        if self.reward_params is None:
            rew = pol
        else:
            rew = self._position_logits(
                self.reward_params, self.reward_cfg, state.tokens, state.length
            )
        out, _ = self._transition(
            cfg, kind, act, state, rollout_done, acc, disc, steps, keys, pol,
            rew,
        )
        if isinstance(aux, dict) and "last_logits" in aux:
            aux = dict(
                aux,
                last_logits=pol.astype(aux["last_logits"].dtype),
            )
        return out, aux

    def value(self, state: Pytree) -> jax.Array:
        if self.value_fn is None:
            return jnp.float32(0.0)
        return self.value_fn(state)

    def has_value(self) -> bool:
        return self.value_fn is not None


# ---------------------------------------------------------------------------
# CachedModelEvaluator — one batched decode step per master tick.
# ---------------------------------------------------------------------------


class CachedModelEvaluator(ModelEvaluator):
    """:class:`ModelEvaluator` with a per-slot KV decode cache in slot aux.

    The uncached evaluator re-runs a **full-prefix** forward for every slot
    on every master tick — O(depth) work per tick.  This evaluator carries
    the ``models.init_cache`` layout (the same cache contract the serving
    engine uses) per slot inside the async engines' aux state, so a master
    tick costs **one batched ``decode_step``** over all ``[B·W]`` in-flight
    slots — O(1) in prefix length, routed through the Pallas
    ``decode_attention`` kernel via the per-slot ragged ``cache['len']``
    vector.

    Aux layout (flat slot axis ``N``; model-cache leaves carry ``N`` on axis
    1 under their layer-stacked axis, evaluator-side leaves on axis 0):

    * ``tokens  i32[N, S]`` — the tokens fed into the cache (valid ``< len``);
    * ``len     i32[N]``    — tokens processed per slot (== the slot's
      prefix depth; the engines' trace mode snapshots it via
      :meth:`aux_len` for invariant tests);
    * ``pol/rew`` — per model: the KV cache (sans ``len``) plus the stored
      logits ``[N, V]`` at each slot's current position (``rew`` is empty
      when the reward model *is* the policy model).

    **Prefix-aware refill** (:meth:`refill_aux`): when a slot settles and is
    handed a new tree path, the path *is* the token prefix — the cache rolls
    ``len`` back to the common prefix with the tokens it already processed
    and re-decodes only the divergent suffix (a data-dependent
    ``while_loop`` of decode steps; a disjoint prefix degenerates to the
    token-by-token re-prefill fallback).  The last prompt token is always
    re-decoded so the stored logits are the new position's logits.

    Garbage-row contract (shared with ``models.prefill_ragged`` and the
    serving engine): KV rows at positions ``>= len`` are invalid; attention
    masks them and every write lands at position ``len`` before ``len``
    moves past it, so they are overwritten before ever becoming visible.
    This rollback story needs position-indexed cache rows, hence KV-cache
    families only (a recurrent SSM state cannot be rolled back).

    Async engines only: the wave engines evaluate rollouts per slot without
    aux plumbing (``build_searcher`` enforces this).
    """

    def __init__(
        self,
        model_cfg,
        params,
        *,
        top_k: int,
        eos_token: int = 0,
        reward_cfg=None,
        reward_params=None,
        value_fn: Optional[Callable] = None,
        decode_fn: Optional[Callable] = None,
        prefill_fn: Optional[Callable] = None,
        chunk_fn: Optional[Callable] = None,
        refill_chunk: int = 8,
    ):
        super().__init__(
            model_cfg, params, top_k=top_k, eos_token=eos_token,
            reward_cfg=reward_cfg, reward_params=reward_params,
            value_fn=value_fn,
        )
        if decode_fn is None:
            from ..models import decode_step as decode_fn  # circular-safe
        if prefill_fn is None:
            from ..models import prefill_ragged as prefill_fn
        if chunk_fn is None:
            from ..models import decode_chunk as chunk_fn
        if refill_chunk < 1:
            raise ValueError(f"refill_chunk must be >= 1, got {refill_chunk}")
        self.decode_fn = decode_fn
        self.prefill_fn = prefill_fn
        self.chunk_fn = chunk_fn
        self.refill_chunk = refill_chunk
        from ..models import KV_CACHE_FAMILIES

        cfgs = [model_cfg] + ([self.reward_cfg] if reward_params is not None
                              else [])
        for c in cfgs:
            if c.family not in KV_CACHE_FAMILIES:
                raise ValueError(
                    "CachedModelEvaluator needs a rollback-able KV cache; "
                    f"family {c.family!r} carries recurrent state "
                    "(use ModelEvaluator)"
                )

    # -- aux structure helpers ---------------------------------------------

    def _branches(self):
        """(aux key, params, cfg) per model the cache tracks."""
        out = [("pol", self.params, self.model_cfg)]
        if self.reward_params is not None:
            out.append(("rew", self.reward_params, self.reward_cfg))
        return out

    def _take_rows(self, aux, rows):
        def branch(b):
            if b == ():
                return ()
            return {
                "cache": jax.tree.map(lambda x: x[:, rows], b["cache"]),
                "logits": b["logits"][rows],
            }

        return {
            "tokens": aux["tokens"][rows],
            "len": aux["len"][rows],
            "pol": branch(aux["pol"]),
            "rew": branch(aux["rew"]),
        }

    def _put_rows(self, aux, rows, sub):
        def branch(b, sb):
            if b == ():
                return ()
            return {
                "cache": jax.tree.map(
                    lambda x, y: x.at[:, rows].set(y), b["cache"], sb["cache"]
                ),
                "logits": b["logits"].at[rows].set(sb["logits"]),
            }

        return {
            "tokens": aux["tokens"].at[rows].set(sub["tokens"]),
            "len": aux["len"].at[rows].set(sub["len"]),
            "pol": branch(aux["pol"], sub["pol"]),
            "rew": branch(aux["rew"], sub["rew"]),
        }

    def _advance(self, aux, token, fed):
        """Feed one token per slot through the cached models.

        Every slot decodes (ONE batched ``decode_step`` per model); only
        ``fed`` slots commit — their ``len`` advances and their stored
        logits refresh.  Non-fed slots' K/V writes land at their own
        position ``len`` (the garbage region) and are overwritten before
        ``len`` ever moves past them.
        """
        idx = jnp.arange(token.shape[0])
        s_max = aux["tokens"].shape[-1]
        length = aux["len"]
        safe = jnp.minimum(length, s_max - 1)
        prev = aux["tokens"][idx, safe]
        tokens = aux["tokens"].at[idx, safe].set(jnp.where(fed, token, prev))

        out = dict(
            tokens=tokens,
            len=jnp.where(fed, length + 1, length),
            pol=(), rew=(),
        )
        for key, params, cfg in self._branches():
            b = aux[key]
            logits, cache = self.decode_fn(
                params, cfg, token, dict(b["cache"], len=safe)
            )
            cache.pop("len")
            out[key] = {
                "cache": cache,
                "logits": jnp.where(
                    fed[:, None], logits, b["logits"]
                ).astype(b["logits"].dtype),
            }
        return out

    # -- evaluator protocol -------------------------------------------------

    def init_aux(self, root_states: Pytree, prefix: tuple) -> Pytree:
        """Prefill every slot's cache with its root prompt — once.

        ``root_states`` leaves lead with ``prefix[:-1]`` (per-tree roots in
        the batched engine); each root broadcasts over the trailing slot
        axis and the flat ``[N]`` pool prefills in ONE ragged batched
        forward (``models.prefill_ragged``).
        """
        from ..models import init_cache

        n = 1
        for p in prefix:
            n *= int(p)
        lead = len(prefix) - 1

        def flat(x):
            x = jnp.expand_dims(x, lead)
            x = jnp.broadcast_to(x, tuple(prefix) + x.shape[lead + 1:])
            return x.reshape((n,) + x.shape[len(prefix):])

        state = jax.tree.map(flat, root_states)
        tokens = jnp.asarray(state.tokens, jnp.int32)
        lengths = jnp.asarray(state.length, jnp.int32)
        s_max = tokens.shape[-1]

        aux = {
            "tokens": tokens, "len": lengths, "pol": (), "rew": (),
        }
        for key, params, cfg in self._branches():
            logits, cache = self.prefill_fn(
                params, cfg, tokens, lengths, init_cache(cfg, n, s_max)
            )
            cache.pop("len")
            aux[key] = {"cache": cache, "logits": logits}
        return aux

    def _rollback_targets(self, sub, new_state, mask):
        """Per-row (start, target, tokens, common) for a refill rollback.

        ``common`` is the (uncapped) shared prefix of the cached tokens and
        the new path's tokens; ``start`` caps it so the final prompt token
        is always re-decoded (the stored logits must be the NEW position's
        logits) — the frontier evaluators compare against the uncapped
        ``common`` to recognize rows whose forced re-decode exists only to
        regenerate logits the frontier cache already holds.  The re-prefill
        fallback is the common == 0 degenerate.  Unmasked rows collapse to
        start == target == their current length (no-op).
        """
        s_max = sub["tokens"].shape[-1]
        pos = jnp.arange(s_max)
        l_new = jnp.asarray(new_state.length, jnp.int32)
        old_len = sub["len"]
        limit = jnp.minimum(old_len, l_new)
        neq = (sub["tokens"] != new_state.tokens) & (pos[None, :] < limit[:, None])
        first = jnp.min(jnp.where(neq, pos[None, :], s_max), axis=1)
        common = jnp.minimum(first, limit)
        start = jnp.minimum(common, jnp.maximum(l_new - 1, 0))
        start = jnp.where(mask, start, old_len)
        target = jnp.where(mask, l_new, old_len)
        tokens = jnp.where(mask[:, None], new_state.tokens, sub["tokens"])
        return start, target, tokens, common

    def refill_aux(self, cfg, aux, rows, new_state, mask):
        del cfg
        sub = self._take_rows(aux, rows)
        r = rows.shape[0]
        s_max = sub["tokens"].shape[-1]
        start, target, tokens, _ = self._rollback_targets(sub, new_state, mask)
        sub = dict(sub, tokens=tokens, len=start)
        sub = self._catch_up(sub, target, r, s_max)
        return self._put_rows(aux, rows, sub), jnp.zeros((r,), jnp.bool_)

    def admit_aux(self, cfg, aux, rows, root_states, w):
        """Mid-stream admission: re-prefill + slot-axis cache splice.

        One ragged batched prefill over the ``R`` admitted roots
        (:mod:`repro.serving.admission`'s shared forward), fanned out to the
        rows' ``w`` sibling slots with a repeat along the cache's slot axis
        — the dense twin of the serving engine's ``add_requests`` splice.
        """
        del cfg
        from ..models import init_cache
        from ..serving.admission import splice_dense_slots

        flat = _flat_slot_rows(rows, w)
        tokens = jnp.asarray(root_states.tokens, jnp.int32)
        lengths = jnp.asarray(root_states.length, jnp.int32)
        r = tokens.shape[0]
        s_max = aux["tokens"].shape[-1]
        out = dict(
            aux,
            tokens=aux["tokens"].at[flat].set(jnp.repeat(tokens, w, axis=0)),
            len=aux["len"].at[flat].set(jnp.repeat(lengths, w, axis=0)),
        )
        for key, params, mcfg in self._branches():
            b = aux[key]
            logits, cache = self.prefill_fn(
                params, mcfg, tokens, lengths, init_cache(mcfg, r, s_max)
            )
            cache.pop("len")
            out[key] = {
                "cache": splice_dense_slots(
                    b["cache"], flat,
                    jax.tree.map(lambda x: jnp.repeat(x, w, axis=1), cache),
                ),
                "logits": b["logits"].at[flat].set(
                    jnp.repeat(logits, w, axis=0)
                ),
            }
        return out

    def init_ring_aux(self, cfg, proto_root_states, capacity: int):
        """Per-request KV staging rows for the device-resident serving ring:
        one prefilled cache row + root logits per staged request, spliced to
        all ``w`` sibling slots at in-loop admission."""
        del cfg
        from ..models import init_cache

        c = int(capacity)
        s_max = int(jnp.shape(proto_root_states.tokens)[-1])
        ring = {
            "tokens": jnp.zeros((c, s_max), jnp.int32),
            "len": jnp.zeros((c,), jnp.int32),
            "pol": (), "rew": (),
        }
        for key, _, mcfg in self._branches():
            cache = init_cache(mcfg, c, s_max)
            cache.pop("len")
            ring[key] = {
                "cache": cache,
                "logits": jnp.zeros((c, mcfg.vocab_size), jnp.float32),
            }
        return ring

    def stage_ring_aux(self, cfg, aux, ring_aux, slots, root_states):
        """Prefill the staged requests NOW (host-paced, between segments) so
        in-loop admission is a pure gather — the dense half of ``admit_aux``
        split at the prefill/splice boundary."""
        del cfg
        from ..models import init_cache

        tokens = jnp.asarray(root_states.tokens, jnp.int32)
        lengths = jnp.asarray(root_states.length, jnp.int32)
        r = tokens.shape[0]
        s_max = ring_aux["tokens"].shape[-1]
        out = dict(
            ring_aux,
            tokens=ring_aux["tokens"].at[slots].set(tokens),
            len=ring_aux["len"].at[slots].set(lengths),
        )
        for key, params, mcfg in self._branches():
            rb = ring_aux[key]
            logits, cache = self.prefill_fn(
                params, mcfg, tokens, lengths, init_cache(mcfg, r, s_max)
            )
            cache.pop("len")
            out[key] = {
                "cache": jax.tree.map(
                    lambda b, x: b.at[:, slots].set(x), rb["cache"], cache
                ),
                "logits": rb["logits"].at[slots].set(logits),
            }
        return aux, out

    def admit_aux_from_ring(self, cfg, aux, ring_aux, slot, mask, w):
        """In-loop admission splice: gather the staged ring rows into the
        admitted rows' ``w`` sibling slots with a masked select (the
        traceable twin of ``admit_aux``'s scatter)."""
        del cfg
        src = jnp.repeat(slot, w)
        fm = jnp.repeat(mask, w)
        out = dict(
            aux,
            tokens=jnp.where(
                fm[:, None], ring_aux["tokens"][src], aux["tokens"]
            ),
            len=jnp.where(fm, ring_aux["len"][src], aux["len"]),
        )
        for key, _, _ in self._branches():
            b, rb = aux[key], ring_aux[key]
            cache = jax.tree.map(
                lambda cur, stg: jnp.where(
                    fm.reshape((1, -1) + (1,) * (cur.ndim - 2)),
                    stg[:, src],
                    cur,
                ),
                b["cache"], rb["cache"],
            )
            out[key] = {
                "cache": cache,
                "logits": jnp.where(
                    fm[:, None], rb["logits"][src], b["logits"]
                ),
            }
        return out, ring_aux

    def _catch_up(self, sub, target, r, s_max):
        """Re-decode each row's divergent suffix in batched ragged chunks.

        One ``models.decode_chunk`` dispatch advances every behind row by up
        to ``refill_chunk`` tokens at its own offset — ``ceil(suffix / C)``
        model calls per refill instead of ``suffix`` single-token decode
        steps (the while_loop of decode_steps this replaces dominated
        shallow-depth ticks; see BENCH_model_eval.json's d8 rows).
        """
        c_sz = min(self.refill_chunk, s_max)
        del r

        def cond(c):
            return jnp.any(c["len"] < target)

        def body(c):
            base = c["len"]
            behind = base < target
            gpos = jnp.minimum(
                base[:, None] + jnp.arange(c_sz)[None, :], s_max - 1
            )
            toks = jnp.take_along_axis(c["tokens"], gpos, axis=1)
            out = dict(c, pol=(), rew=())
            new_len = base
            for key, params, cfg in self._branches():
                b = c[key]
                logits, cache = self.chunk_fn(
                    params, cfg, toks, target, dict(b["cache"], len=base)
                )
                new_len = cache.pop("len")
                # Rows that finish inside this chunk got their final-position
                # logits from the gather; later chunks never touch them.
                fin = behind & (new_len >= target)
                out[key] = {
                    "cache": cache,
                    "logits": jnp.where(
                        fin[:, None], logits, b["logits"]
                    ).astype(b["logits"].dtype),
                }
            out["len"] = new_len
            return out

        return jax.lax.while_loop(cond, body, sub)

    def aux_len(self, aux) -> Optional[jax.Array]:
        return aux["len"]

    def aux_last_logits(self, aux) -> Optional[jax.Array]:
        return aux["pol"]["logits"]

    def tick(self, cfg, kind, act, state, rollout_done, acc, disc, steps, keys,
             aux=()):
        if isinstance(aux, tuple) and aux == ():
            raise ValueError(
                "CachedModelEvaluator.tick needs its slot-aux cache "
                "(init_aux); it runs only inside the async engines — build "
                "with SearchSpec(engine='async') / build_searcher, or use "
                "ModelEvaluator for cache-free evaluation"
            )
        pol = aux["pol"]["logits"]
        rew = aux["rew"]["logits"] if aux["rew"] != () else pol
        out, token = self._transition(
            cfg, kind, act, state, rollout_done, acc, disc, steps, keys, pol,
            rew,
        )
        # Exactly the slots whose env state appended a token this tick.
        fed = (kind != FREE) & jnp.logical_not(state.done)
        return out, self._advance(aux, token, fed)


# ---------------------------------------------------------------------------
# PagedCachedModelEvaluator — shared block pool + per-slot page tables.
# ---------------------------------------------------------------------------


class PagedCachedModelEvaluator(CachedModelEvaluator):
    """:class:`CachedModelEvaluator` over a paged (block-sparse) KV layout.

    Dense slot caches give every in-flight slot a private ``[max_len]`` KV
    row — ``B·W`` slots cost ``B·W·max_len`` rows of HBM even though sibling
    slots share their root prompt (and, after refills, long tree prefixes)
    by construction.  This evaluator stores K/V in a shared block pool
    (:func:`repro.models.init_paged_cache`) and addresses it through
    per-slot page tables, so shared prefixes are stored ONCE:

    * :meth:`init_aux` prefills each distinct root prompt once (one ragged
      batched forward over the ``B`` roots, not ``B·W`` slots), scatters the
      dense rows into pool pages, and points all ``W`` sibling slots' tables
      at the same pages (refcount ``W``);
    * decode writes copy-on-write: a slot about to write into a block with
      ``refcount > 1`` first copies it to a freshly allocated private block
      (one drop-mode gather/scatter over the pool), so siblings never see
      each other's divergent suffixes;
    * :meth:`refill_aux` rollback is a page-table edit — suffix pages are
      refcount-decremented back into the free pool
      (:func:`repro.models.release_pages`) and only the divergent suffix
      re-decodes.

    Attention runs through ``models.paged_decode_step`` →
    ``paged_decode_attention`` (the page-table Pallas kernel on TPU, its
    gather-based jnp oracle elsewhere).  Pool exhaustion inside jitted code
    latches the aux ``oom`` counter; :meth:`check_exhausted` (and eager
    ``init_aux``) surface it as
    :class:`repro.models.PagePoolExhaustedError`.

    Aux layout (flat slot axis ``N``; pool leaves are global):

    * ``tokens i32[N, S]`` / ``len i32[N]`` — as the dense evaluator;
    * ``table i32[N, max_pages]`` — pool block id per logical page; entries
      at page indices ``>= ceil(len/block_size)`` are garbage;
    * ``refcount i32[P]`` / ``oom i32[]`` — shared across branches (policy
      and reward models see the same token stream, so one table/refcount
      serves both; each branch owns its own pools);
    * ``pol/rew`` — ``{"k": [L, P, bs, Hkv, D], "v": ..., "logits": [N, V]}``.
    """

    def __init__(
        self,
        model_cfg,
        params,
        *,
        top_k: int,
        block_size: int,
        num_blocks: int,
        eos_token: int = 0,
        reward_cfg=None,
        reward_params=None,
        value_fn: Optional[Callable] = None,
        prefill_fn: Optional[Callable] = None,
        paged_decode_fn: Optional[Callable] = None,
    ):
        super().__init__(
            model_cfg, params, top_k=top_k, eos_token=eos_token,
            reward_cfg=reward_cfg, reward_params=reward_params,
            value_fn=value_fn, prefill_fn=prefill_fn,
        )
        if paged_decode_fn is None:
            from ..models import paged_decode_step as paged_decode_fn
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        if num_blocks < 1:
            raise ValueError(f"num_blocks must be >= 1, got {num_blocks}")
        self.block_size = block_size
        self.num_blocks = num_blocks
        self.paged_decode_fn = paged_decode_fn

    def _maybe_raise(self, oom) -> None:
        """Surface a latched pool-exhaustion counter at an eager boundary."""
        from ..models import PagePoolExhaustedError

        try:
            n = int(oom)
        except (
            TypeError,
            jax.errors.ConcretizationTypeError,
            jax.errors.TracerArrayConversionError,
        ):
            return
        if n:
            raise PagePoolExhaustedError(
                f"KV block pool exhausted: {n} page allocation(s) failed "
                f"(num_blocks={self.num_blocks}, "
                f"block_size={self.block_size}); grow num_blocks or reduce "
                "concurrent slots"
            )

    def check_exhausted(self, aux) -> None:
        """Raise :class:`PagePoolExhaustedError` if any jitted allocation
        failed since ``init_aux`` (call after a search settles)."""
        self._maybe_raise(aux["oom"])

    # -- aux structure helpers ---------------------------------------------

    def _take_rows(self, aux, rows):
        def branch(b):
            if b == ():
                return ()
            return {"k": b["k"], "v": b["v"], "logits": b["logits"][rows]}

        return {
            "tokens": aux["tokens"][rows],
            "len": aux["len"][rows],
            "table": aux["table"][rows],
            "refcount": aux["refcount"],
            "oom": aux["oom"],
            "pol": branch(aux["pol"]),
            "rew": branch(aux["rew"]),
        }

    def _put_rows(self, aux, rows, sub):
        def branch(b, sb):
            if b == ():
                return ()
            return {
                "k": sb["k"], "v": sb["v"],
                "logits": b["logits"].at[rows].set(sb["logits"]),
            }

        return {
            "tokens": aux["tokens"].at[rows].set(sub["tokens"]),
            "len": aux["len"].at[rows].set(sub["len"]),
            "table": aux["table"].at[rows].set(sub["table"]),
            "refcount": sub["refcount"],
            "oom": sub["oom"],
            "pol": branch(aux["pol"], sub["pol"]),
            "rew": branch(aux["rew"], sub["rew"]),
        }

    def _page_write(self, table, refcount, oom, idx, pos, write):
        """Resolve the physical target for one K/V row write per slot.

        Page bookkeeping per ``write`` slot targeting position ``pos``:

        * ``off == 0`` — the slot is entering a fresh logical page: allocate
          a block and point the table at it;
        * ``off > 0`` and the current block is shared (``refcount > 1``) —
          copy-on-write: allocate, copy the block, decref the shared one;
        * otherwise the slot owns the block exclusively and writes in place.

        Non-write slots never touch the pool (sentinel target, drop-mode
        scatter), so a masked slot can never corrupt a page — shared or
        not.  Allocation failure latches ``oom`` and skips the write.

        Returns ``(table, refcount, oom, wb, off, copy_src, copy_dst)``:
        ``wb`` is the write block per slot (pool size == "no write");
        ``copy_src``/``copy_dst`` drive the per-branch COW pool copy
        (``dst == pool size`` drops).
        """
        from ..models import alloc_blocks

        bs = self.block_size
        p = refcount.shape[0]
        bi = pos // bs
        off = pos % bs
        cur = table[idx, bi]
        cur_c = jnp.clip(cur, 0, p - 1)
        started = off > 0               # page already holds this slot's rows
        shared = refcount[cur_c] > 1
        need_new = write & (~started | shared)
        is_cow = write & started & shared
        blocks, refcount, n_fail = alloc_blocks(refcount, need_new)
        got = need_new & (blocks < p)
        oom = oom + n_fail
        refcount = refcount.at[
            jnp.where(is_cow & got, cur_c, p)
        ].add(-1, mode="drop")
        table = table.at[idx, bi].set(jnp.where(got, blocks, cur))
        ok = write & jnp.where(need_new, got, True)
        wb = jnp.where(ok, jnp.clip(table[idx, bi], 0, p - 1), p)
        copy_src = jnp.where(is_cow & got, cur_c, 0)
        copy_dst = jnp.where(is_cow & got, blocks, p)
        return table, refcount, oom, wb, off, copy_src, copy_dst

    def _advance(self, aux, token, fed):
        """Feed one token per slot: COW resolution → allocation → one batched
        ``paged_decode_step`` per model (bookkeeping in :meth:`_page_write`).
        """
        idx = jnp.arange(token.shape[0])
        s_max = aux["tokens"].shape[-1]
        length = aux["len"]
        safe = jnp.minimum(length, s_max - 1)
        prev = aux["tokens"][idx, safe]
        tokens = aux["tokens"].at[idx, safe].set(jnp.where(fed, token, prev))

        table, refcount, oom, wb, off, copy_src, copy_dst = self._page_write(
            aux["table"], aux["refcount"], aux["oom"], idx, safe, fed
        )
        p = refcount.shape[0]
        att_len = length + jnp.where(wb < p, 1, 0)

        out = dict(
            tokens=tokens,
            len=jnp.where(fed, length + 1, length),
            table=table, refcount=refcount, oom=oom,
            pol=(), rew=(),
        )
        for key, params, cfg in self._branches():
            b = aux[key]
            pk = b["k"].at[:, copy_dst].set(b["k"][:, copy_src], mode="drop")
            pv = b["v"].at[:, copy_dst].set(b["v"][:, copy_src], mode="drop")
            logits, cache = self.paged_decode_fn(
                params, cfg, token,
                {
                    "k": pk, "v": pv, "table": table, "len": att_len,
                    "pos": safe, "write_block": wb, "write_off": off,
                },
            )
            out[key] = {
                "k": cache["k"], "v": cache["v"],
                "logits": jnp.where(
                    fed[:, None], logits, b["logits"]
                ).astype(b["logits"].dtype),
            }
        return out

    # -- evaluator protocol -------------------------------------------------

    def init_aux(self, root_states: Pytree, prefix: tuple) -> Pytree:
        """Prefill each DISTINCT root once; siblings share its pages.

        The ragged batched prefill runs over the ``prod(prefix[:-1])`` roots
        (vs every slot in the dense evaluator), its dense rows scatter into
        sequentially allocated pool pages, and all ``W = prefix[-1]`` slots
        of a root point at the same pages with refcount ``W`` — including
        the last partial page: the first write a slot makes there triggers
        copy-on-write, so sharing is safe from tick zero.
        """
        from ..models import init_cache
        from ..models.paged import num_pages

        n = 1
        for q in prefix:
            n *= int(q)
        w = int(prefix[-1])
        r0 = n // w
        lead = len(prefix) - 1

        def flat(x):
            x = jnp.expand_dims(x, lead)
            x = jnp.broadcast_to(x, tuple(prefix) + x.shape[lead + 1:])
            return x.reshape((n,) + x.shape[len(prefix):])

        state = jax.tree.map(flat, root_states)
        tokens = jnp.asarray(state.tokens, jnp.int32)
        lengths = jnp.asarray(state.length, jnp.int32)
        s_max = tokens.shape[-1]
        bs, p = self.block_size, self.num_blocks
        mp = num_pages(s_max, bs)

        root_tokens = tokens[::w]
        root_len = lengths[::w]
        p_r = (root_len + bs - 1) // bs              # pages per root
        offsets = jnp.cumsum(p_r) - p_r              # sequential block ids
        page_idx = jnp.arange(mp)
        valid = page_idx[None, :] < p_r[:, None]
        dst_raw = offsets[:, None] + page_idx[None, :]
        got = valid & (dst_raw < p)
        dst = jnp.where(got, dst_raw, p).astype(jnp.int32)   # [r0, mp]
        oom = jnp.sum(valid & ~got).astype(jnp.int32)
        refcount = (
            jnp.zeros((p,), jnp.int32)
            .at[dst.reshape(-1)]
            .add(jnp.where(got.reshape(-1), w, 0), mode="drop")
        )
        aux = {
            "tokens": tokens,
            "len": lengths,
            "table": jnp.repeat(dst, w, axis=0),
            "refcount": refcount,
            "oom": oom,
            "pol": (),
            "rew": (),
        }
        for key, params, cfg in self._branches():
            logits, cache = self.prefill_fn(
                params, cfg, root_tokens, root_len,
                init_cache(cfg, r0, mp * bs),
            )
            kv = cache["kv"]

            def to_pool(x):
                l_, _, _, hk, hd = x.shape
                pages = x.reshape(l_, r0 * mp, bs, hk, hd)
                pool = jnp.zeros((l_, p, bs, hk, hd), x.dtype)
                return pool.at[:, dst.reshape(-1)].set(pages, mode="drop")

            aux[key] = {
                "k": to_pool(kv["k"]),
                "v": to_pool(kv["v"]),
                "logits": jnp.repeat(logits, w, axis=0),
            }
        self._maybe_raise(aux["oom"])
        return aux

    def refill_aux(self, cfg, aux, rows, new_state, mask):
        """Rollback = page-table edit; catch-up = batched ragged chunks.

        Suffix pages wholly beyond the common prefix are refcount-released
        (no cache rows rewritten); the retained partial boundary page is
        still shared, so the first catch-up write into it copies-on-write.
        The divergent suffix then re-decodes through the SAME chunked
        ``models.decode_chunk`` path as the dense evaluator
        (:meth:`_paged_catch_up`): the whole suffix's page-allocation
        schedule is resolved up front, the rows' pages are materialized
        dense, and only the written (now-private) pages scatter back.
        """
        del cfg
        from ..models import release_pages

        sub = self._take_rows(aux, rows)
        r = rows.shape[0]
        s_max = sub["tokens"].shape[-1]
        start, target, tokens, _ = self._rollback_targets(sub, new_state, mask)
        bs = self.block_size
        lo = (start + bs - 1) // bs
        hi = (sub["len"] + bs - 1) // bs
        refcount = release_pages(sub["refcount"], sub["table"], lo, hi)
        sub = dict(sub, tokens=tokens, len=start, refcount=refcount)
        sub = self._paged_catch_up(sub, target, r, s_max)
        return self._put_rows(aux, rows, sub), jnp.zeros((r,), jnp.bool_)

    def admit_aux(self, cfg, aux, rows, root_states, w):
        """Mid-stream admission: page release → re-prefill → table splice.

        The rows' slots first return everything they still hold to the pool
        (rows evicted earlier hold nothing — their ``len`` is zero, so the
        release is a no-op and pages are never double-freed).  Each admitted
        root then prefills ONCE (the shared
        :mod:`repro.serving.admission` ragged forward), its dense rows
        scatter into freshly allocated pool pages
        (:func:`repro.serving.admission.splice_pool_pages`), and all ``w``
        sibling slots' tables point at the same pages with refcount ``w`` —
        the same prefix-sharing layout ``init_aux`` builds at cold start.
        Runs at an eager boundary, so exhaustion raises
        :class:`repro.models.PagePoolExhaustedError` immediately.
        """
        del cfg
        from ..models import alloc_blocks, init_cache, release_pages
        from ..serving.admission import splice_pool_pages

        flat = _flat_slot_rows(rows, w)
        tokens = jnp.asarray(root_states.tokens, jnp.int32)
        lengths = jnp.asarray(root_states.length, jnp.int32)
        r = tokens.shape[0]
        bs, p = self.block_size, self.num_blocks
        mp = aux["table"].shape[1]

        hi = (aux["len"][flat] + bs - 1) // bs
        refcount = release_pages(
            aux["refcount"], aux["table"][flat], jnp.zeros_like(hi), hi
        )

        # Fresh page schedule: one block per root page, fanned out to the w
        # sibling slots (alloc_blocks hands out refcount 1; the fan-out adds
        # the other w - 1 sharers).
        p_r = (lengths + bs - 1) // bs
        dst = jnp.full((r, mp), p, jnp.int32)
        oom = aux["oom"]
        for pi in range(mp):
            need = pi < p_r
            blocks, refcount, n_fail = alloc_blocks(refcount, need)
            dst = dst.at[:, pi].set(
                jnp.where(need & (blocks < p), blocks, p)
            )
            oom = oom + n_fail
        refcount = refcount.at[dst.reshape(-1)].add(
            jnp.where((dst < p).reshape(-1), w - 1, 0), mode="drop"
        )

        out = dict(
            aux,
            tokens=aux["tokens"].at[flat].set(jnp.repeat(tokens, w, axis=0)),
            len=aux["len"].at[flat].set(jnp.repeat(lengths, w, axis=0)),
            table=aux["table"].at[flat].set(jnp.repeat(dst, w, axis=0)),
            refcount=refcount,
            oom=oom,
        )
        for key, params, mcfg in self._branches():
            b = aux[key]
            logits, cache = self.prefill_fn(
                params, mcfg, tokens, lengths, init_cache(mcfg, r, mp * bs)
            )
            kv = cache["kv"]
            pk, pv = splice_pool_pages(b["k"], b["v"], kv["k"], kv["v"], dst)
            out[key] = {
                "k": pk, "v": pv,
                "logits": b["logits"].at[flat].set(
                    jnp.repeat(logits, w, axis=0)
                ),
            }
        self._maybe_raise(out["oom"])
        return out

    def evict_aux(self, aux, rows, w):
        """Return settled rows' pages to the pool without admitting.

        Tables drop to the sentinel and ``len`` to zero, so the rows' frozen
        FREE slots never dereference a released block (garbage-table
        entries are clipped + len-masked by the decode path regardless),
        and a later :meth:`admit_aux` release of the same rows is a no-op.
        """
        from ..models import release_pages

        flat = _flat_slot_rows(rows, w)
        bs = self.block_size
        mp = aux["table"].shape[1]
        hi = (aux["len"][flat] + bs - 1) // bs
        refcount = release_pages(
            aux["refcount"], aux["table"][flat], jnp.zeros_like(hi), hi
        )
        return dict(
            aux,
            refcount=refcount,
            table=aux["table"].at[flat].set(
                jnp.full((flat.shape[0], mp), self.num_blocks, jnp.int32)
            ),
            len=aux["len"].at[flat].set(0),
        )

    def init_ring_aux(self, cfg, proto_root_states, capacity: int):
        """Ring staging for the paged evaluator: tokens, a page table and
        root logits per slot.  The KV bytes themselves are NOT staged — a
        staged request's pages live in the shared pool already (written by
        :meth:`stage_ring_aux`, held at refcount 1 by the ring), so in-loop
        admission is a table splice + refcount fan-out."""
        del cfg
        from ..models.paged import num_pages

        c = int(capacity)
        s_max = int(jnp.shape(proto_root_states.tokens)[-1])
        mp = num_pages(s_max, self.block_size)
        ring = {
            "tokens": jnp.zeros((c, s_max), jnp.int32),
            "len": jnp.zeros((c,), jnp.int32),
            "table": jnp.full((c, mp), self.num_blocks, jnp.int32),
            "pol": (), "rew": (),
        }
        for key, _, mcfg in self._branches():
            ring[key] = {
                "logits": jnp.zeros((c, mcfg.vocab_size), jnp.float32),
            }
        return ring

    def stage_ring_aux(self, cfg, aux, ring_aux, slots, root_states):
        """Allocate + prefill the staged requests' pool pages now.

        Pages come out of the live slot-aux refcounts (the serving layer
        budgets against them before staging), are written by one ragged
        prefill, and sit at refcount 1 owned by the ring until in-loop
        admission transfers them to the admitted row.  Pool exhaustion
        latches ``oom`` (checked eagerly by the caller after the round) —
        this path must stay traceable.
        """
        del cfg
        from ..models import alloc_blocks, init_cache
        from ..serving.admission import splice_pool_pages

        tokens = jnp.asarray(root_states.tokens, jnp.int32)
        lengths = jnp.asarray(root_states.length, jnp.int32)
        r = tokens.shape[0]
        bs, p = self.block_size, self.num_blocks
        mp = ring_aux["table"].shape[1]

        # Engine invariant: ring slots outside the staged window hold
        # nothing (cleared at admission), so no release is needed here.
        refcount = aux["refcount"]
        p_r = (lengths + bs - 1) // bs
        dst = jnp.full((r, mp), p, jnp.int32)
        oom = aux["oom"]
        for pi in range(mp):
            need = pi < p_r
            blocks, refcount, n_fail = alloc_blocks(refcount, need)
            dst = dst.at[:, pi].set(jnp.where(need & (blocks < p), blocks, p))
            oom = oom + n_fail

        out_ring = dict(
            ring_aux,
            tokens=ring_aux["tokens"].at[slots].set(tokens),
            len=ring_aux["len"].at[slots].set(lengths),
            table=ring_aux["table"].at[slots].set(dst),
        )
        out_aux = dict(aux, refcount=refcount, oom=oom)
        for key, params, mcfg in self._branches():
            b = aux[key]
            logits, cache = self.prefill_fn(
                params, mcfg, tokens, lengths, init_cache(mcfg, r, mp * bs)
            )
            kv = cache["kv"]
            pk, pv = splice_pool_pages(b["k"], b["v"], kv["k"], kv["v"], dst)
            out_aux[key] = dict(b, k=pk, v=pv)
            out_ring[key] = {
                "logits": ring_aux[key]["logits"].at[slots].set(logits),
            }
        return out_aux, out_ring

    def admit_aux_from_ring(self, cfg, aux, ring_aux, slot, mask, w):
        """In-loop paged admission: table splice + refcount fan-out.

        Admission targets are always fully evicted rows (the fused round
        evicts completed rows before admitting), so there is nothing to
        release.  The ring's single page reference transfers to the first
        sibling slot; the fan-out adds the other ``w - 1`` sharers — the
        same prefix-sharing layout ``admit_aux`` builds eagerly.  Consumed
        ring slots drop to the sentinel so a later re-staging of the same
        slot never double-frees.
        """
        del cfg
        src = jnp.repeat(slot, w)
        fm = jnp.repeat(mask, w)
        p = self.num_blocks
        cap = ring_aux["len"].shape[0]
        dst = ring_aux["table"][slot]                       # [B, mp]
        sharers = jnp.where(mask[:, None] & (dst < p), dst, p)
        refcount = aux["refcount"].at[sharers.reshape(-1)].add(
            jnp.where((sharers < p).reshape(-1), w - 1, 0), mode="drop"
        )
        out = dict(
            aux,
            tokens=jnp.where(
                fm[:, None], ring_aux["tokens"][src], aux["tokens"]
            ),
            len=jnp.where(fm, ring_aux["len"][src], aux["len"]),
            table=jnp.where(fm[:, None], ring_aux["table"][src],
                            aux["table"]),
            refcount=refcount,
        )
        for key, _, _ in self._branches():
            out[key] = dict(
                aux[key],
                logits=jnp.where(
                    fm[:, None],
                    ring_aux[key]["logits"][src],
                    aux[key]["logits"],
                ),
            )
        cslot = jnp.where(mask, slot, cap)                  # OOB = untouched
        out_ring = dict(
            ring_aux,
            table=ring_aux["table"].at[cslot].set(p, mode="drop"),
            len=ring_aux["len"].at[cslot].set(0, mode="drop"),
        )
        return out, out_ring

    def evict_aux_to_ring(self, aux, mask, w):
        """Masked traceable eviction: rows where ``mask`` holds return their
        pages to the pool inside the fused loop (``release_pages`` with
        ``hi = 0`` on unmasked rows is a no-op)."""
        from ..models import release_pages

        fm = jnp.repeat(mask, w)
        bs = self.block_size
        hi = jnp.where(fm, (aux["len"] + bs - 1) // bs, 0)
        refcount = release_pages(
            aux["refcount"], aux["table"], jnp.zeros_like(hi), hi
        )
        return dict(
            aux,
            refcount=refcount,
            table=jnp.where(fm[:, None], self.num_blocks, aux["table"]),
            len=jnp.where(fm, 0, aux["len"]),
        )

    def _paged_catch_up(self, sub, target, r, s_max):
        """Chunked divergent-suffix re-decode over paged rows.

        Page writes no longer interleave with decode steps: every page the
        suffix will touch is resolved FIRST (boundary COW for rows
        re-entering a shared partial page, then one fresh block per whole
        suffix page), which makes all written pages private — so the
        catch-up itself can run as the dense evaluator's batched ragged
        ``decode_chunk`` loop over a dense gather of each row's pages, and
        the written pages scatter back afterwards.  Pages whose allocation
        failed stay masked out of the scatter (shared blocks are never
        corrupted); the failure latches ``oom`` as usual.

        ``sub['len']`` must already hold each row's re-decode start.

        The whole body (boundary COW, page schedule, gather → chunked
        decode → scatter) is gated on any row actually being behind:
        refill_aux runs for every slot every tick, but almost all calls
        are no-ops (nothing settled, or a frontier hit already landed the
        row at its target), and the unconditional bookkeeping alone is
        expensive enough to show up per tick.
        """
        return jax.lax.cond(
            jnp.any(sub["len"] < target),
            lambda op: self._paged_catch_up_behind(op[0], op[1], r, s_max),
            lambda op: op[0],
            (sub, target),
        )

    def _paged_catch_up_behind(self, sub, target, r, s_max):
        from ..models import alloc_blocks

        bs = self.block_size
        p = self.num_blocks
        mp = sub["table"].shape[1]
        idx = jnp.arange(r)
        start = sub["len"]
        behind = start < target

        # Boundary page: rows resuming mid-page COW out of shared blocks.
        bwrite = behind & (start % bs > 0)
        table, refcount, oom, wb, _, copy_src, copy_dst = self._page_write(
            sub["table"], sub["refcount"], sub["oom"], idx,
            jnp.minimum(start, s_max - 1), bwrite,
        )
        page_ok = jnp.ones((r, mp), jnp.bool_).at[
            idx, jnp.clip(start // bs, 0, mp - 1)
        ].set(jnp.where(bwrite, wb < p, True))
        sub = dict(sub, table=table, refcount=refcount, oom=oom)
        for key, _, _ in self._branches():
            b = sub[key]
            sub[key] = dict(
                b,
                k=b["k"].at[:, copy_dst].set(b["k"][:, copy_src], mode="drop"),
                v=b["v"].at[:, copy_dst].set(b["v"][:, copy_src], mode="drop"),
            )

        # Whole-suffix page schedule: one fresh block per page in [lo, hi).
        lo = (start + bs - 1) // bs
        hi = (target + bs - 1) // bs

        def alloc_body(pi, c):
            table, refcount, oom, page_ok = c
            need = behind & (pi >= lo) & (pi < hi)
            blocks, refcount, n_fail = alloc_blocks(refcount, need)
            got = need & (blocks < p)
            table = table.at[:, pi].set(jnp.where(got, blocks, table[:, pi]))
            page_ok = page_ok.at[:, pi].set(
                jnp.where(need, got, page_ok[:, pi])
            )
            return table, refcount, oom + n_fail, page_ok

        table, refcount, oom, page_ok = jax.lax.fori_loop(
            0, mp, alloc_body, (sub["table"], sub["refcount"], sub["oom"],
                                page_ok)
        )
        sub = dict(sub, table=table, refcount=refcount, oom=oom)

        # Dense view → the dense evaluator's chunked catch-up → scatter back.
        t_clip = jnp.clip(table, 0, p - 1)

        def dense(pool):
            out = pool[:, t_clip]                 # [L, R, mp, bs, hkv, hd]
            l_, r_, mp_, bs_, hk, hd = out.shape
            return out.reshape(l_, r_, mp_ * bs_, hk, hd)

        dsub = {"tokens": sub["tokens"], "len": sub["len"],
                "pol": (), "rew": ()}
        for key, _, _ in self._branches():
            b = sub[key]
            dsub[key] = {
                "cache": {"kv": {"k": dense(b["k"]), "v": dense(b["v"])}},
                "logits": b["logits"],
            }
        dsub = self._catch_up(dsub, target, r, s_max)

        pages = jnp.arange(mp)
        changed = (
            behind[:, None]
            & (pages[None, :] >= (start // bs)[:, None])
            & (pages[None, :] < hi[:, None])
            & page_ok
        )
        dst = jnp.where(changed, t_clip, p).reshape(-1)
        out = dict(sub, len=dsub["len"])
        for key, _, _ in self._branches():
            d = dsub[key]["cache"]["kv"]

            def repage(x):
                l_ = x.shape[0]
                return x.reshape(l_, r * mp, bs, *x.shape[3:])

            out[key] = dict(
                sub[key],
                k=sub[key]["k"].at[:, dst].set(repage(d["k"]), mode="drop"),
                v=sub[key]["v"].at[:, dst].set(repage(d["v"]), mode="drop"),
                logits=dsub[key]["logits"],
            )
        return out

    def aux_blocks(self, aux) -> Optional[jax.Array]:
        return jnp.sum(aux["refcount"] > 0)


# ---------------------------------------------------------------------------
# Frontier-speculative expansion: score every candidate child in one forward.
# ---------------------------------------------------------------------------


class _FrontierMixin:
    """Shared frontier-cache logic for the dense and paged evaluators.

    Every tick advance runs through ``models.decode_frontier`` /
    ``paged_decode_frontier``: instead of decoding ONLY the chosen token,
    the slot's ``A = top_k`` candidate children — exactly the action table
    :meth:`ModelEvaluator._transition` decodes ranks against — are scored in
    one tree-batched forward over the shared prefix.  The chosen candidate's
    logits and K/V row commit to the cache (bit-identical to the plain
    decode step), and EXPAND ticks additionally snapshot the whole frontier
    into per-slot aux (``aux['fr']``):

    * ``ptok``/``plen`` — the parent path the frontier was scored FROM;
    * ``cand i32[N, A]`` — the candidate tokens (the transition's top-K);
    * per branch: ``plog`` (the parent position's logits), ``clog [N, A, V]``
      (every candidate's next-position logits) and ``ck``/``cv``
      (``[L, N, A, Hkv, D]``, every candidate's own K/V entry).

    **Refill hits** (:meth:`refill_aux` in the concrete classes): WU-UCT's
    refill assigns the settled slot a tree path that is almost always the
    SAME parent (sibling expansion) or one of its children (deepening) —
    both of which the snapshot already answers:

    * *parent hit* (``len(path) == plen``, path == ptok): restore ``plog``,
      roll ``len`` straight to the target — the standard rollback's forced
      final-token re-decode existed only to regenerate these logits;
    * *child hit* (``len(path) == plen + 1``, last token ∈ ``cand``):
      restore ``clog[rank]`` and commit ``ck``/``cv[rank]`` at position
      ``plen`` — the full refill without any forward.

    Hit rows skip the catch-up loop entirely (zero model dispatches); the
    returned ``hits`` mask feeds the engines' ``frontier_hits`` counter so
    WU-UCT's ``O_s`` accounting is visibly absorbing speculative visits.
    A refill onto a path that diverges from ``ptok`` invalidates the entry.
    """

    def _fr_init(self, aux):
        n, _ = aux["tokens"].shape
        a = self.top_k
        fr = {
            "ptok": jnp.zeros_like(aux["tokens"]),
            "plen": jnp.zeros((n,), jnp.int32),
            "valid": jnp.zeros((n,), jnp.bool_),
            "cand": jnp.zeros((n, a), jnp.int32),
            "pol": (), "rew": (),
        }
        for key, _, cfg in self._branches():
            lg = aux[key]["logits"]
            v = lg.shape[-1]
            fr[key] = {
                "plog": jnp.zeros_like(lg),
                "clog": jnp.zeros((n, a, v), lg.dtype),
                "ck": jnp.zeros(
                    (cfg.num_layers, n, a, cfg.num_kv_heads, cfg.head_dim),
                    cfg.dtype,
                ),
                "cv": jnp.zeros(
                    (cfg.num_layers, n, a, cfg.num_kv_heads, cfg.head_dim),
                    cfg.dtype,
                ),
            }
        return fr

    def init_aux(self, root_states, prefix):
        aux = super().init_aux(root_states, prefix)
        aux["fr"] = self._fr_init(aux)
        return aux

    def _take_rows(self, aux, rows):
        sub = super()._take_rows(aux, rows)
        fr = aux["fr"]

        def br(b):
            if b == ():
                return ()
            return {
                "plog": b["plog"][rows], "clog": b["clog"][rows],
                "ck": b["ck"][:, rows], "cv": b["cv"][:, rows],
            }

        sub["fr"] = {
            "ptok": fr["ptok"][rows], "plen": fr["plen"][rows],
            "valid": fr["valid"][rows], "cand": fr["cand"][rows],
            "pol": br(fr["pol"]), "rew": br(fr["rew"]),
        }
        return sub

    def _put_rows(self, aux, rows, sub):
        out = super()._put_rows(aux, rows, sub)
        fr, sfr = aux["fr"], sub["fr"]

        def br(b, sb):
            if b == ():
                return ()
            return {
                "plog": b["plog"].at[rows].set(sb["plog"]),
                "clog": b["clog"].at[rows].set(sb["clog"]),
                "ck": b["ck"].at[:, rows].set(sb["ck"]),
                "cv": b["cv"].at[:, rows].set(sb["cv"]),
            }

        out["fr"] = {
            "ptok": fr["ptok"].at[rows].set(sfr["ptok"]),
            "plen": fr["plen"].at[rows].set(sfr["plen"]),
            "valid": fr["valid"].at[rows].set(sfr["valid"]),
            "cand": fr["cand"].at[rows].set(sfr["cand"]),
            "pol": br(fr["pol"], sfr["pol"]),
            "rew": br(fr["rew"], sfr["rew"]),
        }
        return out

    def admit_aux(self, cfg, aux, rows, root_states, w):
        """Admission invalidates the rows' frontier snapshots: they were
        taken against the previous request's tree and must never answer the
        new request's refills.  ``_take_rows``/``_put_rows`` thread ``fr``
        through the base splice, so only the validity bit needs clearing."""
        fr = aux["fr"]
        out = super().admit_aux(cfg, dict(aux, fr=()), rows, root_states, w)
        out["fr"] = dict(
            fr, valid=fr["valid"].at[_flat_slot_rows(rows, w)].set(False)
        )
        return out

    def evict_aux(self, aux, rows, w):
        fr = aux["fr"]
        out = super().evict_aux(dict(aux, fr=()), rows, w)
        out["fr"] = dict(
            fr, valid=fr["valid"].at[_flat_slot_rows(rows, w)].set(False)
        )
        return out

    def stage_ring_aux(self, cfg, aux, ring_aux, slots, root_states):
        """Frontier snapshots are per-slot, not per-request — nothing to
        stage; shield ``fr`` from the base staging path."""
        fr = aux["fr"]
        out_aux, out_ring = super().stage_ring_aux(
            cfg, dict(aux, fr=()), ring_aux, slots, root_states
        )
        return dict(out_aux, fr=fr), out_ring

    def admit_aux_from_ring(self, cfg, aux, ring_aux, slot, mask, w):
        """In-loop admission invalidates the rows' frontier snapshots, same
        as the eager ``admit_aux`` — masked select instead of scatter."""
        fr = aux["fr"]
        out, out_ring = super().admit_aux_from_ring(
            cfg, dict(aux, fr=()), ring_aux, slot, mask, w
        )
        out["fr"] = dict(
            fr, valid=jnp.where(jnp.repeat(mask, w), False, fr["valid"])
        )
        return out, out_ring

    def evict_aux_to_ring(self, aux, mask, w):
        fr = aux["fr"]
        out = super().evict_aux_to_ring(dict(aux, fr=()), mask, w)
        out = dict(out)
        out["fr"] = dict(
            fr, valid=jnp.where(jnp.repeat(mask, w), False, fr["valid"])
        )
        return out

    def _fr_record(self, fr, pre_tokens, length, cand, is_exp):
        """Snapshot the parent path + candidate set on EXPAND rows."""
        exp2 = is_exp[:, None]
        return dict(
            fr,
            ptok=jnp.where(exp2, pre_tokens, fr["ptok"]),
            plen=jnp.where(is_exp, length, fr["plen"]),
            valid=fr["valid"] | is_exp,
            cand=jnp.where(exp2, cand, fr["cand"]),
        )

    def _frontier_hits(self, sub, tokens, new_state, common, mask):
        """Classify each refill row against its frontier snapshot.

        Returns ``(parent_hit, child_hit, crank, pmatch)``; ``crank`` is the
        matched candidate's rank (valid only under ``child_hit``).  Both hit
        kinds require the CACHE to still hold the parent prefix (via the
        uncapped ``common``) *and* the new path to match the snapshot's
        parent path (``pmatch``) — the two can diverge independently after
        intervening refills.
        """
        fr = sub["fr"]
        s_max = tokens.shape[-1]
        r = tokens.shape[0]
        idx = jnp.arange(r)
        pos = jnp.arange(s_max)
        l_new = jnp.asarray(new_state.length, jnp.int32)
        plen = fr["plen"]
        cmp_len = jnp.minimum(plen, l_new)
        pmatch = jnp.logical_not(
            jnp.any(
                (fr["ptok"] != tokens) & (pos[None, :] < cmp_len[:, None]),
                axis=1,
            )
        )
        last = tokens[idx, jnp.clip(l_new - 1, 0, s_max - 1)]
        is_cand = fr["cand"] == last[:, None]
        crank = jnp.argmax(is_cand, axis=1)
        ok = mask & fr["valid"] & pmatch
        parent_hit = ok & (l_new == plen) & (common >= l_new)
        child_hit = (
            ok & (l_new == plen + 1) & jnp.any(is_cand, axis=1)
            & (common >= plen)
        )
        return parent_hit, child_hit, crank, pmatch

    def tick(self, cfg, kind, act, state, rollout_done, acc, disc, steps, keys,
             aux=()):
        if isinstance(aux, tuple) and aux == ():
            raise ValueError(
                "frontier evaluators need their slot-aux cache (init_aux); "
                "they run only inside the async engines — build with "
                "SearchSpec(engine='async') / build_searcher"
            )
        pol = aux["pol"]["logits"]
        rew = aux["rew"]["logits"] if aux["rew"] != () else pol
        out, token = self._transition(
            cfg, kind, act, state, rollout_done, acc, disc, steps, keys, pol,
            rew,
        )
        fed = (kind != FREE) & jnp.logical_not(state.done)
        is_exp = fed & (kind == EXPAND)
        # Only EXPAND rows need the A-wide frontier snapshot; ticks where
        # every fed slot is mid-rollout (the majority — expansions number
        # num_simulations, ticks number far more) take the plain one-token
        # advance and carry the snapshot through untouched.
        aux2 = jax.lax.cond(
            jnp.any(is_exp),
            lambda op: self._advance_frontier(*op),
            lambda op: dict(
                self._advance(op[0], op[1], op[2]), fr=op[0]["fr"]
            ),
            (aux, token, fed, is_exp),
        )
        return out, aux2


class FrontierModelEvaluator(_FrontierMixin, CachedModelEvaluator):
    """:class:`CachedModelEvaluator` with frontier-speculative expansion.

    Tick advances run ``models.decode_frontier`` (tree-batched candidate
    scoring over the dense per-slot cache); refills of the snapshotted
    parent or any of its candidate children are answered from aux with zero
    model forwards.  See :class:`_FrontierMixin` for the cache semantics.
    """

    def __init__(self, model_cfg, params, *, top_k: int, eos_token: int = 0,
                 reward_cfg=None, reward_params=None,
                 value_fn: Optional[Callable] = None,
                 decode_fn: Optional[Callable] = None,
                 prefill_fn: Optional[Callable] = None,
                 chunk_fn: Optional[Callable] = None,
                 refill_chunk: int = 8,
                 frontier_fn: Optional[Callable] = None):
        super().__init__(
            model_cfg, params, top_k=top_k, eos_token=eos_token,
            reward_cfg=reward_cfg, reward_params=reward_params,
            value_fn=value_fn, decode_fn=decode_fn, prefill_fn=prefill_fn,
            chunk_fn=chunk_fn, refill_chunk=refill_chunk,
        )
        if frontier_fn is None:
            from ..models import decode_frontier as frontier_fn
        self.frontier_fn = frontier_fn

    def _advance_frontier(self, aux, token, fed, is_exp):
        """One tree-batched frontier forward advances every slot.

        The chosen candidate's logits and K/V row commit exactly as
        :meth:`CachedModelEvaluator._advance` would have (same math: each
        candidate attends the prefix plus itself); EXPAND rows snapshot the
        full candidate set into ``aux['fr']``.
        """
        idx = jnp.arange(token.shape[0])
        s_max = aux["tokens"].shape[-1]
        length = aux["len"]
        safe = jnp.minimum(length, s_max - 1)
        prev = aux["tokens"][idx, safe]
        tokens = aux["tokens"].at[idx, safe].set(jnp.where(fed, token, prev))

        # The same deterministic top-K table _transition decoded the action
        # against — the fed token is one of these candidates by construction.
        _, cand = jax.lax.top_k(aux["pol"]["logits"], self.top_k)
        rank = jnp.argmax(cand == token[:, None], axis=1)

        fr = self._fr_record(aux["fr"], aux["tokens"], length, cand, is_exp)
        out = dict(
            tokens=tokens,
            len=jnp.where(fed, length + 1, length),
            pol=(), rew=(),
        )
        for key, params, cfg in self._branches():
            b = aux[key]
            clog, spec = self.frontier_fn(
                params, cfg, cand, dict(b["cache"], len=safe)
            )
            chosen = clog[idx, rank]
            rk = rank.reshape(1, -1, 1, 1, 1)
            row_k = jnp.take_along_axis(spec["k"], rk, axis=2)[:, :, 0]
            row_v = jnp.take_along_axis(spec["v"], rk, axis=2)[:, :, 0]
            kv = b["cache"]["kv"]
            kv = {
                "k": kv["k"].at[:, idx, safe].set(row_k),
                "v": kv["v"].at[:, idx, safe].set(row_v),
            }
            out[key] = {
                "cache": dict(b["cache"], kv=kv),
                "logits": jnp.where(
                    fed[:, None], chosen, b["logits"]
                ).astype(b["logits"].dtype),
            }
            fb = fr[key]
            fr[key] = {
                "plog": jnp.where(is_exp[:, None], b["logits"], fb["plog"]),
                "clog": jnp.where(
                    is_exp[:, None, None], clog, fb["clog"]
                ).astype(fb["clog"].dtype),
                "ck": jnp.where(
                    is_exp[None, :, None, None, None], spec["k"], fb["ck"]
                ).astype(fb["ck"].dtype),
                "cv": jnp.where(
                    is_exp[None, :, None, None, None], spec["v"], fb["cv"]
                ).astype(fb["cv"].dtype),
            }
        out["fr"] = fr
        return out

    def refill_aux(self, cfg, aux, rows, new_state, mask):
        del cfg
        sub = self._take_rows(aux, rows)
        r = rows.shape[0]
        s_max = sub["tokens"].shape[-1]
        idx = jnp.arange(r)
        start, target, tokens, common = self._rollback_targets(
            sub, new_state, mask
        )
        parent_hit, child_hit, crank, pmatch = self._frontier_hits(
            sub, tokens, new_state, common, mask
        )
        hit = parent_hit | child_hit
        fr = sub["fr"]
        sub["fr"] = dict(
            fr, valid=jnp.where(mask, fr["valid"] & pmatch, fr["valid"])
        )
        sub = dict(sub, tokens=tokens, len=jnp.where(hit, target, start))
        cpos = jnp.clip(fr["plen"], 0, s_max - 1)
        rk = crank.reshape(1, -1, 1, 1, 1)
        for key, _, _ in self._branches():
            b = sub[key]
            fb = fr[key]
            logits = jnp.where(parent_hit[:, None], fb["plog"], b["logits"])
            logits = jnp.where(
                child_hit[:, None], fb["clog"][idx, crank], logits
            ).astype(b["logits"].dtype)
            row_k = jnp.take_along_axis(fb["ck"], rk, axis=2)[:, :, 0]
            row_v = jnp.take_along_axis(fb["cv"], rk, axis=2)[:, :, 0]
            kv = b["cache"]["kv"]
            ch = child_hit[None, :, None, None]
            kv = {
                "k": kv["k"].at[:, idx, cpos].set(
                    jnp.where(ch, row_k, kv["k"][:, idx, cpos])
                ),
                "v": kv["v"].at[:, idx, cpos].set(
                    jnp.where(ch, row_v, kv["v"][:, idx, cpos])
                ),
            }
            sub[key] = {"cache": dict(b["cache"], kv=kv), "logits": logits}
        sub = self._catch_up(sub, target, r, s_max)
        return self._put_rows(aux, rows, sub), hit


class PagedFrontierModelEvaluator(_FrontierMixin, PagedCachedModelEvaluator):
    """:class:`PagedCachedModelEvaluator` with frontier-speculative expansion.

    Same frontier cache as :class:`FrontierModelEvaluator` over the shared
    block pool: candidate scoring reads the prefix straight from the pages
    (``models.paged_decode_frontier`` — no dense gather), and a child hit
    commits its cached K/V row through the usual page bookkeeping
    (allocation / copy-on-write via ``_page_write``).
    """

    def __init__(self, model_cfg, params, *, top_k: int, block_size: int,
                 num_blocks: int, eos_token: int = 0, reward_cfg=None,
                 reward_params=None, value_fn: Optional[Callable] = None,
                 prefill_fn: Optional[Callable] = None,
                 paged_decode_fn: Optional[Callable] = None,
                 frontier_fn: Optional[Callable] = None):
        super().__init__(
            model_cfg, params, top_k=top_k, block_size=block_size,
            num_blocks=num_blocks, eos_token=eos_token,
            reward_cfg=reward_cfg, reward_params=reward_params,
            value_fn=value_fn, prefill_fn=prefill_fn,
            paged_decode_fn=paged_decode_fn,
        )
        if frontier_fn is None:
            from ..models import paged_decode_frontier as frontier_fn
        self.frontier_fn = frontier_fn

    def _advance_frontier(self, aux, token, fed, is_exp):
        """Frontier forward over the page tables; chosen row commits via the
        standard COW/allocation bookkeeping (:meth:`_page_write`)."""
        idx = jnp.arange(token.shape[0])
        s_max = aux["tokens"].shape[-1]
        length = aux["len"]
        safe = jnp.minimum(length, s_max - 1)
        prev = aux["tokens"][idx, safe]
        tokens = aux["tokens"].at[idx, safe].set(jnp.where(fed, token, prev))

        table, refcount, oom, wb, off, copy_src, copy_dst = self._page_write(
            aux["table"], aux["refcount"], aux["oom"], idx, safe, fed
        )

        _, cand = jax.lax.top_k(aux["pol"]["logits"], self.top_k)
        rank = jnp.argmax(cand == token[:, None], axis=1)

        fr = self._fr_record(aux["fr"], aux["tokens"], length, cand, is_exp)
        out = dict(
            tokens=tokens,
            len=jnp.where(fed, length + 1, length),
            table=table, refcount=refcount, oom=oom,
            pol=(), rew=(),
        )
        for key, params, cfg in self._branches():
            b = aux[key]
            pk = b["k"].at[:, copy_dst].set(b["k"][:, copy_src], mode="drop")
            pv = b["v"].at[:, copy_dst].set(b["v"][:, copy_src], mode="drop")
            clog, spec = self.frontier_fn(
                params, cfg, cand,
                {"k": pk, "v": pv, "table": table, "len": safe},
            )
            chosen = clog[idx, rank]
            rk = rank.reshape(1, -1, 1, 1, 1)
            row_k = jnp.take_along_axis(spec["k"], rk, axis=2)[:, :, 0]
            row_v = jnp.take_along_axis(spec["v"], rk, axis=2)[:, :, 0]
            out[key] = {
                "k": pk.at[:, wb, off].set(row_k, mode="drop"),
                "v": pv.at[:, wb, off].set(row_v, mode="drop"),
                "logits": jnp.where(
                    fed[:, None], chosen, b["logits"]
                ).astype(b["logits"].dtype),
            }
            fb = fr[key]
            fr[key] = {
                "plog": jnp.where(is_exp[:, None], b["logits"], fb["plog"]),
                "clog": jnp.where(
                    is_exp[:, None, None], clog, fb["clog"]
                ).astype(fb["clog"].dtype),
                "ck": jnp.where(
                    is_exp[None, :, None, None, None], spec["k"], fb["ck"]
                ).astype(fb["ck"].dtype),
                "cv": jnp.where(
                    is_exp[None, :, None, None, None], spec["v"], fb["cv"]
                ).astype(fb["cv"].dtype),
            }
        out["fr"] = fr
        return out

    def refill_aux(self, cfg, aux, rows, new_state, mask):
        del cfg
        from ..models import release_pages

        sub = self._take_rows(aux, rows)
        r = rows.shape[0]
        s_max = sub["tokens"].shape[-1]
        idx = jnp.arange(r)
        start, target, tokens, common = self._rollback_targets(
            sub, new_state, mask
        )
        parent_hit, child_hit, crank, pmatch = self._frontier_hits(
            sub, tokens, new_state, common, mask
        )
        fr = sub["fr"]
        plen = fr["plen"]
        bs = self.block_size

        # Hit-aware release: a parent hit keeps the whole target prefix, a
        # child hit keeps the parent prefix (the commit lands at ``plen``).
        keep = jnp.where(
            parent_hit, target, jnp.where(child_hit, plen, start)
        )
        lo = (keep + bs - 1) // bs
        hi = (sub["len"] + bs - 1) // bs
        refcount = release_pages(sub["refcount"], sub["table"], lo, hi)
        sub = dict(sub, refcount=refcount)

        # Child-hit commit target, through the usual page bookkeeping.  A
        # failed allocation (wb == pool size) demotes the row to a miss.
        cpos = jnp.clip(plen, 0, s_max - 1)
        table, refcount, oom, wb, off, copy_src, copy_dst = self._page_write(
            sub["table"], sub["refcount"], sub["oom"], idx, cpos, child_hit
        )
        p = refcount.shape[0]
        committed = child_hit & (wb < p)
        hit = parent_hit | committed
        sub = dict(
            sub, table=table, refcount=refcount, oom=oom, tokens=tokens,
            len=jnp.where(hit, target, start),
        )
        sub["fr"] = dict(
            fr, valid=jnp.where(mask, fr["valid"] & pmatch, fr["valid"])
        )
        rk = crank.reshape(1, -1, 1, 1, 1)
        for key, _, _ in self._branches():
            b = sub[key]
            pk = b["k"].at[:, copy_dst].set(b["k"][:, copy_src], mode="drop")
            pv = b["v"].at[:, copy_dst].set(b["v"][:, copy_src], mode="drop")
            fb = fr[key]
            row_k = jnp.take_along_axis(fb["ck"], rk, axis=2)[:, :, 0]
            row_v = jnp.take_along_axis(fb["cv"], rk, axis=2)[:, :, 0]
            logits = jnp.where(parent_hit[:, None], fb["plog"], b["logits"])
            logits = jnp.where(
                committed[:, None], fb["clog"][idx, crank], logits
            ).astype(b["logits"].dtype)
            sub[key] = dict(
                b,
                k=pk.at[:, wb, off].set(row_k, mode="drop"),
                v=pv.at[:, wb, off].set(row_v, mode="drop"),
                logits=logits,
            )
        sub = self._paged_catch_up(sub, target, r, s_max)
        return self._put_rows(aux, rows, sub), hit
