"""Evaluators: the pluggable leaf-evaluation side of parallel MCTS.

"On Effective Parallelization of Monte Carlo Tree Search" frames parallel
MCTS as two separable concerns — tree statistics (the master's bookkeeping,
which WU-UCT keeps principled via ``O_s``) and leaf evaluation (the expensive
expansion/simulation work farmed out to workers).  This module owns the
second concern: every engine in :mod:`repro.core` drives its in-flight slots
through an :class:`Evaluator` instead of hard-wiring ``env.policy`` /
``env.step`` into its loop body.

Two implementations ship:

* :class:`RolloutEvaluator` — the classic random/scripted-policy rollout
  (``env.policy`` chooses simulation actions; ``env.step`` advances).  This
  is a *bit-identical* port of the per-slot stepping that previously lived
  as ``wu_uct.rollout_return`` and ``async_search.slot_tick_step``.
* :class:`ModelEvaluator` — policy/value-LM evaluation over the token
  environment (:mod:`repro.envs.token_env`): all in-flight slots of a master
  tick are scored by **one** batched model forward (``models.forward``)
  instead of three per-slot forwards hidden inside ``env.policy`` +
  ``env.step``.  Plugged into the async engines' flat ``[B·W]`` tick batch,
  this realizes the ROADMAP follow-up: every master tick feeds one model
  forward pass.

The evaluator contract (``init_state`` / ``tick`` / ``rollout`` / ``value``)
is identical across implementations, so engines stay evaluator-agnostic and
:func:`repro.core.api.build_searcher` can swap them freely.
"""

from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from ..envs.base import Environment

Pytree = Any

# Slot phases, shared with the async engines (async_search re-exports them).
FREE, EXPAND, SIM = 0, 1, 2


def slot_accounting(gamma, kind, nxt, state, r, done, rollout_done, acc, disc,
                    steps):
    """Per-slot discounted-return bookkeeping after one environment step.

    The one accounting rule every evaluator must apply identically for the
    engines' vmap bit-equivalence to hold: only live SIM slots accumulate,
    FREE slots freeze their state, EXPAND slots report the edge transition.
    Shape-polymorphic (scalar per-slot or leading batch axes) so the same
    code serves ``RolloutEvaluator._one_step`` and the batched
    ``ModelEvaluator.tick``.
    """
    is_sim = kind == SIM
    live = is_sim & jnp.logical_not(rollout_done)
    acc = acc + jnp.where(live, disc * r, 0.0)
    disc = jnp.where(live, disc * gamma, disc)
    steps = steps + jnp.where(kind != FREE, 1, 0)
    busy = kind != FREE
    new_state = jax.tree.map(
        lambda a_, b_: jnp.where(
            busy.reshape(busy.shape + (1,) * (a_.ndim - busy.ndim)), a_, b_
        ),
        nxt,
        state,
    )
    rollout_done = jnp.where(
        kind == EXPAND, done, rollout_done | (is_sim & done)
    )
    return new_state, r, done, acc, disc, steps, rollout_done


class Evaluator:
    """Protocol for environment/model evaluation inside a search engine.

    Engines call four methods; ``cfg`` is the engine's ``SearchConfig``
    (only ``gamma`` / ``max_sim_steps`` / ``value_mix`` are read):

    * ``init_state(example_state, prefix)`` — allocate zeroed per-slot env
      state buffers with leading ``prefix`` axes (the async slot pools);
    * ``tick(cfg, kind, act, state, rollout_done, acc, disc, steps, keys)``
      — advance a whole batch of in-flight slots by one environment step.
      Leading axis is *all* in-flight slots of a master tick: ``[W]`` for
      the single async engine, the flat ``[B·W]`` for the batched one.
      Returns ``(new_state, r, done, acc, disc, steps, rollout_done)``;
    * ``rollout(cfg, state, already_done, rng)`` — full discounted
      simulation return from one state (the wave engines vmap this per
      slot);
    * ``value(state)`` — bootstrap value ``V(s)`` for truncated rollouts.
    """

    env: Optional[Environment] = None

    def init_state(self, example_state: Pytree, prefix: tuple) -> Pytree:
        """Zeroed per-slot state buffers shaped ``prefix + leaf.shape``."""
        return jax.tree.map(
            lambda x: jnp.zeros(
                tuple(prefix) + jnp.shape(x), jnp.asarray(x).dtype
            ),
            example_state,
        )

    def tick(self, cfg, kind, act, state, rollout_done, acc, disc, steps, keys):
        raise NotImplementedError

    def value(self, state: Pytree) -> jax.Array:
        return jnp.float32(0.0)

    def has_value(self) -> bool:
        """Whether :meth:`value` is a real estimator; gates the rollout's
        truncation bootstrap and ``value_mix`` blending (a zero-constant
        value must not rescale returns)."""
        return False

    def rollout(self, cfg, state, already_done, rng) -> jax.Array:
        """Default full rollout: tick a single SIM slot until done/step cap.

        Implementations with a cheaper native rollout (the classic env
        rollout) override this; model-backed evaluators get it for free —
        under the wave engines' slot ``vmap`` the per-step forward becomes a
        batched forward over all slots.
        """

        def cond(c):
            _, done, _, _, _, steps = c
            return jnp.logical_not(done[0]) & (steps[0] < cfg.max_sim_steps)

        def body(c):
            st, done, acc, disc, rng, steps = c
            rng, k = jax.random.split(rng)
            st, _, _, acc, disc, steps, done = self.tick(
                cfg,
                jnp.full((1,), SIM, jnp.int32),
                jnp.zeros((1,), jnp.int32),
                st, done, acc, disc, steps, k[None],
            )
            return st, done, acc, disc, rng, steps

        init = (
            jax.tree.map(lambda x: x[None], state),
            jnp.asarray(already_done, jnp.bool_)[None],
            jnp.zeros((1,), jnp.float32),
            jnp.ones((1,), jnp.float32),
            rng,
            jnp.zeros((1,), jnp.int32),
        )
        st, done, acc, disc, _, _ = jax.lax.while_loop(cond, body, init)
        ret = acc[0]
        if self.has_value():
            final = jax.tree.map(lambda x: x[0], st)
            ret = ret + disc[0] * jnp.where(done[0], 0.0, self.value(final))
            if cfg.value_mix > 0.0:
                v0 = jnp.where(already_done, 0.0, self.value(state))
                ret = (1.0 - cfg.value_mix) * ret + cfg.value_mix * v0
        return ret


# ---------------------------------------------------------------------------
# RolloutEvaluator — today's env.policy behavior, bit-identical.
# ---------------------------------------------------------------------------


class RolloutEvaluator(Evaluator):
    """Classic rollout evaluation: ``env.policy`` acts, ``env.step`` advances.

    The per-slot stepping and discounted-return accounting are verbatim the
    code that previously lived inside the engines, so every engine's default
    behavior (and RNG stream) is unchanged.
    """

    def __init__(self, env: Environment):
        self.env = env

    def _one_step(self, gamma: float) -> Callable:
        """Per-slot one-env-step transition (the parallel part of a master
        tick) — shared by the single engine (vmapped over ``[W]``) and the
        batched engine (vmapped over the flat ``[B·W]`` axis)."""
        env = self.env

        def one(kind, act, state, rollout_done, acc, disc, steps, key):
            pol_act = env.policy(key, state)
            a = jnp.where(kind == EXPAND, act, pol_act)
            nxt, r, done = env.step(state, a)
            return slot_accounting(
                gamma, kind, nxt, state, r, done, rollout_done, acc, disc,
                steps,
            )

        return one

    def tick(self, cfg, kind, act, state, rollout_done, acc, disc, steps, keys):
        return jax.vmap(self._one_step(cfg.gamma))(
            kind, act, state, rollout_done, acc, disc, steps, keys
        )

    def rollout(self, cfg, state, already_done, rng) -> jax.Array:
        """Discounted simulation return with optional value bootstrap/mixing
        (paper Fig. 1(a) "simulation"; App. D truncation bootstrap)."""
        env = self.env

        def cond(carry):
            _, done, _, _, _, steps = carry
            return jnp.logical_not(done) & (steps < cfg.max_sim_steps)

        def body(carry):
            state, done, acc, disc, rng, steps = carry
            rng, k = jax.random.split(rng)
            a = env.policy(k, state)
            nxt, r, d = env.step(state, a)
            acc = acc + disc * r
            disc = disc * cfg.gamma
            return nxt, done | d, acc, disc, rng, steps + 1

        init = (
            state,
            jnp.asarray(already_done, jnp.bool_),
            jnp.float32(0.0),
            jnp.float32(1.0),
            rng,
            jnp.int32(0),
        )
        final_state, done, acc, disc, _, _ = jax.lax.while_loop(
            cond, body, init
        )

        if env.value_fn is not None:
            # Truncation bootstrap: R_simu = Σ γ^i r_i + γ^T V(s_T) (App. D).
            acc = acc + disc * jnp.where(done, 0.0, env.value_fn(final_state))
            if cfg.value_mix > 0.0:
                v0 = jnp.where(already_done, 0.0, env.value_fn(state))
                acc = (1.0 - cfg.value_mix) * acc + cfg.value_mix * v0
        return acc

    def value(self, state: Pytree) -> jax.Array:
        if self.env.value_fn is None:
            return jnp.float32(0.0)
        return self.env.value_fn(state)

    def has_value(self) -> bool:
        return self.env.value_fn is not None


# ---------------------------------------------------------------------------
# ModelEvaluator — one batched policy/value LM forward per master tick.
# ---------------------------------------------------------------------------


class ModelEvaluator(Evaluator):
    """LM-backed evaluation over :mod:`repro.envs.token_env` state batches.

    The token environment's per-slot ``step`` runs one forward for the
    rollout policy plus two inside the transition (policy top-K + reward
    log-prob).  This evaluator instead runs **one** forward over the whole
    in-flight slot batch per tick and derives all three quantities from the
    same logits: the top-K table (action decoding), the sampled simulation
    action, and the reward log-prob (when the reward model is the policy
    model; a distinct reward model adds exactly one more forward).

    Paired with ``engine='async'`` searchers, whose master tick advances all
    ``[W]`` (or flat ``[B·W]``) slots at once, this yields exactly one model
    forward per master tick — asserted by ``tests/test_facade.py`` with a
    traced call counter, and measured by ``benchmarks/bench_model_eval.py``.

    Transitions apply :func:`repro.envs.token_env.apply_token` — the same
    transition core the env's ``step`` uses — so a search with this
    evaluator explores the same MDP by construction.
    """

    def __init__(
        self,
        model_cfg,
        params,
        *,
        top_k: int,
        eos_token: int = 0,
        reward_cfg=None,
        reward_params=None,
        forward_fn: Optional[Callable] = None,
        value_fn: Optional[Callable] = None,
    ):
        if forward_fn is None:
            from ..models import forward as forward_fn  # circular-safe
        self.model_cfg = model_cfg
        self.params = params
        self.top_k = top_k
        self.eos_token = eos_token
        self.reward_cfg = reward_cfg if reward_cfg is not None else model_cfg
        self.reward_params = reward_params
        self.forward_fn = forward_fn
        self.value_fn = value_fn

    def _position_logits(self, params, cfg, tokens, lengths) -> jax.Array:
        """Logits at each slot's current position — ONE forward for [N]."""
        logits, _ = self.forward_fn(params, cfg, {"tokens": tokens})
        pos = jnp.maximum(lengths - 1, 0)
        return jnp.take_along_axis(logits, pos[:, None, None], axis=1)[:, 0]

    def tick(self, cfg, kind, act, state, rollout_done, acc, disc, steps, keys):
        n = state.length.shape[0]
        idx = jnp.arange(n)

        # --- the one batched forward of this master tick -------------------
        pol = self._position_logits(
            self.params, self.model_cfg, state.tokens, state.length
        )
        top_vals, top_idx = jax.lax.top_k(pol, self.top_k)
        ranks = jax.vmap(jax.random.categorical)(keys, top_vals)
        a = jnp.where(kind == EXPAND, act, ranks).astype(jnp.int32)
        token = top_idx[idx, jnp.clip(a, 0, self.top_k - 1)]

        if self.reward_params is None:
            rew_logits = pol
        else:
            rew_logits = self._position_logits(
                self.reward_params, self.reward_cfg, state.tokens, state.length
            )
        logp = jax.nn.log_softmax(rew_logits.astype(jnp.float32))[idx, token]

        # The env's own transition core, applied to the whole slot batch —
        # the evaluator explores the same MDP by construction.  Deferred
        # import: token_env pulls in the models stack, which a model-free
        # `import repro.core` must not pay for.
        from ..envs.token_env import apply_token

        nxt, r, done = apply_token(state, token, logp, self.eos_token)
        return slot_accounting(
            cfg.gamma, kind, nxt, state, r, done, rollout_done, acc, disc,
            steps,
        )

    def value(self, state: Pytree) -> jax.Array:
        if self.value_fn is None:
            return jnp.float32(0.0)
        return self.value_fn(state)

    def has_value(self) -> bool:
        return self.value_fn is not None
