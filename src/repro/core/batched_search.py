"""Batched multi-root WU-UCT: ``B`` independent searches in lockstep.

The wave engine in :mod:`wu_uct` parallelizes rollouts *within* one search;
this engine parallelizes *across* searches — ``B`` independent root states
(many users, many game positions, or an Ensemble-UCT root committee) advance
through selection → expansion → simulation → completion together on one
accelerator.

Design:

* the forest is a :class:`repro.core.batched_tree.BatchedTree` — every SoA
  buffer carries a leading ``[B, ...]`` axis and path walks are lockstep
  masked ``while_loop``\\ s;
* per traversal level, the child statistics of all ``B`` current nodes are
  gathered into dense ``[B, A]`` tables and scored by **one** call into the
  fused Pallas ``tree_select`` kernel (score + masked argmax in a single
  VMEM pass) — the kernel supports all four tree policies, so batched
  baselines (UCT / TreeP / TreeP-VC) ride the same hot path;
* RNG streams are carried per tree and split exactly like the single-tree
  engine splits its stream, so with ``use_kernel`` either on or off this
  engine is *bit-compatible* with ``jax.vmap`` of :func:`wu_uct.run_search`
  (tested in ``tests/test_batched_search.py``);
* the batch axis shards over the ``('pod', 'data')`` mesh axes — pass
  :func:`repro.distributed.sharding.constrain_search_batch` as ``constrain``.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..envs.base import Environment
from ..kernels.tree_select.ops import tree_select
from ..kernels.tree_select.ref import tree_select_ref
from . import batched_tree as btree
from .batched_tree import BatchedTree, init_batched_tree
from .evaluators import Evaluator, RolloutEvaluator
from .policies import PolicyConfig, gather_children_tables
from .wu_uct import (
    KIND_EXPAND,
    KIND_SIM,
    KIND_TERMINAL,
    SearchConfig,
    SearchResult,
)

Pytree = Any


class _BatchedSlots(NamedTuple):
    kind: jax.Array       # i32[B, W]
    stop_node: jax.Array  # i32[B, W]
    sim_node: jax.Array   # i32[B, W]
    act: jax.Array        # i32[B, W]


def _canonical_keys(rngs: jax.Array) -> jax.Array:
    """Accept typed PRNG key arrays or raw uint32 key data."""
    if hasattr(jax.dtypes, "prng_key") and jnp.issubdtype(
        rngs.dtype, jax.dtypes.prng_key
    ):
        return jax.random.key_data(rngs)
    return rngs


def _split_each(rngs: jax.Array, num: int) -> tuple[jax.Array, ...]:
    """Per-tree ``jax.random.split(rng, num)`` — mirrors the single engine's
    stream structure exactly so vmap-equivalence holds."""
    ks = jax.vmap(lambda k: jax.random.split(k, num))(rngs)
    return tuple(ks[:, i] for i in range(num))


def batched_select(
    tree: BatchedTree,
    nodes: jax.Array,
    pol: PolicyConfig,
    use_kernel: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Best child action of each tree's current node via one fused [B, A]
    kernel call.  Returns ``(act[B], any_valid[B])``."""
    n_c, o_c, v_c, vl_c, n_p, o_p, valid = gather_children_tables(tree, nodes)
    select = tree_select if use_kernel else tree_select_ref
    act, _ = select(
        n_c, o_c, v_c, n_p, o_p, valid, vl_c,
        kind=pol.kind, beta=pol.beta, r_vl=pol.r_vl, n_vl=pol.n_vl,
    )
    return act.astype(jnp.int32), jnp.any(valid, axis=1)


# ---------------------------------------------------------------------------
# Selection — all B trees traverse in lockstep; one kernel call per level.
# ---------------------------------------------------------------------------


def traverse_batched(
    tree: BatchedTree,
    rngs: jax.Array,
    cfg: SearchConfig,
    use_kernel: bool = True,
) -> jax.Array:
    """Walk every tree from its root by the configured tree policy."""
    width = min(cfg.max_width, tree.num_actions)
    b = jnp.arange(tree.batch_size)

    def cond(carry):
        _, _, stopped = carry
        return jnp.any(jnp.logical_not(stopped))

    def body(carry):
        nodes, rng, stopped = carry
        active = jnp.logical_not(stopped)
        new_rng, k_coin = _split_each(rng, 2)
        rng = jnp.where(active[:, None], new_rng, rng)

        kids = tree.children[b, nodes]                       # [B, A]
        n_tried = jnp.sum((kids >= 0).astype(jnp.int32), axis=1)
        is_leaf = n_tried == 0
        at_depth = tree.depth[b, nodes] >= cfg.max_depth
        is_term = tree.terminal[b, nodes]
        not_full = n_tried < width
        coin = jax.vmap(jax.random.uniform)(k_coin) < cfg.expand_coin
        stop = is_leaf | at_depth | is_term | (not_full & coin)

        best, any_valid = batched_select(tree, nodes, cfg.policy, use_kernel)
        stop = stop | jnp.logical_not(any_valid)
        nxt = jnp.where(stop, nodes, tree.children[b, nodes, best])
        nodes = jnp.where(active, nxt, nodes).astype(jnp.int32)
        return nodes, rng, stopped | stop

    nodes0 = jnp.zeros((tree.batch_size,), jnp.int32)
    stopped0 = jnp.zeros((tree.batch_size,), jnp.bool_)
    nodes, _, _ = jax.lax.while_loop(cond, body, (nodes0, rngs, stopped0))
    return nodes


def _expansion_actions(
    tree: BatchedTree, nodes: jax.Array, rngs: jax.Array, cfg: SearchConfig
) -> jax.Array:
    """Per-tree untried-action choice (Algorithm 7, uniform prior)."""
    b = jnp.arange(tree.batch_size)
    kids = tree.children[b, nodes]
    if cfg.deterministic_expansion:
        return jnp.argmax(kids < 0, axis=1).astype(jnp.int32)
    tried = kids >= 0
    logits = jnp.where(tried, -jnp.inf, 0.0)
    g = jax.vmap(lambda k: jax.random.gumbel(k, (tree.num_actions,)))(rngs)
    return jnp.argmax(logits + g, axis=1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# In-flight statistics (per stat_mode) — masked batched variants live in
# :mod:`repro.core.batched_tree`; these wrappers unpack the search config.
# ---------------------------------------------------------------------------


def _mark_in_flight(
    tree: BatchedTree, nodes: jax.Array, cfg: SearchConfig, mask: jax.Array
) -> BatchedTree:
    return btree.mark_in_flight(
        tree, nodes, mask, stat_mode=cfg.stat_mode, r_vl=cfg.policy.r_vl
    )


def _settle(
    tree: BatchedTree,
    nodes: jax.Array,
    rets: jax.Array,
    cfg: SearchConfig,
    mask: jax.Array,
) -> BatchedTree:
    return btree.settle(
        tree, nodes, rets, mask,
        stat_mode=cfg.stat_mode, gamma=cfg.gamma, r_vl=cfg.policy.r_vl,
    )


# ---------------------------------------------------------------------------
# Wave phases
# ---------------------------------------------------------------------------


def _phase1_select(
    tree: BatchedTree, rngs: jax.Array, cfg: SearchConfig, use_kernel: bool
) -> tuple[BatchedTree, _BatchedSlots, jax.Array]:
    """Sequentially select W slots per tree (in-flight stats in between);
    all B trees fill slot j simultaneously."""
    B = tree.batch_size
    W = cfg.wave_size
    width = min(cfg.max_width, tree.num_actions)
    b = jnp.arange(B)

    def slot_body(j, carry):
        tree, rng, slots = carry
        rng, k_t, k_e = _split_each(rng, 3)
        nodes = traverse_batched(tree, k_t, cfg, use_kernel)

        kids = tree.children[b, nodes]
        n_tried = jnp.sum((kids >= 0).astype(jnp.int32), axis=1)
        is_term = tree.terminal[b, nodes]
        at_depth = tree.depth[b, nodes] >= cfg.max_depth
        needs_expand = (
            jnp.logical_not(is_term)
            & jnp.logical_not(at_depth)
            & (n_tried < width)
        )
        act = _expansion_actions(tree, nodes, k_e, cfg)

        tree, child, expanded = btree.reserve_children(
            tree, nodes, act, mask=needs_expand
        )
        kind = jnp.where(
            is_term, KIND_TERMINAL, jnp.where(expanded, KIND_EXPAND, KIND_SIM)
        ).astype(jnp.int32)
        sim_node = jnp.where(expanded, child, nodes).astype(jnp.int32)

        # Incomplete update as soon as the rollout is initiated (Alg. 1);
        # terminal hits settle immediately with return 0.
        tree = _mark_in_flight(tree, sim_node, cfg, mask=jnp.ones((B,), jnp.bool_))
        tree = _settle(tree, sim_node, jnp.zeros((B,), jnp.float32), cfg, mask=is_term)

        slots = _BatchedSlots(
            kind=slots.kind.at[:, j].set(kind),
            stop_node=slots.stop_node.at[:, j].set(nodes),
            sim_node=slots.sim_node.at[:, j].set(sim_node),
            act=slots.act.at[:, j].set(act),
        )
        return tree, rng, slots

    slots0 = _BatchedSlots(
        kind=jnp.zeros((B, W), jnp.int32),
        stop_node=jnp.zeros((B, W), jnp.int32),
        sim_node=jnp.zeros((B, W), jnp.int32),
        act=jnp.zeros((B, W), jnp.int32),
    )
    tree, rngs, slots = jax.lax.fori_loop(0, W, slot_body, (tree, rngs, slots0))

    sorted_stops = jnp.sort(slots.stop_node, axis=1)
    dups = jnp.sum(
        (sorted_stops[:, 1:] == sorted_stops[:, :-1]).astype(jnp.float32),
        axis=1,
    )
    return tree, slots, dups


def _phase2_work(
    env: Environment,
    cfg: SearchConfig,
    tree: BatchedTree,
    slots: _BatchedSlots,
    rngs: jax.Array,
    constrain: Optional[Callable[[Pytree], Pytree]] = None,
    evaluator: Optional[Evaluator] = None,
):
    """Expansion env-step + simulation rollout for all B × W slots at once —
    the compute that shards over the ('pod', 'data') mesh axes."""
    W = cfg.wave_size
    evaluator = evaluator if evaluator is not None else RolloutEvaluator(env)
    keys = jax.vmap(lambda k: jax.random.split(k, W))(rngs)   # [B, W, ...]

    def per_tree(states_b, terminal_b, kinds, stop_nodes, sim_nodes, acts, kb):
        def one_slot(kind, stop_node, sim_node, act, key):
            parent_state = jax.tree.map(lambda x: x[stop_node], states_b)
            child_state, r_edge, done_child = env.step(parent_state, act)
            is_exp = kind == KIND_EXPAND
            start_state = jax.tree.map(
                lambda a, b: jnp.where(is_exp, a, b),
                child_state,
                jax.tree.map(lambda x: x[sim_node], states_b),
            )
            start_done = jnp.where(is_exp, done_child, terminal_b[sim_node])
            ret = evaluator.rollout(cfg, start_state, start_done, key)
            return child_state, r_edge, done_child, ret

        return jax.vmap(one_slot)(kinds, stop_nodes, sim_nodes, acts, kb)

    args = (
        tree.states, tree.terminal,
        slots.kind, slots.stop_node, slots.sim_node, slots.act, keys,
    )
    if constrain is not None:
        args = constrain(args)
    out = jax.vmap(per_tree)(*args)
    if constrain is not None:
        out = constrain(out)
    return out  # (child_states[B,W,...], r_edge[B,W], done_child[B,W], ret[B,W])


def _phase3_settle(
    tree: BatchedTree,
    cfg: SearchConfig,
    slots: _BatchedSlots,
    child_states: Pytree,
    r_edge: jax.Array,
    done_child: jax.Array,
    rets: jax.Array,
) -> BatchedTree:
    """Master-side completion: write expansion results + complete updates."""
    W = cfg.wave_size

    def slot_body(j, tree):
        kind = slots.kind[:, j]
        sim_node = slots.sim_node[:, j]
        st = jax.tree.map(lambda x: x[:, j], child_states)
        tree = btree.finalize_children(
            tree, sim_node, st, r_edge[:, j], done_child[:, j],
            mask=kind == KIND_EXPAND,
        )
        tree = _settle(tree, sim_node, rets[:, j], cfg, mask=kind != KIND_TERMINAL)
        return tree

    return jax.lax.fori_loop(0, W, slot_body, tree)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


def run_search_batched(
    env: Environment,
    cfg: SearchConfig,
    root_states: Pytree,
    rngs: jax.Array,
    constrain: Optional[Callable[[Pytree], Pytree]] = None,
    use_kernel: bool = True,
    evaluator: Optional[Evaluator] = None,
) -> SearchResult:
    """Run ``B`` independent searches; every field of the returned
    :class:`SearchResult` carries a leading ``[B]`` axis.

    ``root_states`` is a pytree whose leaves lead with ``[B]``; ``rngs`` is
    ``jax.random.split(key, B)`` (one independent stream per tree).
    """
    if cfg.num_simulations % cfg.wave_size != 0:
        raise ValueError("num_simulations must be divisible by wave_size")
    num_waves = cfg.num_simulations // cfg.wave_size
    capacity = cfg.num_simulations + cfg.wave_size + 1
    rngs = _canonical_keys(rngs)
    B = rngs.shape[0]
    tree = init_batched_tree(root_states, capacity, env.num_actions)

    def wave_body(i, carry):
        tree, rng, dup_acc, max_o = carry
        rng, k_sel, k_sim = _split_each(rng, 3)
        tree, slots, dups = _phase1_select(tree, k_sel, cfg, use_kernel)
        max_o = jnp.maximum(max_o, tree.O[:, 0])
        child_states, r_edge, done_child, rets = _phase2_work(
            env, cfg, tree, slots, k_sim, constrain, evaluator
        )
        tree = _phase3_settle(
            tree, cfg, slots, child_states, r_edge, done_child, rets
        )
        return tree, rng, dup_acc + dups, max_o

    tree, _, dup_acc, max_o = jax.lax.fori_loop(
        0, num_waves, wave_body,
        (tree, rngs, jnp.zeros((B,), jnp.float32), jnp.zeros((B,), jnp.float32)),
    )

    root_n, root_v = btree.root_action_stats(tree)
    return SearchResult(
        action=btree.best_root_action(tree),
        root_n=root_n,
        root_v=root_v,
        tree_size=tree.size,
        dup_selections=dup_acc / num_waves,
        max_o=max_o,
        overflowed=tree.overflowed,
        ticks=jnp.full((B,), num_waves, jnp.int32),
    )


def make_batched_searcher(
    env: Environment,
    cfg: SearchConfig,
    constrain: Optional[Callable[[Pytree], Pytree]] = None,
    jit: bool = True,
    use_kernel: bool = True,
    evaluator: Optional[Evaluator] = None,
):
    """Build ``search(root_states[B], rngs[B]) -> SearchResult[B]``."""
    fn = functools.partial(
        run_search_batched, env, cfg, constrain=constrain,
        use_kernel=use_kernel, evaluator=evaluator,
    )
    return jax.jit(fn) if jit else fn
