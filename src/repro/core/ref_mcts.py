"""Pure-Python reference MCTS (the test oracle).

A dict-based, straightforwardly-sequential implementation of Algorithms 1-3
and 7-8 of the paper.  It shares *no* code with the JAX implementation and is
used by the tests to validate the SoA tree statistics: with ``wave_size=1``
and a shared PRNG discipline the JAX engine must produce identical trees.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Optional


@dataclass
class RefNode:
    state: Any
    parent: Optional["RefNode"]
    action: int = -1
    reward: float = 0.0          # edge reward into this node
    terminal: bool = False
    depth: int = 0
    children: dict = field(default_factory=dict)
    N: float = 0.0
    O: float = 0.0
    V: float = 0.0


class RefMCTS:
    """Sequential WU-UCT/UCT oracle over a python environment interface.

    ``env`` must provide ``num_actions``, ``step(state, a) -> (s', r, done)``.
    ``rng`` draws are delegated to caller-provided callables so tests can
    replay the exact random choices of the JAX engine.
    """

    def __init__(
        self,
        env,
        beta: float = 1.0,
        gamma: float = 0.99,
        max_depth: int = 100,
        max_width: int = 10**9,
        use_o: bool = True,
    ):
        self.env = env
        self.beta = beta
        self.gamma = gamma
        self.max_depth = max_depth
        self.max_width = min(max_width, env.num_actions)
        self.use_o = use_o

    # -- paper eq. (2)/(4) --------------------------------------------------
    def score(self, parent: RefNode, child: RefNode) -> float:
        if self.use_o:
            log_term = math.log(max(parent.N + parent.O, 1.0))
            denom = child.N + child.O
        else:
            log_term = math.log(max(parent.N, 1.0))
            denom = child.N
        if denom <= 0:
            return float("inf")
        return child.V + self.beta * math.sqrt(2.0 * log_term / denom)

    def select(self, root: RefNode, coin_fn, tiebreak="first") -> RefNode:
        node = root
        while True:
            n_tried = len(node.children)
            if (
                n_tried == 0
                or node.depth >= self.max_depth
                or node.terminal
                or (n_tried < self.max_width and coin_fn())
            ):
                return node
            best, best_score = None, -float("inf")
            for a in sorted(node.children):
                c = node.children[a]
                s = self.score(node, c)
                if s > best_score:
                    best, best_score = c, s
            if best is None:
                return node
            node = best

    def expand(self, node: RefNode, action: int) -> RefNode:
        assert action not in node.children
        s2, r, done = self.env.step(node.state, action)
        child = RefNode(
            state=s2,
            parent=node,
            action=action,
            reward=float(r),
            terminal=bool(done),
            depth=node.depth + 1,
        )
        node.children[action] = child
        return child

    # -- paper Algorithm 2 ---------------------------------------------------
    def incomplete_update(self, node: RefNode) -> None:
        while node is not None:
            node.O += 1.0
            node = node.parent

    # -- paper Algorithm 3 ---------------------------------------------------
    def complete_update(self, node: RefNode, sim_return: float) -> None:
        r_bar = sim_return
        while node is not None:
            node.N += 1.0
            node.O -= 1.0
            r_bar = node.reward + self.gamma * r_bar
            node.V = ((node.N - 1.0) * node.V + r_bar) / node.N
            node = node.parent

    # -- paper Algorithm 8 ---------------------------------------------------
    def backprop(self, node: RefNode, sim_return: float) -> None:
        r_bar = sim_return
        while node is not None:
            node.N += 1.0
            r_bar = node.reward + self.gamma * r_bar
            node.V = ((node.N - 1.0) * node.V + r_bar) / node.N
            node = node.parent

    def simulate(self, state, already_done: bool, policy_fn, max_steps: int):
        if already_done:
            return 0.0
        acc, disc = 0.0, 1.0
        s = state
        for _ in range(max_steps):
            a = policy_fn(s)
            s, r, done = self.env.step(s, a)
            acc += disc * float(r)
            disc *= self.gamma
            if done:
                break
        return acc

    def search(
        self,
        root_state,
        num_simulations: int,
        coin_fn: Callable[[], bool],
        expand_fn: Callable[[RefNode], int],
        policy_fn,
        max_sim_steps: int = 100,
    ) -> RefNode:
        """Sequential search; with W=1 the wave engine must match this."""
        root = RefNode(state=root_state, parent=None)
        for _ in range(num_simulations):
            node = self.select(root, coin_fn)
            n_tried = len(node.children)
            if node.terminal:
                self.incomplete_update(node)
                self.complete_update(node, 0.0)
                continue
            if node.depth < self.max_depth and n_tried < self.max_width:
                node = self.expand(node, expand_fn(node))
            self.incomplete_update(node)
            ret = self.simulate(
                node.state, node.terminal, policy_fn, max_sim_steps
            )
            self.complete_update(node, ret)
        return root

    @staticmethod
    def best_action(root: RefNode) -> int:
        return max(root.children.items(), key=lambda kv: kv[1].N)[0]
