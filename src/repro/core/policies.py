"""Tree (node-selection) policies.

Implements the four selection rules studied by the paper:

* ``uct``      — eq. (2): classic UCB1-over-trees.
* ``wu_uct``   — eq. (4): the paper's contribution; unobserved-sample counts
                 ``O`` corrects both the parent log term and the child
                 denominator.
* ``treep``    — eq. (2) over virtual-loss-adjusted values ``V − VL``
                 (Chaslot et al. 2008 / Algorithm 5).
* ``treep_vc`` — eq. (7), App. E: virtual loss *and* virtual pseudo-count,
                 ``V' = (N·V − c·r_VL) / (N + c·n_VL)`` with ``c`` in-flight
                 queries (tracked via ``O``), non-destructively applied at
                 scoring time.

All functions return per-action scores for one node; invalid actions get
``-inf``.  They are pure and shape-static so they can be vmapped over nodes /
trees and fused into the Pallas ``tree_select`` kernel (kernels/tree_select).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .tree import Tree


class PolicyConfig(NamedTuple):
    kind: str = "wu_uct"   # uct | wu_uct | treep | treep_vc
    beta: float = 1.0      # exploration constant (paper: β)
    r_vl: float = 1.0      # TreeP virtual loss
    n_vl: float = 1.0      # TreeP virtual pseudo-count (eq. 7)


def child_scores(tree: Tree, node: jax.Array, cfg: PolicyConfig) -> jax.Array:
    """Scores of every action at ``node``; -inf for untried/pending children."""
    kids = tree.children[node]                       # i32[A]
    safe = jnp.maximum(kids, 0)
    valid = (kids >= 0) & jnp.logical_not(tree.pending[safe])

    n_c = tree.N[safe]
    o_c = tree.O[safe]
    v_c = tree.V[safe]
    vl_c = tree.VL[safe]
    n_p = tree.N[node]
    o_p = tree.O[node]

    if cfg.kind == "wu_uct":
        # eq. (4): include unobserved samples in both terms.
        log_term = jnp.log(jnp.maximum(n_p + o_p, 1.0))
        denom = n_c + o_c
        explore = cfg.beta * jnp.sqrt(2.0 * log_term / jnp.maximum(denom, 1e-9))
        explore = jnp.where(denom > 0, explore, jnp.inf)
        score = v_c + explore
    elif cfg.kind == "uct":
        # eq. (2).
        log_term = jnp.log(jnp.maximum(n_p, 1.0))
        explore = cfg.beta * jnp.sqrt(2.0 * log_term / jnp.maximum(n_c, 1e-9))
        explore = jnp.where(n_c > 0, explore, jnp.inf)
        score = v_c + explore
    elif cfg.kind == "treep":
        # eq. (2) over virtual-loss-adjusted values.  ``VL`` holds the summed
        # in-flight virtual losses (added at selection, removed at backprop).
        log_term = jnp.log(jnp.maximum(n_p, 1.0))
        explore = cfg.beta * jnp.sqrt(2.0 * log_term / jnp.maximum(n_c, 1e-9))
        explore = jnp.where(n_c > 0, explore, jnp.inf)
        score = (v_c - vl_c) + explore
    elif cfg.kind == "treep_vc":
        # eq. (7) with c = O in-flight queries, applied non-destructively.
        c = o_c
        v_adj = (n_c * v_c - c * cfg.r_vl) / jnp.maximum(n_c + c * cfg.n_vl, 1e-9)
        log_term = jnp.log(jnp.maximum(n_p + o_p, 1.0))
        denom = n_c + c * cfg.n_vl
        explore = cfg.beta * jnp.sqrt(2.0 * log_term / jnp.maximum(denom, 1e-9))
        explore = jnp.where(denom > 0, explore, jnp.inf)
        score = v_adj + explore
    else:  # pragma: no cover - guarded by config validation
        raise ValueError(f"unknown policy kind: {cfg.kind}")

    return jnp.where(valid, score, -jnp.inf)


def gather_children_tables(tree, nodes: jax.Array):
    """Dense [B, A] children-statistics tables at ``nodes`` (one per tree).

    This is the gather feeding the fused Pallas ``tree_select`` kernel: for
    each of the ``B`` current nodes, the stats of all its children plus the
    parent totals.  ``tree`` is a :class:`repro.core.batched_tree.BatchedTree`.

    Returns ``(n_c, o_c, v_c, vl_c, n_p, o_p, valid)`` with shapes
    ``[B, A] × 4, [B] × 2, [B, A]``.
    """
    b = jnp.arange(nodes.shape[0])
    kids = tree.children[b, nodes]                   # i32[B, A]
    safe = jnp.maximum(kids, 0)
    b2 = b[:, None]
    valid = (kids >= 0) & jnp.logical_not(tree.pending[b2, safe])
    n_c = tree.N[b2, safe]
    o_c = tree.O[b2, safe]
    v_c = tree.V[b2, safe]
    vl_c = tree.VL[b2, safe]
    n_p = tree.N[b, nodes]
    o_p = tree.O[b, nodes]
    return n_c, o_c, v_c, vl_c, n_p, o_p, valid


def select_action(
    tree: Tree, node: jax.Array, cfg: PolicyConfig
) -> tuple[jax.Array, jax.Array]:
    """(argmax action, whether any action was selectable) at ``node``."""
    scores = child_scores(tree, node, cfg)
    any_valid = jnp.any(jnp.isfinite(scores) | (scores == jnp.inf))
    return jnp.argmax(scores).astype(jnp.int32), any_valid


def expansion_action(
    tree: Tree,
    node: jax.Array,
    rng: jax.Array,
    prior_logits: jax.Array | None = None,
) -> jax.Array:
    """Sample an *untried* action from the prior (paper Algorithm 7).

    ``prior_logits`` defaults to uniform; a policy network's logits at the
    node state can be passed to bias expansion, as in the paper's production
    system (App. C.2).
    """
    tried = tree.children[node] >= 0
    if prior_logits is None:
        prior_logits = jnp.zeros((tree.num_actions,), jnp.float32)
    logits = jnp.where(tried, -jnp.inf, prior_logits)
    g = jax.random.gumbel(rng, (tree.num_actions,))
    return jnp.argmax(logits + g).astype(jnp.int32)
