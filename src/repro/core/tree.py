"""Structure-of-arrays search tree for (parallel) MCTS.

The tree is a pure pytree of fixed-capacity arrays so that every search
algorithm in this package (WU-UCT, sequential UCT, LeafP, TreeP, RootP) is a
single jittable program built from ``jax.lax`` control flow.

Layout
------
* ``children[s, a]`` is the node index reached from node ``s`` by action
  ``a`` (or ``-1``).  Indexing children *by action* makes "fully expanded" and
  "untried action" checks O(1) masked ops and prevents two in-flight
  expansions from racing on the same action.
* ``pending[s]`` marks a node whose index was reserved at selection time but
  whose environment state has not been produced yet (its expansion is still
  in flight).  Pending nodes cannot be descended into, but their ``O`` mass is
  already visible along the path — the "watch the unobserved" statistics of
  the paper, available as early as the rollout is initiated.
* ``states`` is the centralized game-state storage of the paper (App. A):
  a pytree whose leaves are stacked ``[capacity, ...]`` buffers.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Pytree = Any

NO_NODE = jnp.int32(-1)


class Tree(NamedTuple):
    """Fixed-capacity SoA search tree (a pure pytree)."""

    parent: jax.Array      # i32[M]       parent node index (-1 for root / free)
    action: jax.Array      # i32[M]       action on the edge from parent
    children: jax.Array    # i32[M, A]    child index per action (-1 = untried)
    N: jax.Array           # f32[M]       completed-visit counts  (paper: N_s)
    O: jax.Array           # f32[M]       in-flight visit counts  (paper: O_s)
    V: jax.Array           # f32[M]       running mean value      (paper: V_s)
    VL: jax.Array          # f32[M]       virtual-loss accumulator (TreeP only)
    R: jax.Array           # f32[M]       reward on the edge INTO this node
    terminal: jax.Array    # bool[M]
    pending: jax.Array     # bool[M]      reserved, expansion in flight
    depth: jax.Array       # i32[M]
    size: jax.Array        # i32[]        number of allocated nodes
    overflowed: jax.Array  # bool[]       a reserve was attempted at capacity
    states: Pytree         # pytree[M, ...] env state per node

    @property
    def capacity(self) -> int:
        return self.parent.shape[0]

    @property
    def num_actions(self) -> int:
        return self.children.shape[1]


def init_tree(root_state: Pytree, capacity: int, num_actions: int) -> Tree:
    """Allocate a tree with ``root_state`` installed at node 0."""
    states = jax.tree.map(
        lambda x: jnp.zeros((capacity,) + jnp.shape(x), jnp.asarray(x).dtype)
        .at[0]
        .set(x),
        root_state,
    )
    return Tree(
        parent=jnp.full((capacity,), NO_NODE, jnp.int32),
        action=jnp.full((capacity,), NO_NODE, jnp.int32),
        children=jnp.full((capacity, num_actions), NO_NODE, jnp.int32),
        N=jnp.zeros((capacity,), jnp.float32),
        O=jnp.zeros((capacity,), jnp.float32),
        V=jnp.zeros((capacity,), jnp.float32),
        VL=jnp.zeros((capacity,), jnp.float32),
        R=jnp.zeros((capacity,), jnp.float32),
        terminal=jnp.zeros((capacity,), jnp.bool_),
        pending=jnp.zeros((capacity,), jnp.bool_),
        depth=jnp.zeros((capacity,), jnp.int32),
        size=jnp.int32(1),
        overflowed=jnp.bool_(False),
        states=states,
    )


def get_state(tree: Tree, node: jax.Array) -> Pytree:
    return jax.tree.map(lambda x: x[node], tree.states)


def set_state(tree: Tree, node: jax.Array, state: Pytree) -> Tree:
    states = jax.tree.map(lambda b, x: b.at[node].set(x), tree.states, state)
    return tree._replace(states=states)


# ---------------------------------------------------------------------------
# Path walks.  Each walk is a while_loop over parent pointers; trip count is
# bounded by the tree depth.  These are the master-side O(depth) updates of
# the paper (Algorithms 2, 3 and 8) — cheap by construction, which is why the
# paper keeps them centralized and parallelizes only expansion + simulation.
# ---------------------------------------------------------------------------


def incomplete_update(tree: Tree, node: jax.Array) -> Tree:
    """Paper Algorithm 2: ``O_s += 1`` from ``node`` up to the root."""

    def cond(c):
        n, _ = c
        return n != NO_NODE

    def body(c):
        n, O = c
        return tree.parent[n], O.at[n].add(1.0)

    _, O = jax.lax.while_loop(cond, body, (node, tree.O))
    return tree._replace(O=O)


def complete_update(
    tree: Tree, node: jax.Array, sim_return: jax.Array, gamma: float
) -> Tree:
    """Paper Algorithm 3: ``N+=1; O-=1; r̄ ← R_s + γ·r̄; V ← mean`` leaf→root."""

    def cond(c):
        n, *_ = c
        return n != NO_NODE

    def body(c):
        n, r_bar, N, O, V = c
        new_n = N[n] + 1.0
        r_bar = tree.R[n] + gamma * r_bar
        new_v = ((new_n - 1.0) * V[n] + r_bar) / new_n
        return (
            tree.parent[n],
            r_bar,
            N.at[n].set(new_n),
            O.at[n].add(-1.0),
            V.at[n].set(new_v),
        )

    _, _, N, O, V = jax.lax.while_loop(
        cond, body, (node, jnp.float32(sim_return), tree.N, tree.O, tree.V)
    )
    return tree._replace(N=N, O=O, V=V)


def backprop_update(
    tree: Tree, node: jax.Array, sim_return: jax.Array, gamma: float
) -> Tree:
    """Paper Algorithm 8 (sequential backprop; no O bookkeeping)."""

    def cond(c):
        n, *_ = c
        return n != NO_NODE

    def body(c):
        n, r_bar, N, V = c
        new_n = N[n] + 1.0
        r_bar = tree.R[n] + gamma * r_bar
        new_v = ((new_n - 1.0) * V[n] + r_bar) / new_n
        return tree.parent[n], r_bar, N.at[n].set(new_n), V.at[n].set(new_v)

    _, _, N, V = jax.lax.while_loop(
        cond, body, (node, jnp.float32(sim_return), tree.N, tree.V)
    )
    return tree._replace(N=N, V=V)


def add_virtual_loss(tree: Tree, node: jax.Array, r_vl: float) -> Tree:
    """TreeP: ``V_s ← V_s − r_VL`` along the selected path (and track count)."""

    def cond(c):
        n, _ = c
        return n != NO_NODE

    def body(c):
        n, VL = c
        return tree.parent[n], VL.at[n].add(r_vl)

    _, VL = jax.lax.while_loop(cond, body, (node, tree.VL))
    return tree._replace(VL=VL)


def remove_virtual_loss(tree: Tree, node: jax.Array, r_vl: float) -> Tree:
    def cond(c):
        n, _ = c
        return n != NO_NODE

    def body(c):
        n, VL = c
        return tree.parent[n], VL.at[n].add(-r_vl)

    _, VL = jax.lax.while_loop(cond, body, (node, tree.VL))
    return tree._replace(VL=VL)


def reserve_child(
    tree: Tree, parent: jax.Array, act: jax.Array
) -> tuple[Tree, jax.Array, jax.Array]:
    """Allocate a pending child of ``parent`` via edge ``act``.

    The child becomes visible to the modified UCT policy immediately (its
    path ``O`` mass is added by the caller's incomplete update) but cannot be
    descended into until its expansion result is written by
    :func:`finalize_child`.

    At capacity the reservation is refused instead of corrupting node 0:
    nothing is written, ``tree.overflowed`` latches True, and the returned
    node is ``parent`` with ``ok=False`` so callers degrade to simulating
    from the stop node.  Returns ``(tree, node, ok)``.
    """
    ok = tree.size < tree.capacity
    idx = jnp.minimum(tree.size, tree.capacity - 1)

    def keep(buf, new):
        return buf.at[idx].set(jnp.where(ok, new, buf[idx]))

    tree = tree._replace(
        parent=keep(tree.parent, parent),
        action=keep(tree.action, act),
        children=tree.children.at[parent, act].set(
            jnp.where(ok, idx, tree.children[parent, act])
        ),
        pending=keep(tree.pending, True),
        depth=keep(tree.depth, tree.depth[parent] + 1),
        size=tree.size + ok.astype(jnp.int32),
        overflowed=tree.overflowed | jnp.logical_not(ok),
    )
    return tree, jnp.where(ok, idx, parent).astype(jnp.int32), ok


def finalize_child(
    tree: Tree,
    idx: jax.Array,
    state: Pytree,
    reward: jax.Array,
    done: jax.Array,
) -> Tree:
    """Write the expansion result into a reserved child."""
    tree = set_state(tree, idx, state)
    return tree._replace(
        R=tree.R.at[idx].set(reward),
        terminal=tree.terminal.at[idx].set(done),
        pending=tree.pending.at[idx].set(False),
    )


def root_action_stats(tree: Tree) -> tuple[jax.Array, jax.Array]:
    """Per-action (N, V) at the root; untried actions get N=0, V=-inf."""
    kids = tree.children[0]
    valid = kids >= 0
    safe = jnp.maximum(kids, 0)
    n = jnp.where(valid, tree.N[safe], 0.0)
    v = jnp.where(valid, tree.V[safe], -jnp.inf)
    return n, v


def best_root_action(tree: Tree) -> jax.Array:
    """Most-visited root action (value tiebreak)."""
    n, v = root_action_stats(tree)
    # lexicographic (N, V) argmax via small value perturbation
    v_rank = jax.nn.softmax(jnp.where(jnp.isfinite(v), v, -1e9))
    return jnp.argmax(n + 1e-6 * v_rank).astype(jnp.int32)
