"""Batched structure-of-arrays search forest: ``B`` independent trees.

This is the multi-root throughput layer: every SoA buffer of
:class:`repro.core.tree.Tree` gains a leading ``[B, ...]`` axis and every
path walk becomes a *lockstep* masked walk — all ``B`` trees climb their own
parent chains simultaneously inside one ``lax.while_loop`` whose trip count
is the deepest active path.  Trees that reach their root (or are masked out
with ``NO_NODE``) simply stop contributing updates.

Semantics are element-wise identical to the single-tree ops: the batched
engine built on top of this module must agree exactly with
``jax.vmap``-of-single-tree under identical per-tree RNG streams (this is
tested in ``tests/test_batched_search.py``).

The batch axis is the natural sharding axis for serving many users' searches
from one accelerator — see ``distributed/sharding.py`` (``B`` shards over the
``('pod', 'data')`` mesh axes).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from .tree import NO_NODE

Pytree = Any


class BatchedTree(NamedTuple):
    """Fixed-capacity SoA forest of ``B`` trees (a pure pytree)."""

    parent: jax.Array      # i32[B, M]
    action: jax.Array      # i32[B, M]
    children: jax.Array    # i32[B, M, A]
    N: jax.Array           # f32[B, M]    completed-visit counts
    O: jax.Array           # f32[B, M]    in-flight visit counts
    V: jax.Array           # f32[B, M]    running mean value
    VL: jax.Array          # f32[B, M]    virtual-loss accumulator
    R: jax.Array           # f32[B, M]    reward on the edge INTO the node
    terminal: jax.Array    # bool[B, M]
    pending: jax.Array     # bool[B, M]
    depth: jax.Array       # i32[B, M]
    size: jax.Array        # i32[B]       allocated nodes per tree
    overflowed: jax.Array  # bool[B]      reserve attempted at capacity
    states: Pytree         # pytree[B, M, ...] env state per node

    @property
    def batch_size(self) -> int:
        return self.parent.shape[0]

    @property
    def capacity(self) -> int:
        return self.parent.shape[1]

    @property
    def num_actions(self) -> int:
        return self.children.shape[2]


def _bidx(tree: BatchedTree) -> jax.Array:
    return jnp.arange(tree.batch_size)


def init_batched_tree(
    root_states: Pytree, capacity: int, num_actions: int
) -> BatchedTree:
    """Allocate ``B`` trees; ``root_states`` leaves carry a leading [B]."""
    batch = jax.tree.leaves(root_states)[0].shape[0]
    states = jax.tree.map(
        lambda x: jnp.zeros((batch, capacity) + x.shape[1:],
                            jnp.asarray(x).dtype).at[:, 0].set(x),
        root_states,
    )
    return BatchedTree(
        parent=jnp.full((batch, capacity), NO_NODE, jnp.int32),
        action=jnp.full((batch, capacity), NO_NODE, jnp.int32),
        children=jnp.full((batch, capacity, num_actions), NO_NODE, jnp.int32),
        N=jnp.zeros((batch, capacity), jnp.float32),
        O=jnp.zeros((batch, capacity), jnp.float32),
        V=jnp.zeros((batch, capacity), jnp.float32),
        VL=jnp.zeros((batch, capacity), jnp.float32),
        R=jnp.zeros((batch, capacity), jnp.float32),
        terminal=jnp.zeros((batch, capacity), jnp.bool_),
        pending=jnp.zeros((batch, capacity), jnp.bool_),
        depth=jnp.zeros((batch, capacity), jnp.int32),
        size=jnp.ones((batch,), jnp.int32),
        overflowed=jnp.zeros((batch,), jnp.bool_),
        states=states,
    )


def get_state(tree: BatchedTree, nodes: jax.Array) -> Pytree:
    """Per-tree node states; ``nodes`` is i32[B] → pytree[B, ...]."""
    b = _bidx(tree)
    return jax.tree.map(lambda x: x[b, nodes], tree.states)


def set_state(
    tree: BatchedTree, nodes: jax.Array, state: Pytree, mask: jax.Array
) -> BatchedTree:
    """Write ``state`` (leading [B]) at ``nodes`` where ``mask`` holds."""
    b = _bidx(tree)

    def one(buf, x):
        m = mask.reshape((tree.batch_size,) + (1,) * (x.ndim - 1))
        return buf.at[b, nodes].set(jnp.where(m, x, buf[b, nodes]))

    return tree._replace(states=jax.tree.map(one, tree.states, state))


# ---------------------------------------------------------------------------
# Lockstep path walks.  Each is one while_loop advancing all B parent chains
# at once; per-tree node pointers hit NO_NODE independently and freeze.
# A caller masks a tree out of a walk by passing NO_NODE as its start node.
# ---------------------------------------------------------------------------


def incomplete_update(tree: BatchedTree, nodes: jax.Array) -> BatchedTree:
    """Algorithm 2, vectorized: ``O += 1`` along every tree's path."""
    b = _bidx(tree)

    def cond(c):
        n, _ = c
        return jnp.any(n != NO_NODE)

    def body(c):
        n, O = c
        active = n != NO_NODE
        safe = jnp.maximum(n, 0)
        O = O.at[b, safe].add(jnp.where(active, 1.0, 0.0))
        return jnp.where(active, tree.parent[b, safe], NO_NODE), O

    _, O = jax.lax.while_loop(cond, body, (nodes, tree.O))
    return tree._replace(O=O)


def complete_update(
    tree: BatchedTree, nodes: jax.Array, sim_returns: jax.Array, gamma: float
) -> BatchedTree:
    """Algorithm 3, vectorized: ``N+=1; O-=1; r̄←R+γ·r̄; V←mean`` leaf→root."""
    b = _bidx(tree)

    def cond(c):
        n, *_ = c
        return jnp.any(n != NO_NODE)

    def body(c):
        n, r_bar, N, O, V = c
        active = n != NO_NODE
        safe = jnp.maximum(n, 0)
        new_n = N[b, safe] + 1.0
        new_r = tree.R[b, safe] + gamma * r_bar
        new_v = ((new_n - 1.0) * V[b, safe] + new_r) / new_n
        N = N.at[b, safe].set(jnp.where(active, new_n, N[b, safe]))
        O = O.at[b, safe].add(jnp.where(active, -1.0, 0.0))
        V = V.at[b, safe].set(jnp.where(active, new_v, V[b, safe]))
        r_bar = jnp.where(active, new_r, r_bar)
        return jnp.where(active, tree.parent[b, safe], NO_NODE), r_bar, N, O, V

    _, _, N, O, V = jax.lax.while_loop(
        cond, body,
        (nodes, sim_returns.astype(jnp.float32), tree.N, tree.O, tree.V),
    )
    return tree._replace(N=N, O=O, V=V)


def backprop_update(
    tree: BatchedTree, nodes: jax.Array, sim_returns: jax.Array, gamma: float
) -> BatchedTree:
    """Algorithm 8, vectorized (sequential backprop; no O bookkeeping)."""
    b = _bidx(tree)

    def cond(c):
        n, *_ = c
        return jnp.any(n != NO_NODE)

    def body(c):
        n, r_bar, N, V = c
        active = n != NO_NODE
        safe = jnp.maximum(n, 0)
        new_n = N[b, safe] + 1.0
        new_r = tree.R[b, safe] + gamma * r_bar
        new_v = ((new_n - 1.0) * V[b, safe] + new_r) / new_n
        N = N.at[b, safe].set(jnp.where(active, new_n, N[b, safe]))
        V = V.at[b, safe].set(jnp.where(active, new_v, V[b, safe]))
        r_bar = jnp.where(active, new_r, r_bar)
        return jnp.where(active, tree.parent[b, safe], NO_NODE), r_bar, N, V

    _, _, N, V = jax.lax.while_loop(
        cond, body, (nodes, sim_returns.astype(jnp.float32), tree.N, tree.V)
    )
    return tree._replace(N=N, V=V)


def add_virtual_loss(
    tree: BatchedTree, nodes: jax.Array, r_vl: float
) -> BatchedTree:
    return _shift_virtual_loss(tree, nodes, r_vl)


def remove_virtual_loss(
    tree: BatchedTree, nodes: jax.Array, r_vl: float
) -> BatchedTree:
    return _shift_virtual_loss(tree, nodes, -r_vl)


def _shift_virtual_loss(
    tree: BatchedTree, nodes: jax.Array, delta: float
) -> BatchedTree:
    b = _bidx(tree)

    def cond(c):
        n, _ = c
        return jnp.any(n != NO_NODE)

    def body(c):
        n, VL = c
        active = n != NO_NODE
        safe = jnp.maximum(n, 0)
        VL = VL.at[b, safe].add(jnp.where(active, delta, 0.0))
        return jnp.where(active, tree.parent[b, safe], NO_NODE), VL

    _, VL = jax.lax.while_loop(cond, body, (nodes, tree.VL))
    return tree._replace(VL=VL)


# ---------------------------------------------------------------------------
# Masked stat-mode dispatch.  The batched engines (wave and async) both track
# in-flight statistics per ``stat_mode``; because settles land at different
# ticks per tree in the async engine, every call carries an explicit per-tree
# ``mask`` — masked-out trees contribute no updates (their walk starts at
# ``NO_NODE`` and freezes immediately).
# ---------------------------------------------------------------------------


def mark_in_flight(
    tree: BatchedTree,
    nodes: jax.Array,
    mask: jax.Array,
    *,
    stat_mode: str,
    r_vl: float,
) -> BatchedTree:
    """Per-tree rollout-initiated bookkeeping at ``nodes`` where ``mask``
    holds: Algorithm 2 (``stat_mode='wu'``), virtual loss (``'vl'``), or
    nothing (``'none'``)."""
    targets = jnp.where(mask, nodes, NO_NODE)
    if stat_mode == "wu":
        return incomplete_update(tree, targets)
    if stat_mode == "vl":
        return add_virtual_loss(tree, targets, r_vl)
    return tree


def settle(
    tree: BatchedTree,
    nodes: jax.Array,
    rets: jax.Array,
    mask: jax.Array,
    *,
    stat_mode: str,
    gamma: float,
    r_vl: float,
) -> BatchedTree:
    """Per-tree rollout-completed bookkeeping where ``mask`` holds:
    Algorithm 3 (``'wu'``), virtual-loss removal + plain backprop (``'vl'``),
    or plain backprop (``'none'``)."""
    targets = jnp.where(mask, nodes, NO_NODE)
    if stat_mode == "wu":
        return complete_update(tree, targets, rets, gamma)
    if stat_mode == "vl":
        tree = remove_virtual_loss(tree, targets, r_vl)
        return backprop_update(tree, targets, rets, gamma)
    return backprop_update(tree, targets, rets, gamma)


# ---------------------------------------------------------------------------
# Allocation
# ---------------------------------------------------------------------------


def reserve_children(
    tree: BatchedTree, parents: jax.Array, acts: jax.Array, mask: jax.Array
) -> tuple[BatchedTree, jax.Array, jax.Array]:
    """Per-tree :func:`repro.core.tree.reserve_child` where ``mask`` holds.

    Returns ``(tree, child_nodes[B], ok[B])``; trees at capacity refuse the
    reservation (``ok=False``, child = parent) and latch ``overflowed``.
    """
    b = _bidx(tree)
    has_room = tree.size < tree.capacity
    ok = mask & has_room
    idx = jnp.minimum(tree.size, tree.capacity - 1)

    def keep(buf, new):
        return buf.at[b, idx].set(jnp.where(ok, new, buf[b, idx]))

    tree = tree._replace(
        parent=keep(tree.parent, parents),
        action=keep(tree.action, acts),
        children=tree.children.at[b, parents, acts].set(
            jnp.where(ok, idx, tree.children[b, parents, acts])
        ),
        pending=keep(tree.pending, True),
        depth=keep(tree.depth, tree.depth[b, parents] + 1),
        size=tree.size + ok.astype(jnp.int32),
        overflowed=tree.overflowed | (mask & jnp.logical_not(has_room)),
    )
    return tree, jnp.where(ok, idx, parents).astype(jnp.int32), ok


def finalize_children(
    tree: BatchedTree,
    nodes: jax.Array,
    states: Pytree,
    rewards: jax.Array,
    dones: jax.Array,
    mask: jax.Array,
) -> BatchedTree:
    """Write expansion results into reserved children where ``mask`` holds."""
    b = _bidx(tree)
    tree = set_state(tree, nodes, states, mask)

    def keep(buf, new):
        return buf.at[b, nodes].set(jnp.where(mask, new, buf[b, nodes]))

    return tree._replace(
        R=keep(tree.R, rewards),
        terminal=keep(tree.terminal, dones),
        pending=keep(tree.pending, False),
    )


# ---------------------------------------------------------------------------
# Root statistics
# ---------------------------------------------------------------------------


def root_action_stats(tree: BatchedTree) -> tuple[jax.Array, jax.Array]:
    """Per-tree per-action (N, V) at the root; untried get N=0, V=-inf."""
    kids = tree.children[:, 0]                       # i32[B, A]
    valid = kids >= 0
    safe = jnp.maximum(kids, 0)
    b = _bidx(tree)[:, None]
    n = jnp.where(valid, tree.N[b, safe], 0.0)
    v = jnp.where(valid, tree.V[b, safe], -jnp.inf)
    return n, v


def best_root_action(tree: BatchedTree) -> jax.Array:
    """Most-visited root action per tree (value tiebreak)."""
    n, v = root_action_stats(tree)
    v_rank = jax.nn.softmax(jnp.where(jnp.isfinite(v), v, -1e9), axis=-1)
    return jnp.argmax(n + 1e-6 * v_rank, axis=-1).astype(jnp.int32)
