"""Async-slot WU-UCT — a faithful functional port of the paper's Algorithm 1.

Unlike the wave engine (barrier per wave), this engine reproduces the
master–worker *interleaving* of the paper's real system:

* ``wave_size`` slots model the worker pool; every master tick advances each
  busy slot by **one environment step** (vmapped — the parallel part);
* rollouts terminate at *different* ticks (episodes end at different
  depths), and a finished slot settles (complete update, Algorithm 3) and is
  refilled **immediately** via a fresh selection (eq. 4) + incomplete update
  (Algorithm 2) — no slot ever waits for the slowest rollout.  This is the
  framework's search-side straggler mitigation;
* expansion is a one-step task executed in the same vmapped tick (the paper
  uses a separate expansion pool; Fig. 2 shows those workers under-utilized,
  so folding expansion into the slot loses nothing — DESIGN.md §2).

The entire search is one jitted ``lax.while_loop`` program.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..envs.base import Environment
from . import tree as tree_lib
from .evaluators import EXPAND, FREE, SIM, Evaluator, RolloutEvaluator
from .policies import expansion_action
from .tree import Tree
from .wu_uct import SearchConfig, SearchResult, traverse, _mark_in_flight, _settle

Pytree = Any


class AsyncTickTrace(NamedTuple):
    """Per-master-tick engine snapshots (trace mode; invariant tests).

    Leading axis is the tick index ``K``; the batched engine adds a tree axis
    ``B`` after it.  ``alive`` marks ticks that actually advanced the search
    (``t_done < T`` at tick entry); later snapshots are frozen copies.
    ``state_len`` / ``cache_len`` are ``None`` unless the evaluator carries
    a sequence state / a slot-aux cache (``CachedModelEvaluator``) — they
    let invariant tests check that the cache depth tracks the slot's prefix
    across settle/refill.
    """

    O: jax.Array         # f32[K, M]    in-flight counts after the tick
    parent: jax.Array    # i32[K, M]    parent pointers (grow with reservations)
    kind: jax.Array      # i32[K, W]    slot phase (FREE / EXPAND / SIM)
    sim_node: jax.Array  # i32[K, W]    node each slot's rollout is charged to
    t_done: jax.Array    # i32[K]       completed simulations so far
    alive: jax.Array     # bool[K]
    state_len: Optional[jax.Array] = None  # i32[K, W] slot token prefix length
    cache_len: Optional[jax.Array] = None  # i32[K, W] evaluator cache depth
    blocks_in_use: Optional[jax.Array] = None  # i32[K] paged-pool working set
    frontier_hits: Optional[jax.Array] = None  # i32[K] cumulative refill hits
    busy_slots: Optional[jax.Array] = None  # i32[K] (+[B]) non-FREE slots
    active_trees: Optional[jax.Array] = None  # i32[K] trees still searching


def tick_snapshot(
    carry, alive, cache_len=None, blocks=None, frontier_hits=None
) -> AsyncTickTrace:
    """One :class:`AsyncTickTrace` row from a master-loop carry.

    Both async engines carry ``(tree, slots, rng, t_launch, t_done, ...)``,
    so the trace schema is defined once here — single-tree ``Tree``/slots and
    ``BatchedTree``/batched slots expose the same field names.  ``cache_len``
    is the evaluator's per-slot cache depth (``evaluator.aux_len``), already
    reshaped to the slot table's layout by the engine; ``frontier_hits`` is
    the engine's cumulative count of refills answered from the evaluator's
    frontier cache (WU-UCT's ``O_s`` accounting absorbing speculative
    visits — the engine never dispatched a forward for them).

    ``busy_slots`` / ``active_trees`` are the occupancy counters the serving
    layer aggregates into its slot-idle fraction: per tree, how many of the
    ``W`` slots held in-flight work this tick, and how many trees were still
    searching at all (settled trees' slots are masked FREE and count zero).
    """
    tree, slots = carry[0], carry[1]
    alive_i = jnp.asarray(alive, jnp.int32)
    busy = jnp.sum((slots.kind != FREE).astype(jnp.int32), axis=-1)
    return AsyncTickTrace(
        O=tree.O, parent=tree.parent, kind=slots.kind,
        sim_node=slots.sim_node, t_done=carry[4], alive=alive,
        state_len=getattr(slots.state, "length", None),
        cache_len=cache_len,
        blocks_in_use=blocks,
        frontier_hits=frontier_hits,
        busy_slots=busy * alive_i,
        active_trees=jnp.sum(jnp.atleast_1d(alive_i)),
    )


def slot_tick_step(env: Environment, gamma: float):
    """Per-slot one-env-step transition (the parallel part of a master tick).

    The implementation lives in
    :class:`repro.core.evaluators.RolloutEvaluator`; this wrapper remains
    for callers building the classic per-slot step without an evaluator.
    """
    return RolloutEvaluator(env)._one_step(gamma)


class _AsyncSlots(NamedTuple):
    kind: jax.Array        # i32[W]  FREE / EXPAND / SIM
    sim_node: jax.Array    # i32[W]  node being evaluated
    act: jax.Array         # i32[W]  expansion action (EXPAND phase)
    state: Pytree          # pytree[W, ...] current rollout env state
    rollout_done: jax.Array  # bool[W]
    acc: jax.Array         # f32[W] discounted return accumulator
    disc: jax.Array        # f32[W]
    steps: jax.Array       # i32[W] simulation steps taken


def run_async_search(
    env: Environment,
    cfg: SearchConfig,
    root_state: Pytree,
    rng: jax.Array,
    trace_ticks: int = 0,
    evaluator: Optional[Evaluator] = None,
    use_kernel: bool = True,
) -> SearchResult:
    """Run one async-slot search.

    With ``trace_ticks > 0`` (a static bound ≥ the actual tick count) the
    master loop runs as a fixed-length scan instead of a ``while_loop`` and
    the function returns ``(SearchResult, AsyncTickTrace)`` — identical
    search output, plus per-tick snapshots for invariant checking.
    ``evaluator`` owns the per-slot stepping (default: the classic env
    rollout; :class:`repro.core.evaluators.ModelEvaluator` turns every
    master tick into one batched model forward).
    """
    W = cfg.wave_size
    T = cfg.num_simulations
    width = min(cfg.max_width, env.num_actions)
    capacity = T + W + 1
    evaluator = evaluator if evaluator is not None else RolloutEvaluator(env)
    tree0 = tree_lib.init_tree(root_state, capacity, env.num_actions)

    def slot_state0():
        proto = evaluator.init_state(root_state, (W,))
        return _AsyncSlots(
            kind=jnp.zeros((W,), jnp.int32),
            sim_node=jnp.zeros((W,), jnp.int32),
            act=jnp.zeros((W,), jnp.int32),
            state=proto,
            rollout_done=jnp.zeros((W,), jnp.bool_),
            acc=jnp.zeros((W,), jnp.float32),
            disc=jnp.ones((W,), jnp.float32),
            steps=jnp.zeros((W,), jnp.int32),
        )

    def set_slot(slots: _AsyncSlots, j, **kw) -> _AsyncSlots:
        upd = {}
        for f in slots._fields:
            v = getattr(slots, f)
            if f in kw:
                if f == "state":
                    v = jax.tree.map(lambda b, x: b.at[j].set(x), v, kw[f])
                else:
                    v = v.at[j].set(kw[f])
            upd[f] = v
        return _AsyncSlots(**upd)

    # ------------------------------------------------------------------
    # Master tick
    # ------------------------------------------------------------------
    def refill(carry):
        """Fill FREE slots with fresh selections (Algorithm 1 main loop)."""
        tree, slots, rng, t_launch, t_done, aux, fr_hits = carry

        def body(j, c):
            tree, slots, rng, t_launch, t_done, aux, fr_hits = c
            rng, k_t, k_e = jax.random.split(rng, 3)
            want = (slots.kind[j] == FREE) & (t_launch < T)

            def do_fill(op):
                tree, slots, t_launch, t_done, aux, fr_hits = op
                node = traverse(tree, k_t, cfg, use_kernel)
                kids = tree.children[node]
                n_tried = jnp.sum((kids >= 0).astype(jnp.int32))
                is_term = tree.terminal[node]
                at_depth = tree.depth[node] >= cfg.max_depth
                needs_exp = (
                    jnp.logical_not(is_term)
                    & jnp.logical_not(at_depth)
                    & (n_tried < width)
                )
                act = expansion_action(tree, node, k_e)
                tree, child, reserved = jax.lax.cond(
                    needs_exp,
                    lambda t: tree_lib.reserve_child(t, node, act),
                    lambda t: (t, node, jnp.bool_(False)),
                    tree,
                )
                needs_exp = needs_exp & reserved
                sim_node = jnp.where(needs_exp, child, node).astype(jnp.int32)
                tree = _mark_in_flight(tree, sim_node, cfg)

                # Terminal hit: settle instantly, slot stays FREE (the paper
                # counts it as a completed simulation with return 0).
                def settle_term(t):
                    return _settle(t, sim_node, jnp.float32(0.0), cfg)

                tree = jax.lax.cond(is_term, settle_term, lambda t: t, tree)
                parent_state = tree_lib.get_state(tree, node)
                # Re-sync the evaluator's slot cache with the new path's
                # prefix (no-op for stateless evaluators; terminal hits
                # launch nothing, so their cache stays untouched).
                aux2, hit = evaluator.refill_aux(
                    cfg, aux, jnp.reshape(j, (1,)),
                    jax.tree.map(lambda x: x[None], parent_state),
                    jnp.reshape(jnp.logical_not(is_term), (1,)),
                )
                slots2 = set_slot(
                    slots,
                    j,
                    kind=jnp.where(
                        is_term, FREE, jnp.where(needs_exp, EXPAND, SIM)
                    ).astype(jnp.int32),
                    sim_node=sim_node,
                    act=act,
                    state=parent_state,
                    rollout_done=tree.terminal[sim_node],
                    acc=jnp.float32(0.0),
                    disc=jnp.float32(1.0),
                    steps=jnp.int32(0),
                )
                return (
                    tree,
                    slots2,
                    t_launch + 1,
                    t_done + is_term.astype(jnp.int32),
                    aux2,
                    fr_hits + jnp.sum(hit).astype(jnp.int32),
                )

            tree, slots, t_launch, t_done, aux, fr_hits = jax.lax.cond(
                want, do_fill, lambda op: op,
                (tree, slots, t_launch, t_done, aux, fr_hits),
            )
            return tree, slots, rng, t_launch, t_done, aux, fr_hits

        return jax.lax.fori_loop(0, W, body, carry)

    def tick(slots: _AsyncSlots, rng, aux):
        """Advance every busy slot by one env step (the parallel part)."""
        keys = jax.random.split(rng, W)
        out, aux = evaluator.tick(
            cfg, slots.kind, slots.act, slots.state, slots.rollout_done,
            slots.acc, slots.disc, slots.steps, keys, aux,
        )
        new_state, r_edge, done_edge, acc, disc, steps, rollout_done = out
        slots = slots._replace(
            state=new_state, acc=acc, disc=disc, steps=steps,
            rollout_done=rollout_done,
        )
        return slots, r_edge, done_edge, aux

    def settle_finished(carry, r_edge, done_edge):
        """EXPAND→SIM transitions (finalize child) + completed rollouts."""
        tree, slots, t_done = carry

        def body(j, c):
            tree, slots, t_done = c
            kind = slots.kind[j]

            # EXPAND slot: its env step just produced the child state.
            def finish_expand(op):
                tree, slots = op
                st = jax.tree.map(lambda x: x[j], slots.state)
                tree = tree_lib.finalize_child(
                    tree, slots.sim_node[j], st, r_edge[j], done_edge[j]
                )
                return tree, set_slot(
                    slots, j, kind=jnp.int32(SIM), steps=jnp.int32(0)
                )

            tree, slots = jax.lax.cond(
                kind == EXPAND, finish_expand, lambda op: op, (tree, slots)
            )

            # SIM slot finished (episode done or step cap): complete update.
            fin = (slots.kind[j] == SIM) & (
                slots.rollout_done[j] | (slots.steps[j] >= cfg.max_sim_steps)
            )

            def finish_sim(op):
                tree, slots, t_done = op
                tree = _settle(tree, slots.sim_node[j], slots.acc[j], cfg)
                return tree, set_slot(slots, j, kind=jnp.int32(FREE)), t_done + 1

            tree, slots, t_done = jax.lax.cond(
                fin, finish_sim, lambda op: op, (tree, slots, t_done)
            )
            return tree, slots, t_done

        return jax.lax.fori_loop(0, W, body, (tree, slots, t_done))

    def cond(carry):
        return carry[4] < T          # t_done

    def master_iter(carry):
        tree, slots, rng, t_launch, t_done, ticks, max_o, aux, fr_hits = carry
        rng, k_tick = jax.random.split(rng)
        tree, slots, rng, t_launch, t_done, aux, fr_hits = refill(
            (tree, slots, rng, t_launch, t_done, aux, fr_hits)
        )
        max_o = jnp.maximum(max_o, tree.O[0])
        slots, r_edge, done_edge, aux = tick(slots, k_tick, aux)
        tree, slots, t_done = settle_finished(
            (tree, slots, t_done), r_edge, done_edge
        )
        return (
            tree, slots, rng, t_launch, t_done, ticks + 1, max_o, aux, fr_hits
        )

    init = (
        tree0, slot_state0(), rng, jnp.int32(0), jnp.int32(0), jnp.int32(0),
        jnp.float32(0.0), evaluator.init_aux(root_state, (W,)), jnp.int32(0),
    )
    if trace_ticks > 0:
        # Same program as the while_loop below (master_iter applied while
        # t_done < T, carry frozen afterwards), but with a static trip count
        # so each tick's state can be captured.
        def scan_body(carry, _):
            alive = cond(carry)
            new = jax.tree.map(
                lambda a, b: jnp.where(alive, a, b), master_iter(carry), carry
            )
            return new, tick_snapshot(
                new, alive, evaluator.aux_len(new[7]),
                evaluator.aux_blocks(new[7]),
                frontier_hits=new[8],
            )

        final, trace = jax.lax.scan(scan_body, init, None, length=trace_ticks)
        tree, slots, _, _, _, ticks, max_o, _, _ = final
    else:
        trace = None
        tree, slots, _, _, _, ticks, max_o, _, _ = jax.lax.while_loop(
            cond, master_iter, init
        )

    root_n, root_v = tree_lib.root_action_stats(tree)
    result = SearchResult(
        action=tree_lib.best_root_action(tree),
        root_n=root_n,
        root_v=root_v,
        tree_size=tree.size,
        dup_selections=jnp.float32(0.0),
        max_o=max_o,
        overflowed=tree.overflowed,
        ticks=ticks,
    )
    return (result, trace) if trace_ticks > 0 else result


def make_async_searcher(
    env: Environment,
    cfg: SearchConfig,
    jit: bool = True,
    evaluator: Optional[Evaluator] = None,
    use_kernel: bool = True,
):
    fn = functools.partial(
        run_async_search, env, cfg, evaluator=evaluator, use_kernel=use_kernel
    )
    return jax.jit(fn) if jit else fn
