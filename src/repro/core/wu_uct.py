"""WU-UCT — wave-scheduled parallel MCTS (the paper's Algorithm 1 on SPMD).

TPU adaptation of the paper's master–worker architecture (see DESIGN.md §2):

* the **master** (selection + incomplete/complete updates, Algorithms 1–3) is
  replicated, deterministic bookkeeping over the SoA tree;
* the **workers** are ``wave_size`` in-flight simulation slots whose expensive
  expansion + simulation work is batched (``vmap``) and shardable over the
  ``data`` mesh axis;
* inside a wave, selections happen *sequentially with incomplete updates in
  between*, so slot ``j`` sees the ``O`` mass of slots ``0..j-1`` — exactly
  the information a freshly-idle worker sees in the paper's async system when
  all other workers are busy.

The same engine also executes the baselines (LeafP / TreeP / sequential UCT)
by switching the statistics mode and the selection rule — this mirrors the
paper's App. D, which implements all algorithms in one package so speed
comparisons are apples-to-apples.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..envs.base import Environment
from . import tree as tree_lib
from .evaluators import Evaluator, RolloutEvaluator
from .policies import PolicyConfig, expansion_action
from .tree import Tree

Pytree = Any


class SearchConfig(NamedTuple):
    num_simulations: int = 128      # T_max
    wave_size: int = 16             # W — number of in-flight workers
    max_depth: int = 100            # d_max
    max_sim_steps: int = 100        # simulation rollout cap (App. D: 100)
    max_width: int = 20             # search-width cap (paper: 5 tap / 20 Atari)
    gamma: float = 0.99
    policy: PolicyConfig = PolicyConfig()
    stat_mode: str = "wu"           # wu | vl | none  (in-flight bookkeeping)
    expand_coin: float = 0.5        # traversal rule (iii) stop probability
    value_mix: float = 0.0          # R = (1-m)·R_simu + m·V(s)   (App. D: 0.5)
    deterministic_expansion: bool = False  # first-untried action (tests/oracle)


class SearchResult(NamedTuple):
    action: jax.Array        # i32[] chosen root action
    root_n: jax.Array        # f32[A] root child visit counts
    root_v: jax.Array        # f32[A] root child values
    tree_size: jax.Array     # i32[]
    # Diagnostics for the exploration-collapse studies (Sec. 2.2 / Sec. 4):
    dup_selections: jax.Array  # f32[] avg duplicate stop-nodes per wave
    max_o: jax.Array           # f32[] peak O at root (in-flight pressure)
    overflowed: jax.Array      # bool[] tree capacity was hit during search
    ticks: jax.Array           # i32[] master iterations (waves / async ticks)


# ---------------------------------------------------------------------------
# Selection (paper Sec. 3.1 traversal with rules (i)-(iii))
# ---------------------------------------------------------------------------


def traverse(
    tree: Tree, rng: jax.Array, cfg: SearchConfig, use_kernel: bool = True
) -> jax.Array:
    """Walk the tree from the root by the configured tree policy.

    A ``B=1`` view over the batched lockstep traversal
    (:func:`repro.core.batched_search.traverse_batched`), so single-tree and
    multi-root engines score selections through the same fused Pallas
    ``tree_select`` path — one selection implementation, kernel included.
    Per-level RNG splits match the old per-node ``while_loop`` exactly, so
    the walk is bit-identical to the scalar implementation it replaced.
    """
    # Local imports: batched_search/batched_tree import this module at load.
    from .batched_search import _canonical_keys, traverse_batched
    from .batched_tree import BatchedTree

    lifted = BatchedTree(*(jax.tree.map(lambda x: x[None], f) for f in tree))
    # Canonicalize typed PRNG keys to raw key data before adding the batch
    # axis — the batched walk's masked key-freeze broadcasts against [B, 2].
    nodes = traverse_batched(
        lifted, _canonical_keys(rng)[None], cfg, use_kernel=use_kernel
    )
    return nodes[0]


# ---------------------------------------------------------------------------
# Simulation (the worker-side rollout; paper Fig. 1(a) "simulation")
# ---------------------------------------------------------------------------


def rollout_return(
    env: Environment,
    cfg: SearchConfig,
    state: Pytree,
    already_done: jax.Array,
    rng: jax.Array,
) -> jax.Array:
    """Discounted simulation return under the default rollout evaluation.

    The implementation lives in
    :meth:`repro.core.evaluators.RolloutEvaluator.rollout`; this wrapper
    remains for callers that want the classic ``env.policy`` rollout without
    constructing an evaluator.
    """
    return RolloutEvaluator(env).rollout(cfg, state, already_done, rng)


# ---------------------------------------------------------------------------
# Wave engine
# ---------------------------------------------------------------------------

KIND_SIM = 0      # simulate from an existing node (no expansion)
KIND_EXPAND = 1   # expand a new child, then simulate from it
KIND_TERMINAL = 2 # traversal hit a terminal node: complete with return 0


class _Slots(NamedTuple):
    kind: jax.Array       # i32[W]
    stop_node: jax.Array  # i32[W] node where traversal stopped
    sim_node: jax.Array   # i32[W] node whose state seeds the simulation
    act: jax.Array        # i32[W] expansion action (undefined for kind 0/2)


def _mark_in_flight(tree: Tree, node: jax.Array, cfg: SearchConfig) -> Tree:
    if cfg.stat_mode == "wu":
        return tree_lib.incomplete_update(tree, node)
    if cfg.stat_mode == "vl":
        return tree_lib.add_virtual_loss(tree, node, cfg.policy.r_vl)
    return tree


def _settle(
    tree: Tree, node: jax.Array, ret: jax.Array, cfg: SearchConfig
) -> Tree:
    if cfg.stat_mode == "wu":
        return tree_lib.complete_update(tree, node, ret, cfg.gamma)
    if cfg.stat_mode == "vl":
        tree = tree_lib.remove_virtual_loss(tree, node, cfg.policy.r_vl)
        return tree_lib.backprop_update(tree, node, ret, cfg.gamma)
    return tree_lib.backprop_update(tree, node, ret, cfg.gamma)


def _phase1_select(
    tree: Tree, rng: jax.Array, cfg: SearchConfig, use_kernel: bool = True
) -> tuple[Tree, _Slots, jax.Array]:
    """Sequentially select `wave_size` slots, applying in-flight statistics
    between selections (the heart of WU-UCT)."""
    W = cfg.wave_size
    width = min(cfg.max_width, tree.num_actions)

    def slot_body(j, carry):
        tree, rng, slots = carry
        rng, k_t, k_e = jax.random.split(rng, 3)
        node = traverse(tree, k_t, cfg, use_kernel)

        kids = tree.children[node]
        n_tried = jnp.sum((kids >= 0).astype(jnp.int32))
        is_term = tree.terminal[node]
        at_depth = tree.depth[node] >= cfg.max_depth
        needs_expand = (
            jnp.logical_not(is_term) & jnp.logical_not(at_depth) & (n_tried < width)
        )
        if cfg.deterministic_expansion:
            untried = tree.children[node] < 0
            act = jnp.argmax(untried).astype(jnp.int32)
        else:
            act = expansion_action(tree, node, k_e)

        def do_reserve(t):
            return tree_lib.reserve_child(t, node, act)

        def no_reserve(t):
            return t, node, jnp.bool_(False)

        tree, child, reserved = jax.lax.cond(
            needs_expand, do_reserve, no_reserve, tree
        )
        # A refused reservation (capacity) degrades to simulating from the
        # stop node itself — no expansion, no state write.
        expanded = needs_expand & reserved
        kind = jnp.where(
            is_term, KIND_TERMINAL, jnp.where(expanded, KIND_EXPAND, KIND_SIM)
        ).astype(jnp.int32)
        sim_node = jnp.where(expanded, child, node).astype(jnp.int32)

        # Paper Algorithm 1: incomplete update as soon as the rollout is
        # initiated; terminal hits settle immediately with return 0.
        tree = _mark_in_flight(tree, sim_node, cfg)
        tree = jax.lax.cond(
            is_term,
            lambda t: _settle(t, sim_node, jnp.float32(0.0), cfg),
            lambda t: t,
            tree,
        )

        slots = _Slots(
            kind=slots.kind.at[j].set(kind),
            stop_node=slots.stop_node.at[j].set(node),
            sim_node=slots.sim_node.at[j].set(sim_node),
            act=slots.act.at[j].set(act),
        )
        return tree, rng, slots

    slots0 = _Slots(
        kind=jnp.zeros((W,), jnp.int32),
        stop_node=jnp.zeros((W,), jnp.int32),
        sim_node=jnp.zeros((W,), jnp.int32),
        act=jnp.zeros((W,), jnp.int32),
    )
    tree, rng, slots = jax.lax.fori_loop(0, W, slot_body, (tree, rng, slots0))

    # Diagnostics: duplicate stop-nodes within this wave (exploration
    # collapse indicator — Sec. 2.2 Fig. 1(c)).
    sorted_stops = jnp.sort(slots.stop_node)
    dups = jnp.sum((sorted_stops[1:] == sorted_stops[:-1]).astype(jnp.float32))
    return tree, slots, dups


def _phase2_work(
    env: Environment,
    cfg: SearchConfig,
    tree: Tree,
    slots: _Slots,
    rng: jax.Array,
    constrain: Optional[Callable[[Pytree], Pytree]] = None,
    evaluator: Optional[Evaluator] = None,
):
    """The parallel part: expansion env-step + simulation rollout per slot.

    This is the only compute that touches the environment/policy network; on
    a pod it shards over the ``data`` axis (``constrain`` installs the
    sharding constraint for the GSPMD partitioner).  ``evaluator`` owns the
    simulation (default: the classic env rollout).
    """
    W = cfg.wave_size
    evaluator = evaluator if evaluator is not None else RolloutEvaluator(env)
    keys = jax.random.split(rng, W)

    def one_slot(kind, stop_node, sim_node, act, key):
        parent_state = tree_lib.get_state(tree, stop_node)
        child_state, r_edge, done_child = env.step(parent_state, act)
        is_exp = kind == KIND_EXPAND
        start_state = jax.tree.map(
            lambda a, b: jnp.where(is_exp, a, b),
            child_state,
            tree_lib.get_state(tree, sim_node),
        )
        start_done = jnp.where(is_exp, done_child, tree.terminal[sim_node])
        ret = evaluator.rollout(cfg, start_state, start_done, key)
        return child_state, r_edge, done_child, ret

    args = (slots.kind, slots.stop_node, slots.sim_node, slots.act, keys)
    if constrain is not None:
        args = constrain(args)
    out = jax.vmap(one_slot)(*args)
    if constrain is not None:
        out = constrain(out)
    return out  # (child_states[W,...], r_edge[W], done_child[W], ret[W])


def _phase3_settle(
    tree: Tree,
    cfg: SearchConfig,
    slots: _Slots,
    child_states: Pytree,
    r_edge: jax.Array,
    done_child: jax.Array,
    rets: jax.Array,
) -> Tree:
    """Master-side completion: write expansion results + complete updates."""
    W = cfg.wave_size

    def slot_body(j, tree):
        kind = slots.kind[j]
        sim_node = slots.sim_node[j]

        def do_finalize(t):
            st = jax.tree.map(lambda x: x[j], child_states)
            return tree_lib.finalize_child(t, sim_node, st, r_edge[j], done_child[j])

        tree = jax.lax.cond(kind == KIND_EXPAND, do_finalize, lambda t: t, tree)
        tree = jax.lax.cond(
            kind != KIND_TERMINAL,
            lambda t: _settle(t, sim_node, rets[j], cfg),
            lambda t: t,
            tree,
        )
        return tree

    return jax.lax.fori_loop(0, W, slot_body, tree)


def run_search(
    env: Environment,
    cfg: SearchConfig,
    root_state: Pytree,
    rng: jax.Array,
    constrain: Optional[Callable[[Pytree], Pytree]] = None,
    evaluator: Optional[Evaluator] = None,
    use_kernel: bool = True,
) -> SearchResult:
    """Full search from ``root_state``; returns the move decision + stats."""
    if cfg.num_simulations % cfg.wave_size != 0:
        raise ValueError("num_simulations must be divisible by wave_size")
    num_waves = cfg.num_simulations // cfg.wave_size
    capacity = cfg.num_simulations + cfg.wave_size + 1
    tree = tree_lib.init_tree(root_state, capacity, env.num_actions)

    def wave_body(i, carry):
        tree, rng, dup_acc, max_o = carry
        rng, k_sel, k_sim = jax.random.split(rng, 3)
        tree, slots, dups = _phase1_select(tree, k_sel, cfg, use_kernel)
        max_o = jnp.maximum(max_o, tree.O[0])
        child_states, r_edge, done_child, rets = _phase2_work(
            env, cfg, tree, slots, k_sim, constrain, evaluator
        )
        tree = _phase3_settle(tree, cfg, slots, child_states, r_edge, done_child, rets)
        return tree, rng, dup_acc + dups, max_o

    tree, _, dup_acc, max_o = jax.lax.fori_loop(
        0, num_waves, wave_body, (tree, rng, jnp.float32(0.0), jnp.float32(0.0))
    )

    root_n, root_v = tree_lib.root_action_stats(tree)
    return SearchResult(
        action=tree_lib.best_root_action(tree),
        root_n=root_n,
        root_v=root_v,
        tree_size=tree.size,
        dup_selections=dup_acc / num_waves,
        max_o=max_o,
        overflowed=tree.overflowed,
        ticks=jnp.int32(num_waves),
    )


def make_searcher(
    env: Environment,
    cfg: SearchConfig,
    constrain: Optional[Callable[[Pytree], Pytree]] = None,
    jit: bool = True,
    evaluator: Optional[Evaluator] = None,
    use_kernel: bool = True,
):
    """Build ``search(root_state, rng) -> SearchResult`` for this env/config."""
    fn = functools.partial(
        run_search, env, cfg, constrain=constrain, evaluator=evaluator,
        use_kernel=use_kernel,
    )
    return jax.jit(fn) if jit else fn


# ---------------------------------------------------------------------------
# Episode runner (the outer gameplay loop of Sec. 5: one search per move)
# ---------------------------------------------------------------------------


def play_episode(
    env: Environment,
    cfg: SearchConfig,
    rng: jax.Array,
    max_moves: int = 64,
    searcher=None,
):
    """Play one episode, calling the tree-search subroutine at every step.

    Returns (episode_return, moves_used, done) — `moves_used` is the paper's
    "game step" metric for the tap game.
    """
    search = searcher or make_searcher(env, cfg)

    @jax.jit
    def move(state, key):
        k_search, k_step = jax.random.split(key)
        res = search(state, k_search)
        nxt, r, done = env.step(state, res.action)
        return nxt, r, done, res

    rng, k_init = jax.random.split(rng)
    state = env.init(k_init)
    total, moves, done = 0.0, 0, False
    for _ in range(max_moves):
        rng, k = jax.random.split(rng)
        state, r, d, _ = move(state, k)
        total += float(r)
        moves += 1
        if bool(d):
            done = True
            break
    return total, moves, done
