# The paper's primary contribution: WU-UCT parallel MCTS (wave-scheduled,
# SPMD-shardable) plus the baseline parallelizations it is compared against.
from .policies import PolicyConfig
from .tree import Tree, init_tree
from .wu_uct import SearchConfig, SearchResult, make_searcher, play_episode, run_search
from .async_search import make_async_searcher, run_async_search
from .baselines import (
    make_algorithm,
    make_config,
    run_leafp,
    run_rootp,
    run_treep,
)

__all__ = [
    "PolicyConfig",
    "Tree",
    "init_tree",
    "SearchConfig",
    "SearchResult",
    "make_async_searcher",
    "make_searcher",
    "play_episode",
    "run_async_search",
    "run_search",
    "make_algorithm",
    "make_config",
    "run_leafp",
    "run_rootp",
    "run_treep",
]
