# The paper's primary contribution: WU-UCT parallel MCTS (wave-scheduled,
# SPMD-shardable) plus the baseline parallelizations it is compared against.
#
# Public surface: describe the search with a `SearchSpec` and build it with
# `build_searcher(env, spec)` — one front door for every engine (wave/async),
# batch mode (single-root or B-tree lockstep through the fused Pallas
# tree_select kernel) and algorithm (WU-UCT + App. B baselines).  Leaf
# evaluation is pluggable via `Evaluator` (`RolloutEvaluator` is the default
# env rollout; `ModelEvaluator` batches every master tick into one LM
# forward).
#
# The old per-engine entry points below are deprecated shims for one
# release; call `build_searcher` instead.
import functools as _functools
import warnings as _warnings

from .api import SearchSpec, as_search_config, build_searcher, make_config
from .evaluators import Evaluator, ModelEvaluator, RolloutEvaluator
from .policies import PolicyConfig
from .tree import Tree, init_tree
from .batched_tree import BatchedTree, init_batched_tree
from .wu_uct import SearchConfig, SearchResult, play_episode
from .async_search import AsyncTickTrace
from . import async_search as _async_search
from . import baselines as _baselines
from . import batched_async_search as _batched_async_search
from . import batched_search as _batched_search
from . import wu_uct as _wu_uct


def _deprecated(name: str, fn, instead: str):
    @_functools.wraps(fn)
    def wrapper(*args, **kwargs):
        _warnings.warn(
            f"repro.core.{name} is deprecated; use {instead} "
            "(see repro.core.api).",
            DeprecationWarning,
            stacklevel=2,
        )
        return fn(*args, **kwargs)

    return wrapper


# --- deprecated engine entry points (one release of shim) -------------------
_SPEC = "build_searcher(env, SearchSpec(...))"
run_search = _deprecated(
    "run_search", _wu_uct.run_search, f"{_SPEC} with engine='wave'")
run_search_batched = _deprecated(
    "run_search_batched", _batched_search.run_search_batched,
    f"{_SPEC} with engine='wave', batch=B")
run_async_search = _deprecated(
    "run_async_search", _async_search.run_async_search,
    f"{_SPEC} with engine='async'")
run_async_search_batched = _deprecated(
    "run_async_search_batched", _batched_async_search.run_async_search_batched,
    f"{_SPEC} with engine='async', batch=B")
run_leafp = _deprecated(
    "run_leafp", _baselines.run_leafp, f"{_SPEC} with algo='leafp'")
run_treep = _deprecated(
    "run_treep", _baselines.run_treep, f"{_SPEC} with algo='treep'")
run_rootp = _deprecated(
    "run_rootp", _baselines.run_rootp, f"{_SPEC} with algo='rootp'")
make_searcher = _deprecated(
    "make_searcher", _wu_uct.make_searcher, f"{_SPEC} with engine='wave'")
make_async_searcher = _deprecated(
    "make_async_searcher", _async_search.make_async_searcher,
    f"{_SPEC} with engine='async'")
make_batched_searcher = _deprecated(
    "make_batched_searcher", _batched_search.make_batched_searcher,
    f"{_SPEC} with engine='wave', batch=B")
make_batched_async_searcher = _deprecated(
    "make_batched_async_searcher",
    _batched_async_search.make_batched_async_searcher,
    f"{_SPEC} with engine='async', batch=B")
make_algorithm = _deprecated(
    "make_algorithm", _baselines.make_algorithm, f"{_SPEC} with algo=...")

__all__ = [
    # the front door
    "SearchSpec",
    "as_search_config",
    "build_searcher",
    "make_config",
    # evaluators (pluggable leaf evaluation)
    "Evaluator",
    "RolloutEvaluator",
    "ModelEvaluator",
    # configs / results / trees
    "AsyncTickTrace",
    "PolicyConfig",
    "SearchConfig",
    "SearchResult",
    "Tree",
    "init_tree",
    "BatchedTree",
    "init_batched_tree",
    "play_episode",
    # deprecated shims
    "make_algorithm",
    "make_async_searcher",
    "make_batched_async_searcher",
    "make_batched_searcher",
    "make_searcher",
    "run_async_search",
    "run_async_search_batched",
    "run_leafp",
    "run_rootp",
    "run_search",
    "run_search_batched",
    "run_treep",
]
