# The paper's primary contribution: WU-UCT parallel MCTS (wave-scheduled,
# SPMD-shardable) plus the baseline parallelizations it is compared against,
# and the batched multi-root engine (B independent trees in lockstep through
# the fused Pallas tree_select kernel).
from .policies import PolicyConfig
from .tree import Tree, init_tree
from .batched_tree import BatchedTree, init_batched_tree
from .wu_uct import SearchConfig, SearchResult, make_searcher, play_episode, run_search
from .batched_search import make_batched_searcher, run_search_batched
from .async_search import AsyncTickTrace, make_async_searcher, run_async_search
from .batched_async_search import (
    make_batched_async_searcher,
    run_async_search_batched,
)
from .baselines import (
    make_algorithm,
    make_config,
    run_leafp,
    run_rootp,
    run_treep,
)

__all__ = [
    "AsyncTickTrace",
    "PolicyConfig",
    "Tree",
    "init_tree",
    "BatchedTree",
    "init_batched_tree",
    "SearchConfig",
    "SearchResult",
    "make_async_searcher",
    "make_batched_async_searcher",
    "make_batched_searcher",
    "make_searcher",
    "play_episode",
    "run_async_search",
    "run_async_search_batched",
    "run_search",
    "run_search_batched",
    "make_algorithm",
    "make_config",
    "run_leafp",
    "run_rootp",
    "run_treep",
]
