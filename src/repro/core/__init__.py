# The paper's primary contribution: WU-UCT parallel MCTS (wave-scheduled,
# SPMD-shardable) plus the baseline parallelizations it is compared against.
#
# Public surface: describe the search with a `SearchSpec` and build it with
# `build_searcher(env, spec)` — one front door for every engine (wave/async),
# batch mode (single-root or B-tree lockstep through the fused Pallas
# tree_select kernel) and algorithm (WU-UCT + App. B baselines).  Leaf
# evaluation is pluggable via `Evaluator` (`RolloutEvaluator` is the default
# env rollout; `ModelEvaluator` batches every master tick into one LM
# forward; `CachedModelEvaluator` makes that forward a single KV-cached
# decode step).
#
# The pre-facade per-engine entry points (`run_*`, `make_*searcher`,
# `make_algorithm`) finished their one-release deprecation window and are
# gone from this namespace; the underlying functions remain importable from
# their engine modules (`repro.core.wu_uct`, `repro.core.async_search`, …)
# for tests and oracles, but callers should use `build_searcher`.
from .api import SearchSpec, as_search_config, build_searcher, make_config
from .evaluators import (
    CachedModelEvaluator,
    Evaluator,
    FrontierModelEvaluator,
    ModelEvaluator,
    PagedCachedModelEvaluator,
    PagedFrontierModelEvaluator,
    RolloutEvaluator,
)
from .policies import PolicyConfig
from .tree import Tree, init_tree
from .batched_tree import BatchedTree, init_batched_tree
from .wu_uct import SearchConfig, SearchResult, play_episode
from .async_search import AsyncTickTrace

__all__ = [
    # the front door
    "SearchSpec",
    "as_search_config",
    "build_searcher",
    "make_config",
    # evaluators (pluggable leaf evaluation)
    "Evaluator",
    "RolloutEvaluator",
    "ModelEvaluator",
    "CachedModelEvaluator",
    "PagedCachedModelEvaluator",
    "FrontierModelEvaluator",
    "PagedFrontierModelEvaluator",
    # configs / results / trees
    "AsyncTickTrace",
    "PolicyConfig",
    "SearchConfig",
    "SearchResult",
    "Tree",
    "init_tree",
    "BatchedTree",
    "init_batched_tree",
    "play_episode",
]
