"""The one front door for search: ``SearchSpec`` → ``build_searcher``.

The paper keeps one principled tree policy (WU-UCT's eq. 4) while the
expensive expansion/simulation work is farmed out to parallel workers.  This
module exposes that one idea through one configuration surface instead of
seven divergent entry points:

* :class:`SearchSpec` — a frozen spec subsuming ``SearchConfig`` + the
  algorithm, engine and batch choice.  ``engine='wave'`` is the barrier-per-
  wave engine; ``engine='async'`` the slot-level master–worker interleaving;
  ``batch=B>0`` runs ``B`` independent trees in lockstep through the fused
  Pallas ``tree_select`` kernel.  ``algo`` selects WU-UCT or any baseline
  parallelization the paper compares against (App. B) — RootP/Ensemble-UCT
  rides the same surface rather than a bespoke runner ("Ensemble UCT Needs
  High Exploitation").
* :func:`build_searcher` — dispatches to the right engine and returns the
  jitted searcher.  Leaf evaluation is pluggable via
  :class:`repro.core.evaluators.Evaluator` (the tree-statistics vs. leaf-
  evaluation split of "On Effective Parallelization of MCTS"): the default
  reproduces today's ``env.policy`` rollouts bit-for-bit, while
  :class:`~repro.core.evaluators.ModelEvaluator` batches every master
  tick's ``[B·W]`` in-flight slots into one policy/value LM forward.

The old per-engine entry points (``run_search``, ``run_async_search``, …)
remain importable from :mod:`repro.core` as deprecated shims for one release.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional

import jax

from ..envs.base import Environment
from .async_search import run_async_search
from .baselines import run_leafp, run_rootp
from .batched_async_search import run_async_search_batched
from .batched_search import run_search_batched
from .evaluators import CachedModelEvaluator, Evaluator, ModelEvaluator
from .policies import PolicyConfig
from .wu_uct import SearchConfig, run_search

Pytree = Any

ALGOS = ("wu_uct", "uct", "treep", "treep_vc", "leafp", "rootp")
ENGINES = ("wave", "async")


class SearchSpec(NamedTuple):
    """Frozen, hashable description of one search program.

    ``algo`` picks the tree policy + in-flight statistics mode; ``engine``
    the scheduling (wave barrier vs. async slot interleaving); ``batch`` the
    number of independent root states per call (0 = single-root).  The
    remaining fields are the paper's search knobs, flattened so a spec is a
    plain value — no nested ``PolicyConfig`` to thread by hand.
    """

    algo: str = "wu_uct"            # wu_uct | uct | treep | treep_vc | leafp | rootp
    engine: str = "wave"            # wave | async
    batch: int = 0                  # B > 0: multi-root lockstep engines
    num_simulations: int = 128      # T_max
    wave_size: int = 16             # W — in-flight workers (K for rootp)
    max_depth: int = 100            # d_max
    max_sim_steps: int = 100        # simulation rollout cap (App. D: 100)
    max_width: int = 20             # search-width cap (paper: 5 tap / 20 Atari)
    gamma: float = 0.99
    beta: float = 1.0               # exploration constant β
    r_vl: float = 1.0               # TreeP virtual loss
    n_vl: float = 1.0               # TreeP-VC virtual pseudo-count (eq. 7)
    expand_coin: float = 0.5        # traversal rule (iii) stop probability
    value_mix: float = 0.0          # R = (1-m)·R_simu + m·V(s)  (App. D: 0.5)
    deterministic_expansion: bool = False  # first-untried action (tests)
    use_kernel: bool = True         # Pallas tree_select vs. jnp reference

    @property
    def config(self) -> SearchConfig:
        return as_search_config(self)


# Per-algo (policy kind, stat_mode).  Baselines score with plain UCT — no
# in-flight statistics exist for leafp/rootp; treep_vc's eq. (7) consumes the
# in-flight count c == O, so it runs 'wu' bookkeeping.
_ALGO_MODES = {
    "wu_uct": ("wu_uct", "wu"),
    "uct": ("uct", "none"),
    "treep": ("treep", "vl"),
    "treep_vc": ("treep_vc", "wu"),
    "leafp": ("uct", "none"),
    "rootp": ("uct", "none"),
}


def as_search_config(spec: SearchSpec) -> SearchConfig:
    """Lower a :class:`SearchSpec` to the engines' :class:`SearchConfig`."""
    if spec.algo not in ALGOS:
        raise ValueError(f"unknown algo {spec.algo!r}; expected one of {ALGOS}")
    if spec.engine not in ENGINES:
        raise ValueError(
            f"unknown engine {spec.engine!r}; expected one of {ENGINES}"
        )
    kind, stat_mode = _ALGO_MODES[spec.algo]
    return SearchConfig(
        num_simulations=spec.num_simulations,
        # Sequential UCT is the W=1 special case by definition (eq. 2).
        wave_size=1 if spec.algo == "uct" else spec.wave_size,
        max_depth=spec.max_depth,
        max_sim_steps=spec.max_sim_steps,
        max_width=spec.max_width,
        gamma=spec.gamma,
        policy=PolicyConfig(
            kind=kind, beta=spec.beta, r_vl=spec.r_vl, n_vl=spec.n_vl
        ),
        stat_mode=stat_mode,
        expand_coin=spec.expand_coin,
        value_mix=spec.value_mix,
        deterministic_expansion=spec.deterministic_expansion,
    )


def build_searcher(
    env: Environment,
    spec: SearchSpec,
    *,
    evaluator: Optional[Evaluator] = None,
    constrain: Optional[Callable[[Pytree], Pytree]] = None,
    jit: bool = True,
):
    """Build the searcher described by ``spec`` for ``env``.

    Returns a jitted callable:

    * ``batch == 0`` — ``search(root_state, rng) -> SearchResult``;
    * ``batch  > 0`` — ``search(root_states, rngs) -> SearchResult`` with a
      leading ``[B]`` axis on every field (``root_states`` leaves lead with
      ``[B]``; ``rngs = jax.random.split(key, B)``).

    ``evaluator`` plugs the leaf evaluation (default: classic env rollouts,
    bit-identical to the direct engine calls — oracle-tested in
    ``tests/test_facade.py``).  ``constrain`` installs sharding constraints
    (:func:`repro.distributed.sharding.constrain_search_batch`) on the
    engines that shard their slot batch.
    """
    cfg = as_search_config(spec)
    if spec.batch < 0:
        raise ValueError(f"batch must be >= 0, got {spec.batch}")
    if isinstance(evaluator, ModelEvaluator) and (
        evaluator.top_k != env.num_actions
    ):
        # Actions are ranks into the evaluator's top-K table; a mismatched
        # table would silently alias several env actions onto one token.
        raise ValueError(
            f"ModelEvaluator(top_k={evaluator.top_k}) does not match "
            f"env.num_actions={env.num_actions}"
        )
    if isinstance(evaluator, CachedModelEvaluator) and spec.engine != "async":
        # The KV slot cache lives in the async engines' slot-aux state; the
        # wave engines evaluate whole rollouts per slot without it.
        raise ValueError(
            "CachedModelEvaluator requires engine='async' (the wave engines "
            "carry no slot cache; use ModelEvaluator)"
        )
    if spec.algo in ("leafp", "rootp"):
        if spec.engine == "async":
            raise ValueError(
                f"engine='async' supports wave-engine algos, not {spec.algo!r}"
            )
        if spec.batch > 0:
            raise ValueError(
                f"batch > 0 supports wave-engine algos, not {spec.algo!r} "
                "(rootp is itself a K-tree batched committee)"
            )

    if spec.batch > 0:
        run = (
            run_async_search_batched if spec.engine == "async"
            else run_search_batched
        )
        fn = functools.partial(
            run, env, cfg, constrain=constrain, use_kernel=spec.use_kernel,
            evaluator=evaluator,
        )
    elif spec.engine == "async":
        fn = functools.partial(
            run_async_search, env, cfg, evaluator=evaluator,
            use_kernel=spec.use_kernel,
        )
    elif spec.algo == "leafp":
        fn = functools.partial(
            run_leafp, env, cfg, evaluator=evaluator,
            use_kernel=spec.use_kernel,
        )
    elif spec.algo == "rootp":
        fn = functools.partial(
            run_rootp, env, cfg, use_kernel=spec.use_kernel, evaluator=evaluator
        )
    else:
        fn = functools.partial(
            run_search, env, cfg, constrain=constrain, evaluator=evaluator,
            use_kernel=spec.use_kernel,
        )
    return jax.jit(fn) if jit else fn


def make_config(algorithm: str, **kw) -> SearchConfig:
    """Legacy config builder, re-expressed over :class:`SearchSpec`.

    ``kw`` takes the flattened spec fields (``beta=…``, ``r_vl=…``, search
    budgets); explicit ``policy=`` / ``stat_mode=`` overrides are honored
    for back-compat with the old per-algo builders.
    """
    policy = kw.pop("policy", None)
    stat_mode = kw.pop("stat_mode", None)
    cfg = as_search_config(SearchSpec(algo=algorithm, **kw))
    if policy is not None:
        cfg = cfg._replace(policy=policy)
    if stat_mode is not None:
        cfg = cfg._replace(stat_mode=stat_mode)
    return cfg
