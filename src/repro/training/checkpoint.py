"""Fault-tolerant checkpointing: atomic, async, elastic-restorable.

Design (single-host implementation of the multi-host protocol):

* **Atomic**: state is written to ``<dir>/tmp.<step>`` and ``os.rename``-d to
  ``<dir>/step_<N>`` only after every leaf + manifest is on disk, so a crash
  mid-save can never corrupt the latest checkpoint.
* **Async**: ``save`` device_gets on the caller thread (cheap, just D2H) and
  hands serialization to a background thread so the train loop keeps stepping.
* **Elastic**: leaves are stored unsharded (gathered); ``restore`` re-
  device_puts them under *any* new mesh/sharding — restart on a different
  topology (e.g. after losing a pod) just works.  On real multi-host pods the
  same layout is written per-process for the process-local shards; the
  manifest carries the mesh so a resharding restore can reassemble.
* **Keep-k**: old checkpoints are garbage-collected after a successful save.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

Pytree = Any

_SEP = "/"


def _flatten(tree: Pytree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._lock = threading.Lock()
        self._pending: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(
        self,
        step: int,
        state: Pytree,
        extra: Optional[dict] = None,
        blocking: bool = False,
    ) -> None:
        # D2H on the caller thread (the arrays may be donated/overwritten by
        # the next step otherwise); serialization happens in the background.
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)

        def _write():
            tmp = os.path.join(self.directory, f"tmp.{step}")
            final = os.path.join(self.directory, f"step_{step:08d}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            flat = _flatten(host_state)
            np.savez(os.path.join(tmp, "arrays.npz"), **flat)
            manifest = {
                "step": step,
                "keys": sorted(flat.keys()),
                "shapes": {k: list(v.shape) for k, v in flat.items()},
                "dtypes": {k: str(v.dtype) for k, v in flat.items()},
                "extra": extra or {},
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)       # atomic publish
            self._gc()

        self.wait()
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        self._pending = t
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self) -> None:
        with self._lock:
            steps = self.all_steps()
            for s in steps[: -self.keep] if self.keep > 0 else []:
                shutil.rmtree(
                    os.path.join(self.directory, f"step_{s:08d}"),
                    ignore_errors=True,
                )

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(
                os.path.join(self.directory, name, "manifest.json")
            ):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        like: Pytree,
        step: Optional[int] = None,
        shardings: Optional[Pytree] = None,
    ) -> tuple[int, Pytree]:
        """Restore into the structure of ``like``; optionally re-shard.

        ``shardings`` may target a *different* mesh than the one saved from —
        the elastic-restart path.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step:08d}")
        arrays = np.load(os.path.join(path, "arrays.npz"))

        flat_like = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        flat_shardings = (
            jax.tree.leaves(
                shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)
            )
            if shardings is not None
            else [None] * len(flat_like[0])
        )
        for (pth, leaf), shd in zip(flat_like[0], flat_shardings):
            key = _SEP.join(
                str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                for p in pth
            )
            arr = arrays[key]
            assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
            arr = arr.astype(leaf.dtype)
            leaves.append(
                jax.device_put(arr, shd) if shd is not None else jax.numpy.asarray(arr)
            )
        return step, jax.tree_util.tree_unflatten(flat_like[1], leaves)
