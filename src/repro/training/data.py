"""Data pipeline: deterministic synthetic stream + packed memmap shards.

Both sources are (a) deterministic given (seed, step) — so a restarted job
resumes mid-epoch without replaying or skipping data, the checkpoint only
needs the step counter; and (b) sharded by (dp_rank, dp_world) so every data-
parallel worker reads a disjoint slice.  Double-buffered host→device prefetch
overlaps input with compute.
"""

from __future__ import annotations

import json
import os
import threading
import queue
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


class SyntheticStream:
    """Deterministic pseudo-text: Zipfian tokens from a counter-based PRNG."""

    def __init__(
        self,
        vocab_size: int,
        batch_size: int,
        seq_len: int,
        seed: int = 0,
        dp_rank: int = 0,
        dp_world: int = 1,
    ):
        assert batch_size % dp_world == 0
        self.vocab_size = vocab_size
        self.local_batch = batch_size // dp_world
        self.seq_len = seq_len
        self.seed = seed
        self.dp_rank = dp_rank
        self.dp_world = dp_world
        # Zipf-ish distribution over the vocab (heavier head like real text).
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        p = 1.0 / ranks
        self._probs = (p / p.sum()).astype(np.float64)

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + self.dp_rank
        )
        tokens = rng.choice(
            self.vocab_size,
            size=(self.local_batch, self.seq_len),
            p=self._probs,
        ).astype(np.int32)
        return {"tokens": tokens}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def write_token_shards(
    path: str, num_shards: int, tokens_per_shard: int, vocab_size: int, seed: int = 0
) -> None:
    """Materialize packed token shards (one flat .npy per shard + manifest)."""
    os.makedirs(path, exist_ok=True)
    for i in range(num_shards):
        rng = np.random.default_rng(seed * 7919 + i)
        arr = rng.integers(0, vocab_size, size=(tokens_per_shard,), dtype=np.int32)
        np.save(os.path.join(path, f"shard_{i:05d}.npy"), arr)
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(
            {
                "num_shards": num_shards,
                "tokens_per_shard": tokens_per_shard,
                "vocab_size": vocab_size,
            },
            f,
        )


class PackedShards:
    """Memmap-backed packed-sequence reader with deterministic addressing.

    ``batch_at(step)`` computes shard/offset from (step, rank) arithmetic —
    no iterator state to checkpoint, and restart-safe by construction.
    """

    def __init__(
        self,
        path: str,
        batch_size: int,
        seq_len: int,
        dp_rank: int = 0,
        dp_world: int = 1,
    ):
        with open(os.path.join(path, "manifest.json")) as f:
            self.manifest = json.load(f)
        assert batch_size % dp_world == 0
        self.path = path
        self.local_batch = batch_size // dp_world
        self.global_batch = batch_size
        self.seq_len = seq_len
        self.dp_rank = dp_rank
        self.dp_world = dp_world
        self._mmaps = [
            np.load(
                os.path.join(path, f"shard_{i:05d}.npy"), mmap_mode="r"
            )
            for i in range(self.manifest["num_shards"])
        ]
        self.windows_per_shard = self.manifest["tokens_per_shard"] // seq_len
        self.total_windows = self.windows_per_shard * self.manifest["num_shards"]

    def batch_at(self, step: int) -> dict:
        out = np.empty((self.local_batch, self.seq_len), np.int32)
        base = step * self.global_batch + self.dp_rank * self.local_batch
        for j in range(self.local_batch):
            w = (base + j) % self.total_windows
            shard, idx = divmod(w, self.windows_per_shard)
            off = idx * self.seq_len
            out[j] = self._mmaps[shard][off : off + self.seq_len]
        return {"tokens": out}


class Prefetcher:
    """Double-buffered host→device prefetch (overlaps input with compute)."""

    def __init__(self, source, start_step: int = 0, depth: int = 2, sharding=None):
        self.source = source
        self.sharding = sharding
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            if self.sharding is not None:
                batch = jax.device_put(batch, self.sharding)
            else:
                batch = jax.tree.map(jnp.asarray, batch)
            self._q.put((step, batch))
            step += 1

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
