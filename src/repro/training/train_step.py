"""Jittable train step: microbatched grad accumulation + AdamW + metrics.

The returned function is pure and donation-friendly:
``train_step(params, opt_state, batch) -> (params, opt_state, metrics)``.
Gradient accumulation runs as a ``lax.scan`` over microbatches, so memory is
bounded by one microbatch's activations (with per-layer remat inside the
model).  Optional error-feedback int8 gradient compression emulates the
bandwidth-saving all-reduce (distributed/compress.py).
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..models import loss_fn
from ..models.config import ModelConfig
from .optimizer import AdamWConfig, adamw_update

Pytree = Any


class TrainConfig(NamedTuple):
    optimizer: AdamWConfig = AdamWConfig()
    microbatches: int = 1
    compress_grads: bool = False   # int8 error-feedback all-reduce emulation


def make_train_step(
    model_cfg: ModelConfig,
    train_cfg: TrainConfig = TrainConfig(),
):
    opt_cfg = train_cfg.optimizer
    mb = train_cfg.microbatches

    def grad_fn(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, model_cfg, batch), has_aux=True
        )(params)
        return loss, metrics, grads

    def train_step(params, opt_state, batch):
        if mb == 1:
            loss, metrics, grads = grad_fn(params, batch)
        else:
            def split_mb(x):
                b = x.shape[0]
                assert b % mb == 0, (b, mb)
                return x.reshape(mb, b // mb, *x.shape[1:])

            micro = jax.tree.map(split_mb, batch)

            def body(acc, mbatch):
                loss_acc, grads_acc = acc
                loss, _, grads = grad_fn(params, mbatch)
                grads_acc = jax.tree.map(jnp.add, grads_acc, grads)
                return (loss_acc + loss, grads_acc), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.float32(0.0), zeros), micro
            )
            loss = loss / mb
            grads = jax.tree.map(lambda g: g / mb, grads)
            metrics = {}

        if train_cfg.compress_grads:
            from ..distributed.compress import compress_decompress

            grads = compress_decompress(grads)

        params, opt_state, opt_metrics = adamw_update(
            grads, opt_state, params, opt_cfg
        )
        out_metrics = {"loss": loss, **opt_metrics}
        return params, opt_state, out_metrics

    return train_step
