from .optimizer import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from .train_step import TrainConfig, make_train_step
from .data import SyntheticStream, PackedShards, write_token_shards
from .checkpoint import CheckpointManager

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "cosine_schedule",
    "TrainConfig",
    "make_train_step",
    "SyntheticStream",
    "PackedShards",
    "write_token_shards",
    "CheckpointManager",
]
