"""AdamW with fp32 master weights (ZeRO-shardable state) + LR schedules.

The optimizer state (m, v, master) is a plain pytree mirroring the params,
so ``distributed.sharding.opt_state_specs`` can shard it over *both* mesh
axes (ZeRO-style): params are TP-sharded over ``model`` and replicated over
``data`` for compute, while the fp32 state is additionally partitioned over
``data`` — cutting optimizer memory by the DP degree.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Pytree = Any


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: jax.Array
    m: Pytree
    v: Pytree
    master: Pytree   # fp32 master copy of the (possibly bf16) params


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    scale = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * scale


def adamw_init(params: Pytree) -> AdamWState:
    f32 = lambda x: jnp.zeros(x.shape, jnp.float32)
    return AdamWState(
        step=jnp.int32(0),
        m=jax.tree.map(f32, params),
        v=jax.tree.map(f32, params),
        # jnp.array(copy=True): for fp32 params, .astype would alias the
        # param buffer — fatal when both params and state are donated.
        master=jax.tree.map(
            lambda x: jnp.array(x, dtype=jnp.float32, copy=True), params
        ),
    )


def global_norm(tree: Pytree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def adamw_update(
    grads: Pytree,
    state: AdamWState,
    params: Pytree,
    cfg: AdamWConfig,
) -> tuple[Pytree, AdamWState, dict]:
    """One optimizer step; returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = cosine_schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, master, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        new_master = master - lr * (
            mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * master
        )
        return m, v, new_master, new_master.astype(p.dtype)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    flat_w = jax.tree.leaves(state.master)
    flat_p = jax.tree.leaves(params)
    out = [upd(*args) for args in zip(flat_g, flat_m, flat_v, flat_w, flat_p)]
    new_m = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    new_w = treedef.unflatten([o[2] for o in out])
    new_p = treedef.unflatten([o[3] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step=step, m=new_m, v=new_v, master=new_w), metrics
