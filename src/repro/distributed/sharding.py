"""Sharding rules: logical axes → mesh axes for params, state and batches.

Mesh axes (launch/mesh.py): ``('data', 'model')`` single-pod and
``('pod', 'data', 'model')`` multi-pod.  ``pod`` behaves as an outer data
axis for training (and as the wave/root-parallel axis for search).

Rules of thumb implemented here:

* vocab/d_ff/expert/head dims → ``model`` (TP / EP) when divisible, else
  replicate (the divisibility fallback matters for phi3/qwen2.5's 40 heads
  and whisper's 12 — see EXPERIMENTS.md §Perf for the padding hillclimb);
* batch → ``(pod, data)``;
* AdamW fp32 state (m, v, master) is additionally sharded over ``data`` on
  its largest divisible axis — ZeRO-style: DP replicas each own a slice of
  optimizer memory;
* MCTS tree statistics are replicated; wave slots shard over ``(pod, data)``;
* batched multi-root search (core/batched_search.py) shards its leading
  tree-batch axis ``B`` over ``(pod, data)`` — each DP replica owns a slice
  of the forest and its wave slots (see :func:`constrain_search_batch`);
* the batched *async* engine (core/batched_async_search.py) additionally
  flattens its slot ticks to one ``[B·W]`` rollout batch; the same
  :func:`constrain_search_batch` hook shards that axis (and the future
  policy/value model forward pass riding it) over ``(pod, data)``.
"""

from __future__ import annotations

import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig

Pytree = Any


def _mesh_axis_sizes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape)) if isinstance(
        mesh, Mesh
    ) else dict(mesh.shape)


def data_axes(mesh) -> tuple[str, ...]:
    names = mesh.axis_names if hasattr(mesh, "axis_names") else tuple(mesh.shape)
    return tuple(a for a in ("pod", "data") if a in names)


def logical_spec(mesh, *axes) -> P:
    """PartitionSpec with axes not present in the mesh dropped."""
    names = set(mesh.axis_names)

    def keep(a):
        if a is None:
            return None
        if isinstance(a, tuple):
            kept = tuple(x for x in a if x in names)
            return kept if kept else None
        return a if a in names else None

    return P(*(keep(a) for a in axes))


def ambient_abstract_mesh():
    """The ambient abstract mesh, or ``None`` on JAX versions without the
    ``get_abstract_mesh`` API (pre-0.5) — constraints degrade to no-ops."""
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    return get() if get is not None else None


def abstract_mesh(axis_sizes, axis_names):
    """Version-portable ``jax.sharding.AbstractMesh`` constructor.

    Newer JAX takes ``(axis_sizes, axis_names, axis_types=...)``; pre-0.5
    releases (no ``AxisType``) take a single ``((name, size), ...)`` tuple.
    Spec logic downstream only reads ``.shape`` / ``.axis_names``, which both
    forms provide.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.sharding.AbstractMesh(tuple(zip(axis_names, axis_sizes)))
    return jax.sharding.AbstractMesh(
        tuple(axis_sizes), tuple(axis_names),
        axis_types=(axis_type.Auto,) * len(axis_names),
    )


def use_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    ``jax.set_mesh`` where it exists; on older JAX the ``Mesh`` object itself
    is the context manager.
    """
    set_mesh = getattr(jax, "set_mesh", None)
    return set_mesh(mesh) if set_mesh is not None else mesh


def constrain(x: jax.Array, *axes) -> jax.Array:
    """with_sharding_constraint against the ambient abstract mesh (no-op
    outside a mesh context, so model code stays mesh-agnostic)."""
    mesh = ambient_abstract_mesh()
    if mesh is None or not getattr(mesh, "axis_names", ()):  # unset mesh
        return x
    spec = logical_spec(mesh, *axes)
    # Drop axes that don't divide the corresponding dim.
    sizes = _mesh_axis_sizes(mesh)
    fixed = []
    for dim, a in zip(x.shape, spec):
        if a is None:
            fixed.append(None)
            continue
        parts = 1
        for name in (a if isinstance(a, tuple) else (a,)):
            parts *= sizes[name]
        fixed.append(a if dim % parts == 0 else None)
    return jax.lax.with_sharding_constraint(x, P(*fixed))


def constrain_search_batch(pytree: Pytree) -> Pytree:
    """Shard the leading tree-batch axis of every leaf over ``(pod, data)``.

    This is the ``constrain`` hook for both batched search engines
    (:func:`repro.core.batched_search.run_search_batched` and
    :func:`repro.core.batched_async_search.run_async_search_batched`): slot
    tables and per-node state buffers all lead with the ``B`` axis — and the
    async engine's flattened ``[B·W]`` slot-tick batch leads with ``B·W`` —
    so one constraint rule covers the whole pytree.  A no-op outside a mesh
    context, and for leaves whose leading dim does not divide the data axes.
    """

    def one(x):
        if not hasattr(x, "ndim") or x.ndim == 0:
            return x
        return constrain(x, ("pod", "data"), *([None] * (x.ndim - 1)))

    return jax.tree.map(one, pytree)


# ---------------------------------------------------------------------------
# Parameter rules
# ---------------------------------------------------------------------------


def _tp_ok(dim: int, mesh, axis: str = "model") -> bool:
    sizes = _mesh_axis_sizes(mesh)
    return axis in sizes and dim % sizes[axis] == 0


def _param_rule(cfg: ModelConfig, path: str, shape: tuple, mesh) -> P:
    tp = "model"
    hd = cfg.head_dim

    def heads_shardable(n_heads):
        return _tp_ok(n_heads, mesh)

    # --- embeddings / head ---
    if path.endswith("embed"):
        return logical_spec(mesh, tp, None) if _tp_ok(shape[0], mesh) else P()
    if path.endswith("lm_head"):
        return logical_spec(mesh, None, tp) if _tp_ok(shape[1], mesh) else P()

    # --- attention ---
    if re.search(r"(attn|cross)/w[qkvo]$", path) or re.search(r"(attn|cross)/b[qkv]$", path):
        n_heads = cfg.num_heads if re.search(r"w[qo]|bq", path) else cfg.num_kv_heads
        if not heads_shardable(n_heads):
            return P()  # replicate: attention falls back to pure DP
        if path.endswith("wo"):
            return logical_spec(mesh, tp, None)
        if re.search(r"b[qkv]$", path):
            return logical_spec(mesh, tp)
        return logical_spec(mesh, None, tp)

    # --- dense MLP / shared expert ---
    if re.search(r"(mlp|shared)/w_(gate|up)$", path):
        return logical_spec(mesh, None, tp) if _tp_ok(shape[-1], mesh) else P()
    if re.search(r"(mlp|shared)/w_down$", path):
        return logical_spec(mesh, tp, None) if _tp_ok(shape[-2], mesh) else P()

    # --- MoE routed experts: EP over the expert dim ---
    if re.search(r"moe/w_(gate|up|down)$", path):
        return (
            logical_spec(mesh, tp, None, None)
            if _tp_ok(shape[-3], mesh)
            else P()
        )
    if path.endswith("router"):
        return P()

    # --- Mamba-2 ---
    if re.search(r"ssm/in_[xz]$", path):
        return logical_spec(mesh, None, tp) if _tp_ok(shape[-1], mesh) else P()
    if re.search(r"ssm/in_dt$", path):
        return logical_spec(mesh, None, tp) if _tp_ok(shape[-1], mesh) else P()
    if re.search(r"ssm/conv_x$", path):
        return logical_spec(mesh, None, tp) if _tp_ok(shape[-1], mesh) else P()
    if re.search(r"ssm/(A_log|dt_bias|D|norm)$", path):
        return logical_spec(mesh, tp) if _tp_ok(shape[-1], mesh) else P()
    if re.search(r"ssm/out$", path):
        return logical_spec(mesh, tp, None) if _tp_ok(shape[-2], mesh) else P()
    # in_B / in_C / conv_B / conv_C / norms / everything else: replicate.
    return P()


def _paths_and_leaves(tree: Pytree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        yield key, leaf
    return


def _fsdp_rule(shape: tuple, mesh, axes: tuple[str, ...]) -> P:
    """ZeRO-3/FSDP: shard the largest divisible dim over all given axes.

    Compute-time behavior under GSPMD: weights are all-gathered per layer
    (cheap — parameter bytes) instead of activations being all-reduced
    (expensive at large batch·seq) — the classic TP→FSDP trade for models
    that fit one chip's memory after sharding.
    """
    sizes = _mesh_axis_sizes(mesh)
    total = 1
    for a in axes:
        total *= sizes.get(a, 1)
    best, best_dim = None, 0
    for i, dim in enumerate(shape):
        if dim % total == 0 and dim > best_dim:
            best, best_dim = i, dim
    if best is None:
        return P()
    entries = [None] * len(shape)
    entries[best] = axes if len(axes) > 1 else axes[0]
    return P(*entries)


def param_partition_specs(
    cfg: ModelConfig, abstract_params: Pytree, mesh, strategy: str = "tp"
) -> Pytree:
    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_params)
    all_axes = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
    specs = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        shape = leaf.shape
        stacked = key.startswith(("blocks/", "encoder/blocks/"))
        tail = shape[1:] if stacked else shape
        if strategy == "fsdp":
            spec = _fsdp_rule(tail, mesh, all_axes)
        else:
            spec = _param_rule(cfg, key, tail, mesh)
        specs.append(P(None, *spec) if stacked else spec)
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_shardings(
    cfg: ModelConfig, abstract_params: Pytree, mesh: Mesh, strategy: str = "tp"
) -> Pytree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        param_partition_specs(cfg, abstract_params, mesh, strategy),
        is_leaf=lambda x: isinstance(x, P),
    )


def _zero_shard(spec: P, shape: tuple, mesh) -> P:
    """Extend a TP spec with ZeRO sharding over the data axes: partition the
    largest still-unsharded, divisible dim over ('pod','data')."""
    dp = data_axes(mesh)
    if not dp:
        return spec
    used = set()
    for a in spec:
        for name in (a if isinstance(a, tuple) else (a,)):
            used.add(name)
    if used & set(dp):  # already data-sharded (fsdp strategy)
        return spec
    sizes = _mesh_axis_sizes(mesh)
    dp_total = 1
    for a in dp:
        dp_total *= sizes[a]
    entries = list(spec) + [None] * (len(shape) - len(spec))
    best, best_dim = None, 0
    for i, (dim, a) in enumerate(zip(shape, entries)):
        if a is None and dim % dp_total == 0 and dim > best_dim:
            best, best_dim = i, dim
    if best is None:
        return spec
    entries[best] = dp if len(dp) > 1 else dp[0]
    return P(*entries)


def opt_state_shardings(
    cfg: ModelConfig,
    abstract_params: Pytree,
    mesh: Mesh,
    abstract_opt: Pytree,
    strategy: str = "tp",
) -> Pytree:
    """AdamW state: param spec + ZeRO partition over data axes."""
    pspecs = param_partition_specs(cfg, abstract_params, mesh, strategy)

    def for_moment(spec_tree, leaf_tree):
        return jax.tree.map(
            lambda s, l: NamedSharding(mesh, _zero_shard(s, l.shape, mesh)),
            spec_tree,
            leaf_tree,
            is_leaf=lambda x: isinstance(x, P),
        )

    from ..training.optimizer import AdamWState

    return AdamWState(
        step=NamedSharding(mesh, P()),
        m=for_moment(pspecs, abstract_opt.m),
        v=for_moment(pspecs, abstract_opt.v),
        master=for_moment(pspecs, abstract_opt.master),
    )


def batch_spec(mesh, strategy: str = "tp", global_batch: int | None = None) -> P:
    if strategy == "fsdp":
        # Batch shards over ALL axes when divisible (single-pod: 256 = 16·16).
        axes = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
        sizes = _mesh_axis_sizes(mesh)
        total = 1
        for a in axes:
            total *= sizes[a]
        if global_batch is None or global_batch % total == 0:
            return P(axes)
    dp = data_axes(mesh)
    return P(dp if len(dp) > 1 else (dp[0] if dp else None))


def batch_shardings(mesh: Mesh, batch_abstract: Pytree) -> Pytree:
    spec = batch_spec(mesh)
    return jax.tree.map(lambda _: NamedSharding(mesh, spec), batch_abstract)
