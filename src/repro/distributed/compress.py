"""Gradient compression: int8 quantized all-reduce with error feedback.

On a real pod the all-reduce would run over the int8 payload (8.0x wire
saving vs f32 / 2.0x vs bf16); under GSPMD we emulate the numerics — quantize
→ (all-reduce happens on the quantized values via the surrounding psum) →
dequantize — and carry the quantization residual as *error feedback* so the
bias vanishes over steps (Karimireddy et al., 2019).
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

Pytree = Any


def _quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_decompress(grads: Pytree) -> Pytree:
    """Stateless quantize→dequantize round trip (wire-format emulation)."""

    def one(g):
        q, s = _quantize(g.astype(jnp.float32))
        return _dequantize(q, s).astype(g.dtype)

    return jax.tree.map(one, grads)


def compress_with_feedback(
    grads: Pytree, error: Optional[Pytree]
) -> tuple[Pytree, Pytree]:
    """Error-feedback compression: returns (compressed grads, new residual)."""
    if error is None:
        error = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = _quantize(corrected)
        deq = _dequantize(q, s)
        return deq.astype(g.dtype), corrected - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        treedef.unflatten([o[0] for o in out]),
        treedef.unflatten([o[1] for o in out]),
    )
