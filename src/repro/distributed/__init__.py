from .sharding import (
    abstract_mesh,
    batch_spec,
    constrain,
    constrain_search_batch,
    data_axes,
    logical_spec,
    opt_state_shardings,
    param_shardings,
    use_mesh,
)

__all__ = [
    "abstract_mesh",
    "batch_spec",
    "constrain",
    "constrain_search_batch",
    "data_axes",
    "logical_spec",
    "opt_state_shardings",
    "param_shardings",
    "use_mesh",
]
