from .sharding import (
    batch_spec,
    constrain,
    constrain_search_batch,
    data_axes,
    logical_spec,
    opt_state_shardings,
    param_shardings,
)

__all__ = [
    "batch_spec",
    "constrain",
    "constrain_search_batch",
    "data_axes",
    "logical_spec",
    "opt_state_shardings",
    "param_shardings",
]
