"""``reprolint`` CLI: ``python -m repro.analysis.lint src tests``.

Exit codes: 0 clean (or every finding matches the committed baseline),
1 on any diff vs the baseline (new findings OR stale baseline entries),
2 on usage errors.  ``--json`` emits machine-readable findings;
``--rules`` prints the catalog with the historical regression each rule
encodes.  The default baseline is ``reprolint_baseline.json`` in the
current directory when it exists (CI runs from the repo root).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

from .engine import Baseline, diff_baseline, lint_paths, rule_catalog

DEFAULT_BASELINE = "reprolint_baseline.json"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-lint",
        description="JAX-aware static analysis for this repo's historical "
        "bug classes (JX001..JX005)",
    )
    ap.add_argument("paths", nargs="*", default=["src", "tests"],
                    help="files/directories to scan (default: src tests)")
    ap.add_argument("--baseline", default=None,
                    help=f"baseline JSON (default: {DEFAULT_BASELINE} "
                    "if present)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report every finding, ignore any baseline")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit findings as JSON")
    ap.add_argument("--rules", action="store_true",
                    help="print the rule catalog and exit")
    args = ap.parse_args(argv)

    if args.rules:
        for rid, title, regression in rule_catalog():
            print(f"{rid}  {title}\n       encodes: {regression}")
        return 0

    paths = args.paths or ["src", "tests"]
    for p in paths:
        if not os.path.exists(p):
            print(f"repro-lint: path not found: {p}", file=sys.stderr)
            return 2
    findings = lint_paths(paths)

    baseline = Baseline()
    baseline_path = args.baseline
    if not args.no_baseline:
        if baseline_path is None and os.path.isfile(DEFAULT_BASELINE):
            baseline_path = DEFAULT_BASELINE
        if baseline_path is not None:
            try:
                baseline = Baseline.load(baseline_path)
            except (OSError, json.JSONDecodeError, ValueError) as e:
                print(f"repro-lint: bad baseline {baseline_path}: {e}",
                      file=sys.stderr)
                return 2
    new, stale = diff_baseline(findings, baseline)

    if args.as_json:
        print(json.dumps({
            "findings": [f.to_json() for f in findings],
            "new": [f.to_json() for f in new],
            "stale_baseline": stale,
        }, indent=2))
    else:
        for f in new:
            print(f.format())
        for e in stale:
            print(
                f"STALE BASELINE: {e['rule']} @ {e['path']} no longer "
                f"fires ({e['message'][:60]}...) — remove the entry",
                file=sys.stderr,
            )
        grandfathered = len(findings) - len(new)
        summary = (
            f"repro-lint: {len(findings)} finding(s), {len(new)} new, "
            f"{grandfathered} baselined, {len(stale)} stale baseline "
            f"entr(y/ies) over {len(paths)} path(s)"
        )
        print(summary, file=sys.stderr)
    return 1 if (new or stale) else 0


if __name__ == "__main__":
    sys.exit(main())
