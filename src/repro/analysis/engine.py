"""``reprolint`` engine: rule registry, suppressions, baseline, file runner.

The engine is deliberately small and stdlib-only.  Rules live in
:mod:`repro.analysis.rules`; each one is an :class:`Rule` subclass
registered with :func:`register`.  Two rule shapes exist:

* **module rules** implement :meth:`Rule.check_module` and are run once per
  scanned ``.py`` file with the parsed AST;
* **project rules** implement :meth:`Rule.check_project` and are run once
  over the whole scanned file set (e.g. the kernel ref-oracle contract,
  which relates ``src/repro/kernels/<name>/`` packages to ``tests/``).

Findings can be silenced two ways, both intentionally noisy in review:

* an inline ``# reprolint: disable=JX002`` comment on the finding's line
  (or on a comment-only line directly above it) — for deliberate patterns,
  next to a justification;
* a committed **baseline** file (``reprolint_baseline.json``) holding
  grandfathered findings, each with a ``justification`` string.  The CLI
  fails on any *diff* against the baseline: new findings must be fixed or
  baselined, and stale entries (the finding no longer fires) must be
  removed so the baseline only ever shrinks deliberately.
"""

from __future__ import annotations

import ast
import dataclasses
import json
import os
import re
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

#: Inline suppression directive: ``# reprolint: disable=JX001,JX004``.
_SUPPRESS_RE = re.compile(r"#\s*reprolint:\s*disable=([A-Z0-9_,\s]+)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    rule: str
    path: str
    line: int
    col: int
    message: str

    @property
    def key(self) -> Tuple[str, str, str]:
        """Baseline identity: line numbers drift, (rule, path, message)
        is stable across unrelated edits to the same file."""
        return (self.rule, self.path, self.message)

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


class Rule:
    """Base class for lint rules.  Subclasses set ``id``/``title``/``regression``
    and implement one of the ``check_*`` hooks."""

    id: str = "JX000"
    title: str = ""
    #: The historical regression this rule encodes (shown by ``--rules``).
    regression: str = ""

    def check_module(
        self, tree: ast.Module, src: str, path: str
    ) -> Iterable[Finding]:
        return ()

    def check_project(
        self, files: Dict[str, str], trees: Dict[str, ast.Module]
    ) -> Iterable[Finding]:
        return ()


_REGISTRY: List[Rule] = []


def register(cls):
    """Class decorator adding a rule to the global registry."""
    _REGISTRY.append(cls())
    return cls


def all_rules() -> List[Rule]:
    from . import rules as _rules  # noqa: F401  (registers on import)

    return list(_REGISTRY)


def rule_catalog() -> List[Tuple[str, str, str]]:
    """(id, title, regression) rows, for ``--rules`` and the README table."""
    return [(r.id, r.title, r.regression) for r in all_rules()]


# ---------------------------------------------------------------------------
# Suppressions
# ---------------------------------------------------------------------------
def suppressed_lines(src: str) -> Dict[int, set]:
    """Map line number -> set of rule ids suppressed on that line.

    A directive on a comment-only line also covers the next line, so a
    justification comment can sit above the flagged statement::

        # Deliberate: one row per call keeps the jitted evict at one shape.
        # reprolint: disable=JX002
        self._carry = self._evict_fn(self._carry, row)
    """
    out: Dict[int, set] = {}
    for i, text in enumerate(src.splitlines(), start=1):
        m = _SUPPRESS_RE.search(text)
        if not m:
            continue
        ids = {tok.strip() for tok in m.group(1).split(",") if tok.strip()}
        out.setdefault(i, set()).update(ids)
        if text.lstrip().startswith("#"):
            out.setdefault(i + 1, set()).update(ids)
    return out


def _apply_suppressions(
    findings: Iterable[Finding], src: str
) -> List[Finding]:
    sup = suppressed_lines(src)
    return [f for f in findings if f.rule not in sup.get(f.line, ())]


# ---------------------------------------------------------------------------
# Runners
# ---------------------------------------------------------------------------
def lint_source(
    src: str, path: str = "<string>", rules: Optional[Sequence[Rule]] = None
) -> List[Finding]:
    """Run the module rules over one source string (the test fixture entry
    point — ``path`` feeds the rules' path-scoped heuristics)."""
    try:
        tree = ast.parse(src)
    except SyntaxError as e:
        return [
            Finding("JX000", path, e.lineno or 0, e.offset or 0,
                    f"syntax error: {e.msg}")
        ]
    findings: List[Finding] = []
    for rule in rules if rules is not None else all_rules():
        findings.extend(rule.check_module(tree, src, path))
    findings = _apply_suppressions(findings, src)
    return sorted(findings, key=lambda f: (f.path, f.line, f.col, f.rule))


def collect_files(paths: Sequence[str], root: str = ".") -> Dict[str, str]:
    """Gather ``.py`` sources under ``paths`` as {root-relative path: text}."""
    files: Dict[str, str] = {}
    for p in paths:
        full = os.path.join(root, p)
        if os.path.isfile(full):
            cands = [full]
        else:
            cands = [
                os.path.join(dirpath, name)
                for dirpath, dirnames, names in os.walk(full)
                for name in sorted(names)
                if name.endswith(".py")
                and "__pycache__" not in dirpath.split(os.sep)
            ]
        for c in sorted(cands):
            rel = os.path.relpath(c, root).replace(os.sep, "/")
            with open(c, encoding="utf-8") as f:
                files[rel] = f.read()
    return files


def lint_paths(
    paths: Sequence[str],
    root: str = ".",
    rules: Optional[Sequence[Rule]] = None,
) -> List[Finding]:
    """Run all rules (module + project) over the scanned paths."""
    active = list(rules) if rules is not None else all_rules()
    files = collect_files(paths, root)
    trees: Dict[str, ast.Module] = {}
    findings: List[Finding] = []
    for path, src in files.items():
        mod_findings = lint_source(src, path, rules=active)
        findings.extend(mod_findings)
        try:
            trees[path] = ast.parse(src)
        except SyntaxError:
            pass  # already reported as JX000 by lint_source
    for rule in active:
        findings.extend(rule.check_project(files, trees))
    return sorted(findings, key=lambda f: (f.path, f.line, f.col, f.rule))


# ---------------------------------------------------------------------------
# Baseline
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Baseline:
    """Committed grandfathered findings, each carrying a justification."""

    entries: List[dict] = dataclasses.field(default_factory=list)

    @classmethod
    def load(cls, path: str) -> "Baseline":
        with open(path, encoding="utf-8") as f:
            data = json.load(f)
        entries = data.get("findings", [])
        for e in entries:
            missing = {"rule", "path", "message", "justification"} - set(e)
            if missing:
                raise ValueError(
                    f"baseline entry {e!r} is missing {sorted(missing)} — "
                    "every grandfathered finding must say why it is allowed"
                )
        return cls(entries)

    def keys(self) -> List[Tuple[str, str, str]]:
        return [(e["rule"], e["path"], e["message"]) for e in self.entries]


def diff_baseline(
    findings: Sequence[Finding], baseline: Baseline
) -> Tuple[List[Finding], List[dict]]:
    """Multiset diff of fresh findings vs the baseline.

    Returns ``(new, stale)``: findings not covered by a baseline entry, and
    baseline entries whose finding no longer fires (remove them — a baseline
    only shrinks deliberately, so fixed findings cannot silently return).
    """
    remaining = list(baseline.entries)
    new: List[Finding] = []
    for f in findings:
        for i, e in enumerate(remaining):
            if (e["rule"], e["path"], e["message"]) == f.key:
                del remaining[i]
                break
        else:
            new.append(f)
    return new, remaining
