"""Correctness tooling for the repo's *performance-correctness* bug classes.

Two layers (see README "Static analysis & sanitizers"):

* :mod:`repro.analysis.lint` — ``reprolint``, an AST static-analysis pass
  whose rule catalog (JX001..JX005, :mod:`repro.analysis.rules`) mechanizes
  the regressions that have already bitten this repo: per-shape retraces of
  jitted entry points, host syncs / per-iteration dispatch in engine tick
  paths, RNG key reuse, swallowed exceptions and silent clipping, and the
  kernel ref-oracle contract.  Run ``python -m repro.analysis.lint src
  tests`` (or the ``repro-lint`` console script).
* :mod:`repro.analysis.retrace_guard` — a runtime sanitizer: a context
  manager that counts jit cache misses per wrapped function, so tests can
  pin ``traces == 1`` on hot paths (the serving admit/evict/segment graphs)
  instead of discovering a 30x recompile regression in a benchmark.

This package deliberately imports no JAX at lint time — the static pass is
pure stdlib (``ast``) and safe to run in a bare CI step.
"""

from .engine import (  # noqa: F401
    Baseline,
    Finding,
    Rule,
    collect_files,
    diff_baseline,
    lint_paths,
    lint_source,
    rule_catalog,
)
from .retrace_guard import (  # noqa: F401
    RetraceError,
    RetraceGuard,
    jit_cache_size,
    retrace_guard,
)

__all__ = [
    "Baseline",
    "Finding",
    "Rule",
    "RetraceError",
    "RetraceGuard",
    "collect_files",
    "diff_baseline",
    "jit_cache_size",
    "lint_paths",
    "lint_source",
    "retrace_guard",
    "rule_catalog",
]
