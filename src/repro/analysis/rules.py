"""The ``reprolint`` rule catalog: JX001..JX005.

Every rule mechanizes a bug class that has already cost this repo a
regression (see README "Static analysis & sanitizers" for the table):

* **JX001 retrace hazard** — Python-varying shapes (``len()``-derived
  sizes, comprehension/``list()``-built sequences) passed into jitted
  entry points.  PR 8's variable-shape batched admit recompiled the whole
  prefill graph per distinct row count: a 30x timed-drain regression that
  no functional test could see.
* **JX002 host sync / dispatch in hot loops** — ``.item()`` / ``float()``
  / ``np.*`` concretization inside traced scopes, and per-iteration
  ``jnp.*``/jitted-call dispatch inside Python loops of engine/serving
  tick paths.  PR 7's ungated per-slot paged bookkeeping cost 4x at
  d64_B4 before it was hoisted behind ``lax.cond``.
* **JX003 RNG discipline** — a ``jax.random`` sampler reusing a key that
  was not freshly derived (double consumption, loop-carried keys, a key
  used both as sampler input and as a ``split``/``fold_in`` parent).
  Correlated streams silently bias search statistics — the WU-UCT ``O_s``
  accounting assumes independent rollouts.
* **JX004 exception hygiene** — bare/over-broad ``except`` without
  re-raise and silent clipping of user-facing action values.  PR 8 swept
  these out of the serving layer (silent cache overflow, clipped invalid
  actions, a bare ``except`` around the baseline lookup); this rule keeps
  them out everywhere.
* **JX005 kernel contract** — every ``kernels/<name>/`` package ships a
  ``ref.py`` oracle and is named by a parity test under ``tests/``; the
  Pallas kernels are only trustworthy relative to their jnp references.
"""

from __future__ import annotations

import ast
import re
from typing import Dict, Iterable, List, Optional, Set, Tuple

from .engine import Finding, Rule, register

# ---------------------------------------------------------------------------
# Shared AST helpers
# ---------------------------------------------------------------------------
_JIT_NAMES = {"jax.jit", "jit", "jax.pjit", "pjit"}
_PARTIAL_NAMES = {"partial", "functools.partial"}
#: Transforms whose function argument is traced (its body runs under trace).
_TRACING_CALLS = _JIT_NAMES | {
    "jax.lax.scan", "lax.scan",
    "jax.lax.while_loop", "lax.while_loop",
    "jax.lax.fori_loop", "lax.fori_loop",
    "jax.lax.cond", "lax.cond",
    "jax.lax.switch", "lax.switch",
    "jax.lax.map", "lax.map",
    "jax.lax.associative_scan", "lax.associative_scan",
    "jax.vmap", "vmap", "jax.pmap", "pmap",
    "jax.grad", "grad", "jax.value_and_grad", "value_and_grad",
    "jax.checkpoint", "jax.remat",
}


def _dotted(node: ast.AST) -> Optional[str]:
    """'jax.random.split' for nested Attribute/Name chains, else None."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else None
    return None


def _seg(src: str, node: ast.AST) -> str:
    return ast.get_source_segment(src, node) or ""


def _jit_wrapped_arg(call: ast.Call) -> Optional[ast.AST]:
    """For ``jax.jit(fn, ...)`` return ``fn``; else None."""
    if _dotted(call.func) in _JIT_NAMES and call.args:
        return call.args[0]
    return None


def _is_jit_decorator(dec: ast.AST) -> bool:
    if _dotted(dec) in _JIT_NAMES:
        return True
    if isinstance(dec, ast.Call):
        d = _dotted(dec.func)
        if d in _JIT_NAMES:
            return True
        if d in _PARTIAL_NAMES and dec.args:
            return _dotted(dec.args[0]) in _JIT_NAMES
    return False


class ModuleInfo:
    """One pre-pass shared by the rules: jitted entry points + traced defs."""

    def __init__(self, tree: ast.Module):
        self.jitted_names: Set[str] = set()
        self.traced_defs: List[ast.AST] = []
        defs_by_name: Dict[str, List[ast.AST]] = {}
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                defs_by_name.setdefault(node.name, []).append(node)
        traced_names: Set[str] = set()
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if any(_is_jit_decorator(d) for d in node.decorator_list):
                    self.jitted_names.add(node.name)
                    self.traced_defs.append(node)
            elif isinstance(node, (ast.Assign, ast.AnnAssign)):
                value = node.value
                if not isinstance(value, ast.Call):
                    continue
                wrapped = _jit_wrapped_arg(value)
                if wrapped is None:
                    continue
                targets = (
                    node.targets if isinstance(node, ast.Assign)
                    else [node.target]
                )
                for t in targets:
                    d = _dotted(t)
                    if d:
                        self.jitted_names.add(d)
                if isinstance(wrapped, ast.Lambda):
                    self.traced_defs.append(wrapped)
                elif isinstance(wrapped, ast.Name):
                    traced_names.add(wrapped.id)
            elif isinstance(node, ast.Call):
                if _dotted(node.func) in _TRACING_CALLS:
                    for arg in node.args:
                        if isinstance(arg, ast.Lambda):
                            self.traced_defs.append(arg)
                        elif isinstance(arg, ast.Name):
                            traced_names.add(arg.id)
        for name in traced_names:
            self.traced_defs.extend(defs_by_name.get(name, []))


def _walk_same_scope(node: ast.AST) -> Iterable[ast.AST]:
    """Walk a def body without descending into nested function scopes."""
    stack = list(ast.iter_child_nodes(node))
    while stack:
        n = stack.pop()
        yield n
        if not isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda)):
            stack.extend(ast.iter_child_nodes(n))


# ---------------------------------------------------------------------------
# JX001 — retrace hazard
# ---------------------------------------------------------------------------
@register
class RetraceHazard(Rule):
    id = "JX001"
    title = "Python-varying shape passed to a jitted entry point"
    regression = (
        "PR 8: variable-shape batched admit recompiled the prefill graph "
        "per distinct row count (30x timed-drain regression)"
    )

    def check_module(self, tree, src, path):
        info = ModuleInfo(tree)
        if not info.jitted_names:
            return
        for scope in [tree, *(
            n for n in ast.walk(tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
        )]:
            yield from self._check_scope(scope, info, src, path)

    def _check_scope(self, scope, info, src, path):
        varying: Set[str] = set()
        empty_lists: Set[str] = set()
        for node in _walk_same_scope(scope):
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                t = node.targets[0]
                if isinstance(t, ast.Name):
                    if self._varying_expr(node.value, varying):
                        varying.add(t.id)
                    elif (isinstance(node.value, ast.List)
                          and not node.value.elts):
                        empty_lists.add(t.id)
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in ("append", "extend")
                    and isinstance(node.func.value, ast.Name)
                    and node.func.value.id in empty_lists):
                varying.add(node.func.value.id)
        for node in _walk_same_scope(scope):
            if not isinstance(node, ast.Call):
                continue
            d = _dotted(node.func)
            if d not in info.jitted_names:
                continue
            args = list(node.args) + [kw.value for kw in node.keywords]
            for arg in args:
                if self._varying_expr(arg, varying, deep=True):
                    yield Finding(
                        self.id, path, arg.lineno, arg.col_offset,
                        f"jitted entry point '{d}' called with a "
                        f"Python-varying shape ({_seg(src, arg)[:60]!r}): "
                        "every distinct size retraces and recompiles the "
                        "graph — pass a fixed-shape array (pad) or mark "
                        "the argument static",
                    )
                    break

    @staticmethod
    def _varying_expr(expr: ast.AST, varying: Set[str],
                      deep: bool = False) -> bool:
        """Does ``expr`` produce / derive from a Python-varying size?"""
        def is_varying_node(n):
            if isinstance(n, (ast.ListComp, ast.SetComp, ast.GeneratorExp)):
                return True
            if isinstance(n, ast.Call):
                d = _dotted(n.func)
                if d in ("len", "list", "sorted"):
                    return True
            if isinstance(n, ast.Name) and n.id in varying:
                return True
            return False

        if not deep:
            return is_varying_node(expr)
        return any(is_varying_node(n) for n in ast.walk(expr))


# ---------------------------------------------------------------------------
# JX002 — host sync / per-iteration dispatch in hot paths
# ---------------------------------------------------------------------------
_HOT_NAME_RE = re.compile(
    r"(?:^|_)(tick|step|poll|segment|master|advance|harvest|admit|evict|"
    r"drain|iter)"
)
_HOT_PATH_RE = re.compile(r"(^|/)(core|serving)/")
#: Static-shape reads are not host syncs: int(x.shape[0]) is fine under jit.
_STATIC_ARG_RE = re.compile(r"\.shape|\.ndim|\.size\b|\.dtype|len\(")


@register
class HostSyncInHotLoop(Rule):
    id = "JX002"
    title = "host sync in traced code / per-iteration dispatch in a hot loop"
    regression = (
        "PR 7: ungated per-slot paged bookkeeping dispatched every tick "
        "(4x regression at d64_B4); host round-trips inside jit hide "
        "implicit consts and device syncs"
    )

    def check_module(self, tree, src, path):
        info = ModuleInfo(tree)
        seen: Set[int] = set()
        for fn in info.traced_defs:
            for node in ast.walk(fn):
                if id(node) in seen or not isinstance(node, ast.Call):
                    continue
                msg = self._host_sync(node, src)
                if msg:
                    seen.add(id(node))
                    yield Finding(
                        self.id, path, node.lineno, node.col_offset, msg
                    )
        if not _HOT_PATH_RE.search(path):
            return
        for fn in ast.walk(tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if not _HOT_NAME_RE.search(fn.name):
                continue
            for loop in _walk_same_scope(fn):
                if not isinstance(loop, (ast.For, ast.While)):
                    continue
                for node in ast.walk(loop):
                    if not isinstance(node, ast.Call):
                        continue
                    d = _dotted(node.func)
                    if d is None:
                        continue
                    if (d.startswith(("jnp.", "jax.")) and d not in
                            ("jax.random.PRNGKey", "jax.random.key")
                            or d in info.jitted_names):
                        yield Finding(
                            self.id, path, node.lineno, node.col_offset,
                            f"'{d}' dispatched inside a Python loop in hot "
                            f"path '{fn.name}': each iteration pays a "
                            "device dispatch (and a retrace if shapes "
                            "vary) — batch the work into one call or move "
                            "the loop into lax control flow",
                        )
                        break  # one finding per loop is enough

    @staticmethod
    def _host_sync(node: ast.Call, src: str) -> Optional[str]:
        func = node.func
        if (isinstance(func, ast.Attribute) and func.attr == "item"
                and not node.args):
            return (
                ".item() inside traced code blocks on the device and "
                "escapes the trace — keep the value on-device or compute "
                "it outside jit"
            )
        d = _dotted(func)
        if d and (d.startswith("np.") or d.startswith("numpy.")):
            return (
                f"'{d}' inside traced code forces a host round-trip per "
                "call — use jnp inside jit, numpy only at eager boundaries"
            )
        if (isinstance(func, ast.Name) and func.id in ("float", "int", "bool")
                and len(node.args) == 1
                and not isinstance(node.args[0], ast.Constant)):
            arg_src = _seg(src, node.args[0])
            if not _STATIC_ARG_RE.search(arg_src):
                return (
                    f"{func.id}() on a traced value concretizes it "
                    "(ConcretizationTypeError or silent host sync) — use "
                    "jnp ops, or hoist the scalar out of the traced scope"
                )
        return None


# ---------------------------------------------------------------------------
# JX003 — RNG key discipline
# ---------------------------------------------------------------------------
_KEY_PRODUCERS = {"PRNGKey", "key", "split", "fold_in", "clone",
                  "wrap_key_data"}
_KEY_DERIVERS = {"split", "fold_in"}
_NON_CONSUMERS = _KEY_PRODUCERS | {"key_data", "key_impl", "unsafe_rbg_key"}


@register
class RngDiscipline(Rule):
    id = "JX003"
    title = "jax.random key reused instead of split/fold_in-derived"
    regression = (
        "correlated sampler streams bias parallel rollout statistics — "
        "WU-UCT's O_s accounting assumes independent simulations"
    )

    def check_module(self, tree, src, path):
        aliases = self._random_aliases(tree)
        scopes = [tree] + [
            n for n in ast.walk(tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
        ]
        for scope in scopes:
            yield from self._check_scope(scope, aliases, src, path)

    @staticmethod
    def _random_aliases(tree) -> Set[str]:
        """Dotted prefixes that mean the jax.random module."""
        out = {"jax.random"}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.name == "jax.random" and a.asname:
                        out.add(a.asname)
            elif isinstance(node, ast.ImportFrom) and node.module == "jax":
                for a in node.names:
                    if a.name == "random":
                        out.add(a.asname or "random")
        return out

    def _rand_fn(self, call: ast.Call, aliases: Set[str]) -> Optional[str]:
        d = _dotted(call.func)
        if d is None or "." not in d:
            return None
        prefix, leaf = d.rsplit(".", 1)
        return leaf if prefix in aliases else None

    def _check_scope(self, scope, aliases, src, path):
        versions: Dict[str, int] = {}
        is_key: Set[Tuple[str, int]] = set()
        def_depth: Dict[Tuple[str, int], int] = {}
        sampled: Dict[Tuple[str, int], List[ast.Call]] = {}
        derived: Dict[Tuple[str, int], List[ast.Call]] = {}
        findings: List[Finding] = []

        def cur(name):
            return (name, versions.get(name, 0))

        def bind(name, key, depth):
            versions[name] = versions.get(name, 0) + 1
            if key:
                is_key.add(cur(name))
                def_depth[cur(name)] = depth

        def key_producing(expr) -> bool:
            if isinstance(expr, ast.Call):
                leaf = self._rand_fn(expr, aliases)
                if leaf in _KEY_PRODUCERS:
                    return True
            if isinstance(expr, ast.Name) and cur(expr.id) in is_key:
                return True
            if isinstance(expr, ast.Subscript):
                return key_producing(expr.value)
            return False

        def visit_expr(expr, depth):
            for node in ast.walk(expr):
                if not isinstance(node, ast.Call):
                    continue
                leaf = self._rand_fn(node, aliases)
                if leaf is None:
                    continue
                for arg in list(node.args) + [k.value for k in node.keywords]:
                    if not isinstance(arg, ast.Name):
                        continue
                    kv = cur(arg.id)
                    if kv not in is_key:
                        continue
                    if leaf in _KEY_DERIVERS:
                        derived.setdefault(kv, []).append(node)
                    elif leaf not in _NON_CONSUMERS:
                        uses = sampled.setdefault(kv, [])
                        uses.append(node)
                        if len(uses) == 2:
                            findings.append(Finding(
                                self.id, path, node.lineno, node.col_offset,
                                f"key '{arg.id}' consumed by jax.random."
                                f"{leaf} after already being consumed — "
                                "derive fresh keys with split/fold_in",
                            ))
                        if depth > def_depth.get(kv, depth):
                            findings.append(Finding(
                                self.id, path, node.lineno, node.col_offset,
                                f"key '{arg.id}' consumed by jax.random."
                                f"{leaf} inside a loop but produced outside "
                                "it — every iteration reuses the same key",
                            ))

        def bind_target(t, key, depth):
            if isinstance(t, ast.Name):
                bind(t.id, key, depth)
            elif isinstance(t, (ast.Tuple, ast.List)):
                for el in t.elts:
                    bind_target(el, key, depth)

        if isinstance(scope, (ast.FunctionDef, ast.AsyncFunctionDef)):
            params = (scope.args.posonlyargs + scope.args.args
                      + scope.args.kwonlyargs)
            for p in params:
                if re.search(r"(^|_)(key|rng|prng)s?$", p.arg):
                    bind(p.arg, True, 0)

        def visit_stmts(stmts, depth):
            for st in stmts:
                if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                                   ast.ClassDef)):
                    continue  # separate scope
                if isinstance(st, ast.Assign):
                    visit_expr(st.value, depth)
                    key = key_producing(st.value)
                    for t in st.targets:
                        bind_target(t, key, depth)
                elif isinstance(st, ast.AugAssign):
                    visit_expr(st.value, depth)
                elif isinstance(st, ast.AnnAssign) and st.value is not None:
                    visit_expr(st.value, depth)
                    bind_target(st.target, key_producing(st.value), depth)
                elif isinstance(st, (ast.For, ast.AsyncFor)):
                    visit_expr(st.iter, depth)
                    iter_keys = any(
                        isinstance(n, ast.Call)
                        and (self._rand_fn(n, aliases) in ("split",))
                        for n in ast.walk(st.iter)
                    )
                    bind_target(st.target, iter_keys, depth + 1)
                    visit_stmts(st.body, depth + 1)
                    visit_stmts(st.orelse, depth)
                elif isinstance(st, ast.While):
                    visit_expr(st.test, depth + 1)
                    visit_stmts(st.body, depth + 1)
                    visit_stmts(st.orelse, depth)
                elif isinstance(st, ast.If):
                    visit_expr(st.test, depth)
                    visit_stmts(st.body, depth)
                    visit_stmts(st.orelse, depth)
                elif isinstance(st, (ast.With, ast.AsyncWith)):
                    for item in st.items:
                        visit_expr(item.context_expr, depth)
                    visit_stmts(st.body, depth)
                elif isinstance(st, ast.Try):
                    visit_stmts(st.body, depth)
                    for h in st.handlers:
                        visit_stmts(h.body, depth)
                    visit_stmts(st.orelse, depth)
                    visit_stmts(st.finalbody, depth)
                elif isinstance(st, (ast.Return, ast.Expr)):
                    if st.value is not None:
                        visit_expr(st.value, depth)
                elif isinstance(st, ast.Raise):
                    if st.exc is not None:
                        visit_expr(st.exc, depth)

        body = scope.body if hasattr(scope, "body") else []
        visit_stmts(body, 0)
        for kv, uses in sampled.items():
            if kv in derived:
                findings.append(Finding(
                    self.id, path, uses[0].lineno, uses[0].col_offset,
                    f"key '{kv[0]}' is consumed by a sampler AND used as a "
                    "split/fold_in parent — the sampler stream is "
                    "correlated with every derived key",
                ))
        yield from findings


# ---------------------------------------------------------------------------
# JX004 — exception hygiene / silent clipping
# ---------------------------------------------------------------------------
_BROAD_EXC = {"Exception", "BaseException"}
_CLIP_FNS = {"jnp.clip", "jax.numpy.clip", "np.clip", "numpy.clip"}
_USER_VALUE_RE = re.compile(r"action", re.IGNORECASE)


@register
class ExceptionHygiene(Rule):
    id = "JX004"
    title = "bare/over-broad except or silent clip of a user-facing value"
    regression = (
        "PR 8 serving sweep: silent cache overflow on over-long prompts, "
        "invalid search actions clipped into confident-looking tokens, a "
        "bare except hiding baseline-parse failures"
    )

    def check_module(self, tree, src, path):
        for node in ast.walk(tree):
            if isinstance(node, ast.ExceptHandler):
                yield from self._check_handler(node, src, path)
        for fn in ast.walk(tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            has_raise = any(
                isinstance(n, ast.Raise) for n in _walk_same_scope(fn)
            )
            if has_raise:
                continue
            for node in _walk_same_scope(fn):
                if isinstance(node, ast.Call):
                    clipped = self._clipped_user_value(node, src)
                    if clipped:
                        yield Finding(
                            self.id, path, node.lineno, node.col_offset,
                            f"silent clip of user-facing value "
                            f"{clipped!r} in '{fn.name}' — an out-of-range "
                            "action becomes indistinguishable from a valid "
                            "one; validate and raise at the eager boundary",
                        )

    def _check_handler(self, node: ast.ExceptHandler, src, path):
        if node.type is None:
            yield Finding(
                self.id, path, node.lineno, node.col_offset,
                "bare 'except:' swallows everything including "
                "KeyboardInterrupt — catch a specific exception tuple",
            )
            return
        names = []
        types = node.type.elts if isinstance(node.type, ast.Tuple) \
            else [node.type]
        for t in types:
            d = _dotted(t)
            if d in _BROAD_EXC:
                names.append(d)
        if not names:
            return
        reraises = any(
            isinstance(n, ast.Raise)
            and (n.exc is None
                 or (isinstance(n.exc, ast.Name) and n.exc.id == node.name))
            for n in ast.walk(node)
        )
        if not reraises:
            yield Finding(
                self.id, path, node.lineno, node.col_offset,
                f"over-broad 'except {'/'.join(names)}' without re-raise "
                "hides real failures — catch the specific exception tuple "
                "the guarded code can actually raise",
            )

    @staticmethod
    def _clipped_user_value(node: ast.Call, src) -> Optional[str]:
        d = _dotted(node.func)
        target = None
        if d in _CLIP_FNS and node.args:
            target = node.args[0]
        elif (isinstance(node.func, ast.Attribute)
              and node.func.attr == "clip" and d is None):
            target = node.func.value
        elif (isinstance(node.func, ast.Attribute)
              and node.func.attr == "clip" and d and d.count(".") >= 1
              and d.split(".")[0] not in ("jnp", "np", "numpy", "jax")):
            target = node.func.value
        if target is None:
            return None
        seg = _seg(src, target)
        return seg if _USER_VALUE_RE.search(seg) else None


# ---------------------------------------------------------------------------
# JX005 — kernel ref-oracle contract
# ---------------------------------------------------------------------------
_KERNEL_PKG_RE = re.compile(r"(^|/)kernels/([^/]+)/[^/]+\.py$")


@register
class KernelContract(Rule):
    id = "JX005"
    title = "kernel package missing its ref.py oracle or parity test"
    regression = (
        "a Pallas kernel is only trustworthy relative to its jnp "
        "reference; every kernel family here landed with oracle parity "
        "sweeps and later optimizations were caught against them"
    )

    def check_project(self, files, trees):
        pkgs: Dict[str, List[str]] = {}
        for path in files:
            m = _KERNEL_PKG_RE.search(path)
            if m:
                pkgs.setdefault(m.group(2), []).append(path)
        test_files = {
            p: s for p, s in files.items()
            if p.split("/")[0] == "tests" or "/tests/" in p
            or p.rsplit("/", 1)[-1].startswith("test_")
        }
        for name, members in sorted(pkgs.items()):
            non_init = [p for p in members
                        if not p.endswith("__init__.py")]
            if not non_init:
                continue
            anchor = sorted(non_init)[0]
            if not any(p.endswith(f"kernels/{name}/ref.py")
                       for p in members):
                yield Finding(
                    self.id, anchor, 1, 0,
                    f"kernel package '{name}' ships no ref.py oracle — "
                    "add the jnp reference implementation the Pallas "
                    "kernel is tested against",
                )
            if test_files and not any(name in s for s in
                                      test_files.values()):
                yield Finding(
                    self.id, anchor, 1, 0,
                    f"kernel '{name}' is not named by any parity test "
                    "under tests/ — add an oracle-parity test pinning the "
                    "kernel to its ref.py",
                )
