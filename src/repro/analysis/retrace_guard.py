"""Runtime retrace sanitizer: pin ``traces == 1`` on jitted hot paths.

Static analysis (JX001) catches the *shape* of retrace hazards; this module
catches the fact.  A :class:`RetraceGuard` snapshots each wrapped jitted
function's compilation-cache size on entry and diffs it on exit: every
cache miss inside the guarded region is a (re)trace.  Tests wrap the
serving hot path's graphs — ``admit`` / ``evict`` / ``run_segment`` — and
assert each traced exactly once across a ragged-arrival drain, turning
PR 8's 30x variable-shape-admit regression into a permanently red test
instead of a benchmark archaeology exercise.

Usage::

    with retrace_guard(admit=svc._admit_fn, evict=svc._evict_fn) as g:
        svc.serve(prompts)           # raises RetraceError if any fn
    assert g.counts()["admit"] == 1  # traced more than max_traces times

The guard needs ``jax.jit``-wrapped callables (anything exposing JAX's
``_cache_size``); it imports no JAX itself and adds zero overhead to the
guarded calls — it only reads cache sizes at the region boundaries.
"""

from __future__ import annotations

from typing import Any, Dict, Optional


class RetraceError(AssertionError):
    """A guarded jitted function retraced more than ``max_traces`` times."""


def jit_cache_size(fn: Any) -> int:
    """Compiled-signature count of a ``jax.jit``-wrapped callable."""
    try:
        return int(fn._cache_size())
    except AttributeError:
        raise TypeError(
            f"{fn!r} is not a jax.jit-wrapped callable (no _cache_size): "
            "retrace_guard can only watch jitted functions"
        ) from None


class RetraceGuard:
    """Context manager counting jit cache misses per wrapped function."""

    def __init__(self, fns: Dict[str, Any], max_traces: int = 1):
        if not fns:
            raise ValueError("retrace_guard needs at least one function")
        for name, fn in fns.items():
            jit_cache_size(fn)  # fail fast on non-jitted callables
        self._fns = dict(fns)
        self.max_traces = max_traces
        self._base: Optional[Dict[str, int]] = None

    def __enter__(self) -> "RetraceGuard":
        self._base = {n: jit_cache_size(f) for n, f in self._fns.items()}
        return self

    def counts(self) -> Dict[str, int]:
        """Traces per function since the guard was entered."""
        if self._base is None:
            raise RuntimeError("retrace_guard not entered yet")
        return {
            n: jit_cache_size(f) - self._base[n]
            for n, f in self._fns.items()
        }

    def check(self) -> None:
        """Raise :class:`RetraceError` if any function over-traced."""
        offenders = {
            n: c for n, c in self.counts().items() if c > self.max_traces
        }
        if offenders:
            detail = ", ".join(
                f"{n}: {c} traces" for n, c in sorted(offenders.items())
            )
            raise RetraceError(
                f"retraced beyond max_traces={self.max_traces} inside the "
                f"guarded region ({detail}) — an argument's shape/dtype is "
                "varying per call; pad to a fixed shape or mark it static"
            )

    def __exit__(self, exc_type, exc, tb) -> None:
        # Don't mask an exception already unwinding through the region.
        if exc_type is None:
            self.check()


def retrace_guard(max_traces: int = 1, **fns: Any) -> RetraceGuard:
    """Build a :class:`RetraceGuard` over ``name=jitted_fn`` pairs."""
    return RetraceGuard(fns, max_traces=max_traces)
