"""Production mesh definitions.

Functions (not module-level constants) so importing this module never touches
jax device state — the dry-run sets XLA_FLAGS *before* any jax init.
"""

from __future__ import annotations

import jax


def _mk(shape, axes):
    # axis_types only exists on newer JAX; pre-0.5 meshes are untyped.
    axis_type = getattr(jax.sharding, "AxisType", None)
    kw = {} if axis_type is None else {
        "axis_types": (axis_type.Auto,) * len(axes)
    }
    return jax.make_mesh(shape, axes, **kw)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_test_mesh(*, multi_pod: bool = False):
    """Small mesh for CI-scale dry-run tests (8 host devices)."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_single_device_mesh():
    return _mk((1, 1), ("data", "model"))
