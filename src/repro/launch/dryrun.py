import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("DRYRUN_EXTRA_XLA_FLAGS", "")
)

# ---------------------------------------------------------------------------
# Multi-pod dry-run: lower + compile every (architecture × input-shape) cell
# for the production meshes and extract the roofline terms from the compiled
# artifact.  This file proves the distribution config is coherent without
# real hardware — any sharding mismatch, compile-OOM or unsupported
# collective is a bug in the system, not in the harness.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
#       --shape train_4k --mesh single_pod
#   PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/dryrun
# ---------------------------------------------------------------------------

import argparse
import json
import re
import time
import traceback
from typing import Optional

import jax

from repro.launch.cells import SHAPES, all_cells, build_cell, skip_reason
from repro.launch.mesh import make_production_mesh, make_test_mesh

# The failure modes a dry-run cell can legitimately hit: sharding/shape
# mismatches (ValueError/TypeError), compile failures and XLA OOM
# (RuntimeError — XlaRuntimeError subclasses it), missing cell config keys
# (KeyError/AttributeError), unsupported collectives (NotImplementedError)
# and artifact IO (OSError).  Anything else — e.g. a KeyboardInterrupt or a
# typo-level NameError — should crash the sweep, not be recorded as a cell
# failure.
_CELL_ERRORS = (
    RuntimeError, ValueError, TypeError, KeyError, AttributeError,
    IndexError, NotImplementedError, OSError, ArithmeticError,
)

# TPU v5e hardware constants (assignment-specified).
PEAK_FLOPS = 197e12       # bf16 FLOP/s per chip
HBM_BW = 819e9            # bytes/s per chip
LINK_BW = 50e9            # bytes/s per chip (effective ICI collective bw)

_COLL_RE = re.compile(
    r"^\s*(?:%|\S+ = )?"
    r"(?P<shape>\(?[a-z0-9]+\[[0-9,]*\][^ ]*\)?)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def _shape_bytes(shape_str: str) -> int:
    """Bytes of an HLO shape string like 'bf16[8,128]{1,0}' or a tuple."""
    total = 0
    for m in re.finditer(r"([a-z][a-z0-9]*)\[([0-9,]*)\]", shape_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-chip wire bytes per collective type, parsed from post-SPMD HLO.

    The compiled module is the per-device SPMD program, so result shapes are
    shard shapes.  Wire-cost model (ring algorithms, group size n):
      all-gather:        out_bytes * (n-1)/n     ≈ out_bytes
      all-reduce:        2 * bytes * (n-1)/n     ≈ 2 * bytes
      reduce-scatter:    in_bytes  * (n-1)/n     ≈ out_bytes * (n-1)
      all-to-all:        bytes * (n-1)/n
      collective-permute: bytes
    We use the ≈ forms (upper bounds) with n from replica_groups when
    parseable.
    """
    out = {k: 0.0 for k in (
        "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
        "collective-permute")}
    counts = {k: 0 for k in out}
    for line in hlo_text.splitlines():
        if "fused_computation" in line:
            continue
        m = re.search(
            r"= (?P<shape>\(?[^=]*?\)?) (?P<op>all-gather|all-reduce|"
            r"reduce-scatter|all-to-all|collective-permute)(?:-start)?\(",
            line,
        )
        if not m:
            continue
        op = m.group("op")
        nbytes = _shape_bytes(m.group("shape"))
        gm = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
        n = int(gm.group(2)) if gm else 2
        if op == "all-gather":
            out[op] += nbytes * (n - 1) / max(n, 1)
        elif op == "all-reduce":
            out[op] += 2 * nbytes * (n - 1) / max(n, 1)
        elif op == "reduce-scatter":
            out[op] += nbytes * (n - 1)
        elif op == "all-to-all":
            out[op] += nbytes * (n - 1) / max(n, 1)
        else:
            out[op] += nbytes
        counts[op] += 1
    out["total"] = sum(out.values())
    out["counts"] = counts
    return out


def model_flops(cell, mesh_devices: int) -> float:
    """6·N·D bookkeeping (N = active params for MoE)."""
    cfg = cell.model_cfg
    n = cfg.active_param_count()
    if cell.kind == "train":
        return 6.0 * n * cell.tokens_per_step
    return 2.0 * n * cell.tokens_per_step


def _compile_cell(cell, mesh):
    with jax.set_mesh(mesh):
        jitted = jax.jit(
            cell.fn,
            in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings,
        )
        lowered = jitted.lower(*cell.arg_specs)
        compiled = lowered.compile()
    return lowered, compiled


def _cost_point(arch, shape, mesh, overrides, strategy="tp", kv_mode=None) -> dict:
    """Per-device (flops, bytes, collectives) for a small UNROLLED config.

    XLA's cost analysis counts while-loop bodies once, so the scanned full
    model under-reports per-layer work.  We therefore compile 2-3 small
    *unrolled* configs with identical per-device activation shapes and solve
    the affine model cost(L) = base + L·layer (+ sites·site for hybrid).
    """
    cell = build_cell(
        arch, shape, mesh, cfg_overrides=overrides,
        strategy=strategy, kv_mode=kv_mode,
    )
    _, compiled = _compile_cell(cell, mesh)
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": coll["total"],
        "coll_detail": {k: coll[k] for k in (
            "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
            "collective-permute")},
    }


def _lin_combine(points: dict[int, dict], weights: dict[int, float]) -> dict:
    keys = ("flops", "bytes", "coll")
    out = {k: 0.0 for k in keys}
    detail = {}
    for L, w in weights.items():
        for k in keys:
            out[k] += w * points[L][k]
        for k, v in points[L]["coll_detail"].items():
            detail[k] = detail.get(k, 0.0) + w * v
    out["coll_detail"] = {k: max(v, 0.0) for k, v in detail.items()}
    return {k: (max(v, 0.0) if not isinstance(v, dict) else v) for k, v in out.items()}


def measure_roofline_terms(
    arch, shape, mesh, overrides=None, strategy="tp", kv_mode=None
) -> dict:
    """Extrapolated per-device totals for the real layer count."""
    from repro.configs import get_config

    cfg = get_config(arch)
    # Keep remat ON so the compute term includes real recompute FLOPs.
    base_over = dict(overrides or {})
    base_over["scan_layers"] = False
    if cfg.family == "hybrid":
        k = cfg.attn_every
        pts = {}
        for L in (k, k + 1, 2 * k):
            pts[L] = _cost_point(
                arch, shape, mesh, dict(base_over, num_layers=L),
                strategy, kv_mode,
            )
        # f(L) = base + L*ssm + sites(L)*site; sites(k)=1, sites(k+1)=2, sites(2k)=2
        # ssm  = (f(2k) - f(k+1)) / (k - 1)
        # site = f(k+1) - f(k) - ssm
        # base = f(k) - k*ssm - site
        L_real, sites_real = cfg.num_layers, (cfg.num_layers + k - 1) // k
        den = k - 1
        w_ssm = {2 * k: 1.0 / den, k + 1: -1.0 / den}
        # site = f(k+1) - f(k) - ssm
        w_site = {k + 1: 1.0 + 1.0 / den, k: -1.0, 2 * k: -1.0 / den}
        # base = f(k) - k*ssm - site
        w_base = {
            k: 2.0,
            k + 1: -(1.0 + 1.0 / den) + (k * 1.0 / den),
            2 * k: 1.0 / den - k * 1.0 / den,
        }
        weights = {}
        for L in pts:
            weights[L] = (
                w_base.get(L, 0.0)
                + L_real * w_ssm.get(L, 0.0)
                + sites_real * w_site.get(L, 0.0)
            )
        return _lin_combine(pts, weights)

    pts = {}
    for L in (1, 2):
        over = dict(base_over, num_layers=L)
        if cfg.family == "encdec":
            over["num_encoder_layers"] = L
        pts[L] = _cost_point(arch, shape, mesh, over, strategy, kv_mode)
    L_real = cfg.num_layers  # == num_encoder_layers for whisper
    # slope = f(2) - f(1); base = f(1) - slope; total = base + L*slope
    weights = {1: 1.0 - (L_real - 1.0), 2: (L_real - 1.0)}
    return _lin_combine(pts, weights)


def run_cell(
    arch: str, shape: str, mesh, mesh_name: str, verbose=True,
    overrides: Optional[dict] = None, measure: bool = True,
    strategy: str = "tp", kv_mode: Optional[str] = None,
) -> dict:
    t0 = time.time()
    cell = build_cell(
        arch, shape, mesh, cfg_overrides=overrides,
        strategy=strategy, kv_mode=kv_mode,
    )
    lowered, compiled = _compile_cell(cell, mesh)
    t_full = time.time() - t0

    mem = compiled.memory_analysis()
    coll_full = collective_bytes(compiled.as_text())
    n_dev = mesh.devices.size

    terms = (
        measure_roofline_terms(arch, shape, mesh, overrides, strategy, kv_mode)
        if measure
        else None
    )
    t_measure = time.time() - t0 - t_full

    result = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_name,
        "devices": n_dev,
        "kind": cell.kind,
        "overrides": overrides or {},
        "strategy": strategy,
        "kv_mode": kv_mode,
        "compile_s": round(t_full, 1),
        "measure_s": round(t_measure, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": (
                getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "temp_size_in_bytes", 0)
            ),
        },
        "full_module_collectives": coll_full["counts"],
    }
    if terms is not None:
        compute_s = terms["flops"] / PEAK_FLOPS
        memory_s = terms["bytes"] / HBM_BW
        collective_s = terms["coll"] / LINK_BW
        dominant = max(
            ("compute", compute_s),
            ("memory", memory_s),
            ("collective", collective_s),
            key=lambda kv: kv[1],
        )[0]
        mf = model_flops(cell, n_dev)
        useful = mf / (terms["flops"] * n_dev) if terms["flops"] else 0.0
        result["per_device"] = terms
        result["roofline"] = {
            "compute_s": compute_s,
            "memory_s": memory_s,
            "collective_s": collective_s,
            "dominant": dominant,
            "model_flops": mf,
            "useful_flops_ratio": useful,
            "step_time_lower_bound_s": max(compute_s, memory_s, collective_s),
            "roofline_fraction": (
                compute_s / max(compute_s, memory_s, collective_s)
                if max(compute_s, memory_s, collective_s) > 0
                else 0.0
            ),
        }
    if verbose:
        print(json.dumps(result, indent=2, default=str))
    return result


MESHES = {
    "single_pod": lambda: make_production_mesh(multi_pod=False),
    "multi_pod": lambda: make_production_mesh(multi_pod=True),
    "test": lambda: make_test_mesh(multi_pod=False),
    "test_multi": lambda: make_test_mesh(multi_pod=True),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single_pod", choices=list(MESHES))
    ap.add_argument("--all", action="store_true", help="run every cell")
    ap.add_argument("--out", default=None, help="directory for JSON records")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--strategy", default="tp", choices=["tp", "fsdp"])
    ap.add_argument("--kv-mode", default=None,
                    choices=[None, "batch", "seq_data", "batch+seq_model", "seq_all"])
    ap.add_argument("--tag", default=None,
                    help="suffix for the output record (perf iterations)")
    ap.add_argument(
        "--override", default=None,
        help="comma list of cfg overrides, e.g. num_heads=48,loss_chunk=512",
    )
    args = ap.parse_args()

    overrides = None
    if args.override:
        overrides = {}
        for kv in args.override.split(","):
            k, v = kv.split("=")
            overrides[k] = (
                v == "True" if v in ("True", "False") else
                float(v) if "." in v else int(v)
            )

    mesh = MESHES[args.mesh]()
    mesh_name = args.mesh

    if args.out:
        os.makedirs(args.out, exist_ok=True)

    todo = []
    if args.all:
        for arch, shape, reason in all_cells():
            todo.append((arch, shape, reason))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        todo.append((args.arch, args.shape, skip_reason(args.arch, args.shape)))

    failures = []
    for arch, shape, reason in todo:
        tag = f"{arch}__{shape}__{mesh_name}"
        if args.tag:
            tag = f"{tag}__{args.tag}"
        path = os.path.join(args.out, f"{tag}.json") if args.out else None
        if reason is not None:
            rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                   "skipped": reason}
            print(f"SKIP {tag}: {reason}")
        elif args.skip_existing and path and os.path.exists(path):
            print(f"CACHED {tag}")
            continue
        else:
            print(f"=== {tag} ===", flush=True)
            try:
                rec = run_cell(
                    arch, shape, mesh, mesh_name, verbose=not args.out,
                    overrides=overrides, strategy=args.strategy,
                    kv_mode=args.kv_mode,
                )
                r = rec["roofline"]
                print(
                    f"ok   {tag}: compile={rec['compile_s']}s "
                    f"dominant={r['dominant']} "
                    f"compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s "
                    f"collective={r['collective_s']:.4f}s "
                    f"useful={r['useful_flops_ratio']:.2f}",
                    flush=True,
                )
            except _CELL_ERRORS as e:  # record the cell's failure, continue
                traceback.print_exc()
                rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                       "error": str(e)}
                failures.append(tag)
        if path:
            with open(path, "w") as f:
                json.dump(rec, f, indent=2, default=str)
    if failures:
        print(f"\nFAILED cells: {failures}")
        raise SystemExit(1)
    print("\nall requested cells passed")


if __name__ == "__main__":
    main()
