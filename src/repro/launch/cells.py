"""(architecture × input-shape) cell definitions for the dry-run + roofline.

Each LM cell builds:
  * a step function (``train_step`` for train shapes, ``prefill``/``serve``
    for inference shapes),
  * allocation-free ShapeDtypeStruct argument specs (params, optimizer
    state, caches, batches),
  * in/out shardings for the production mesh.

``long_500k`` runs only for sub-quadratic archs (ssm/hybrid) per the
assignment; the skip is recorded, not silent.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs import get_config, list_archs
from ..distributed.sharding import (
    batch_spec,
    opt_state_shardings,
    param_partition_specs,
    param_shardings,
)
from ..models import abstract_params, decode_step, init_cache, prefill
from ..models.config import ModelConfig
from ..training.optimizer import AdamWConfig, AdamWState
from ..training.train_step import TrainConfig, make_train_step

SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}


class Cell(NamedTuple):
    arch: str
    shape: str
    kind: str
    fn: Any                      # callable to lower
    arg_specs: tuple             # ShapeDtypeStructs
    in_shardings: tuple
    out_shardings: Any
    model_cfg: ModelConfig
    tokens_per_step: int         # for MODEL_FLOPS bookkeeping


def skip_reason(arch: str, shape: str) -> Optional[str]:
    cfg = get_config(arch)
    if shape == "long_500k" and not cfg.supports_long_context:
        return (
            "full-attention arch: long_500k requires sub-quadratic attention "
            "(assignment rule; see DESIGN.md §Arch-applicability)"
        )
    return None


def _pad_experts(cfg: ModelConfig, tp: int) -> ModelConfig:
    """Pad routed experts to a multiple of the TP size for EP divisibility."""
    if cfg.family != "moe" or cfg.num_experts % tp == 0:
        return cfg
    padded = ((cfg.num_experts + tp - 1) // tp) * tp
    return dataclasses.replace(
        cfg, num_experts=padded, num_experts_real=cfg.num_experts
    )


def _abstract(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree
    )


def _batch_specs(cfg: ModelConfig, batch_size: int, seq_len: int) -> dict:
    batch: dict[str, Any] = {}
    if cfg.family == "vlm":
        text = seq_len - cfg.num_patches
        batch["tokens"] = jax.ShapeDtypeStruct((batch_size, text), jnp.int32)
        batch["patch_embeds"] = jax.ShapeDtypeStruct(
            (batch_size, cfg.num_patches, cfg.d_model), cfg.dtype
        )
    else:
        batch["tokens"] = jax.ShapeDtypeStruct((batch_size, seq_len), jnp.int32)
    if cfg.family == "encdec":
        batch["frame_embeds"] = jax.ShapeDtypeStruct(
            (batch_size, cfg.encoder_seq, cfg.d_model), cfg.dtype
        )
    return batch


def _cache_shardings(cfg: ModelConfig, cache_abs, mesh, *, kv_mode: str):
    """KV cache placement:

    * ``batch``      — B over data axes (default decode/prefill),
    * ``seq_data``   — S over data (batch=1 long-context SP decode),
    * ``batch+seq_model`` — B over data AND S over model: split-KV decode
      (flash-decoding): each model shard reduces its S/16 slice, merged by a
      tiny LSE psum — the decode-cell hillclimb.
    """
    dp = batch_spec(mesh)

    def spec_for(path_key: str, leaf):
        nd = len(leaf.shape)
        if path_key.endswith("len"):
            return P()
        if "cross" in path_key:
            # enc-dec cross KV is short (1500 frames) and rarely divides the
            # model axis — batch-shard only.
            return P(None, dp[0] if dp else None, None, None, None)
        if "kv" in path_key:
            # [L(or sites), B, S, H, D]
            if kv_mode == "seq_data":
                return P(None, None, dp[0] if dp else None, None, None)
            if kv_mode == "batch+seq_model":
                return P(None, dp[0] if dp else None, "model", None, None)
            if kv_mode == "seq_all":
                # batch=1 long-context: S over EVERY mesh axis.
                axes = tuple(a for a in ("pod", "data", "model")
                             if a in mesh.axis_names)
                return P(None, None, axes, None, None)
            return P(None, dp[0] if dp else None, None, None, None)
        if "ssm" in path_key:
            # conv: [L, B, K-1, C] / state: [L, B, H, P, N]
            entries = [None] * nd
            if kv_mode not in ("seq_data", "seq_all"):
                entries[1] = dp[0] if dp else None
            return P(*entries)
        return P()

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_abs)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", p)) for p in path)
        out.append(NamedSharding(mesh, spec_for(key, leaf)))
    return jax.tree_util.tree_unflatten(treedef, out)


def build_cell(
    arch: str,
    shape: str,
    mesh: Mesh,
    cfg_overrides: Optional[dict] = None,
    strategy: str = "tp",
    kv_mode: Optional[str] = None,
) -> Cell:
    reason = skip_reason(arch, shape)
    if reason is not None:
        raise ValueError(f"cell ({arch}, {shape}) skipped: {reason}")
    spec = SHAPES[shape]
    tp = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    cfg = _pad_experts(get_config(arch), tp)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    gb, sl = spec["global_batch"], spec["seq_len"]
    if kv_mode is None:
        kv_mode = "seq_data" if shape == "long_500k" else "batch"

    params_abs = abstract_params(cfg)
    pshard = param_shardings(cfg, params_abs, mesh, strategy)
    dp = batch_spec(mesh, strategy, gb)

    if spec["kind"] == "train":
        opt_abs = jax.eval_shape(
            lambda p: AdamWState(
                step=jnp.int32(0),
                m=jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), p),
                v=jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), p),
                master=jax.tree.map(lambda x: x.astype(jnp.float32), p),
            ),
            params_abs,
        )
        oshard = opt_state_shardings(cfg, params_abs, mesh, opt_abs, strategy)
        batch_abs = _batch_specs(cfg, gb, sl)
        bshard = jax.tree.map(lambda _: NamedSharding(mesh, dp), batch_abs)
        step = make_train_step(cfg, TrainConfig())
        metrics_shard = None  # let the partitioner place scalars
        return Cell(
            arch=arch,
            shape=shape,
            kind="train",
            fn=step,
            arg_specs=(params_abs, opt_abs, batch_abs),
            in_shardings=(pshard, oshard, bshard),
            out_shardings=(pshard, oshard, metrics_shard),
            model_cfg=cfg,
            tokens_per_step=gb * sl,
        )

    if spec["kind"] == "prefill":
        cache_abs = jax.eval_shape(lambda: init_cache(cfg, gb, sl))
        cshard = _cache_shardings(cfg, cache_abs, mesh, kv_mode=kv_mode)
        batch_abs = _batch_specs(cfg, gb, sl)
        bshard = jax.tree.map(lambda _: NamedSharding(mesh, dp), batch_abs)

        def prefill_fn(params, batch, cache):
            return prefill(params, cfg, batch, cache)

        return Cell(
            arch=arch,
            shape=shape,
            kind="prefill",
            fn=prefill_fn,
            arg_specs=(params_abs, batch_abs, cache_abs),
            in_shardings=(pshard, bshard, cshard),
            out_shardings=(NamedSharding(mesh, dp), cshard),
            model_cfg=cfg,
            tokens_per_step=gb * sl,
        )

    # decode: one new token against a seq_len-deep cache.
    cache_abs = jax.eval_shape(lambda: init_cache(cfg, gb, sl))
    # pretend the cache is full up to sl-1
    cache_abs = dict(cache_abs, len=jax.ShapeDtypeStruct((), jnp.int32))
    cshard = _cache_shardings(cfg, cache_abs, mesh, kv_mode=kv_mode)
    token_abs = jax.ShapeDtypeStruct((gb,), jnp.int32)
    tshard = NamedSharding(
        mesh, dp if kv_mode not in ("seq_data", "seq_all") else P()
    )

    def decode_fn(params, token, cache):
        return decode_step(params, cfg, token, cache)

    return Cell(
        arch=arch,
        shape=shape,
        kind="decode",
        fn=decode_fn,
        arg_specs=(params_abs, token_abs, cache_abs),
        in_shardings=(pshard, tshard, cshard),
        out_shardings=(tshard, cshard),
        model_cfg=cfg,
        tokens_per_step=gb,
    )


def all_cells() -> list[tuple[str, str, Optional[str]]]:
    """Every (arch, shape) with its skip reason (None = runnable)."""
    out = []
    for arch in list_archs():
        for shape in SHAPES:
            out.append((arch, shape, skip_reason(arch, shape)))
    return out
