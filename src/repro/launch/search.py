"""Search launcher: WU-UCT (or any baseline) on any registered environment.

Everything goes through the one front door, ``repro.core.build_searcher``:
the ``--algo/--engine/--batch`` flags map 1:1 onto ``SearchSpec`` fields.

Episode play (one search per move):
  PYTHONPATH=src python -m repro.launch.search --env tap --algo wu_uct \
      --workers 16 --simulations 128 --episodes 2

Batched multi-root mode (B independent searches in lockstep through the
fused Pallas tree_select kernel; reports searches/sec):
  PYTHONPATH=src python -m repro.launch.search --env bandit --algo wu_uct \
      --batch 32 --workers 8 --simulations 64

The wave engine is the default; ``--engine async`` selects the async-slot
engine (the paper's master–worker interleaving: no slot waits for the
slowest rollout).  Combined with ``--batch`` it runs B trees × W slots in
one program with the rollout batch flattened to [B·W]:
  PYTHONPATH=src python -m repro.launch.search --env bandit --algo wu_uct \
      --batch 32 --workers 16 --simulations 128 --engine async
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core import SearchSpec, build_searcher, play_episode
from repro.distributed import constrain_search_batch
from repro.envs import make_bandit_tree, make_random_mdp, make_tap_game


def make_env(name: str):
    return {
        "tap": lambda: make_tap_game(grid_size=6, num_colors=4, goal_count=10,
                                     step_budget=20),
        "tap_hard": lambda: make_tap_game(grid_size=7, num_colors=5,
                                          goal_count=14, step_budget=30),
        "bandit": lambda: make_bandit_tree(depth=6, num_actions=4),
        "mdp": lambda: make_random_mdp(num_states=32, num_actions=4, horizon=16),
    }[name]()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--env", default="tap",
                    choices=["tap", "tap_hard", "bandit", "mdp"])
    ap.add_argument("--algo", default="wu_uct",
                    choices=["wu_uct", "uct", "treep", "treep_vc", "leafp", "rootp"])
    ap.add_argument("--workers", type=int, default=16)
    ap.add_argument("--simulations", type=int, default=128)
    ap.add_argument("--episodes", type=int, default=2)
    ap.add_argument("--max-depth", type=int, default=10)
    ap.add_argument("--width", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--batch", type=int, default=0,
                    help="B>0: run B root states through the batched "
                         "multi-root engine instead of episode play")
    ap.add_argument("--engine", default="wave", choices=["wave", "async"],
                    help="wave: barrier per wave; async: slot-level "
                         "interleaving (refill the instant a rollout settles)")
    args = ap.parse_args()

    env = make_env(args.env)
    spec = SearchSpec(
        algo=args.algo,
        engine=args.engine,
        batch=args.batch,
        num_simulations=args.simulations,
        wave_size=args.workers,
        max_depth=args.max_depth,
        max_sim_steps=20,
        max_width=min(args.width, env.num_actions),
        gamma=0.99,
    )

    if args.batch > 0:
        B = args.batch
        # constrain is a no-op without a mesh; under one, shards the B (and
        # async [B·W]) axis over ('pod', 'data').
        search = build_searcher(env, spec, constrain=constrain_search_batch)
        roots = jax.vmap(env.init)(
            jax.random.split(jax.random.PRNGKey(args.seed), B)
        )
        rngs = jax.random.split(jax.random.PRNGKey(args.seed + 1), B)
        res = jax.block_until_ready(search(roots, rngs))  # compile
        t0 = time.time()
        res = jax.block_until_ready(search(roots, rngs))
        dt = time.time() - t0
        acts = np.asarray(res.action)
        cfg = spec.config
        print(f"{args.algo}[{args.engine}] B={B} W={cfg.wave_size} "
              f"T={cfg.num_simulations}: "
              f"{B / dt:.1f} searches/s  wall={dt:.2f}s  "
              f"actions={acts[:min(B, 16)].tolist()}"
              f"{'…' if B > 16 else ''}  overflowed={bool(res.overflowed.any())}")
        return

    searcher = build_searcher(env, spec)
    rets, steps = [], []
    for ep in range(args.episodes):
        t0 = time.time()
        ret, moves, done = play_episode(
            env, spec.config, jax.random.PRNGKey(args.seed + ep), max_moves=32,
            searcher=searcher,
        )
        rets.append(ret)
        steps.append(moves)
        print(
            f"episode {ep}: return={ret:.3f} game_steps={moves} done={done} "
            f"wall={time.time() - t0:.1f}s"
        )
    print(
        f"\n{args.algo} W={args.workers}: return={np.mean(rets):.3f}"
        f"±{np.std(rets):.3f} game_steps={np.mean(steps):.1f}"
    )


if __name__ == "__main__":
    main()
