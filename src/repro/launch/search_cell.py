"""Dry-run cell for the paper's technique itself: one WU-UCT wave step on
the production mesh.

Maps the master–worker architecture onto the mesh exactly as DESIGN.md §2
describes:

* tree statistics + master bookkeeping (phases 1/3): replicated — zero
  communication by determinism;
* the wave of in-flight simulation slots (phase 2): sharded over the
  ``(pod, data)`` axes (`with_sharding_constraint` on every slot-indexed
  tensor);
* the rollout policy network: a tap-game policy MLP tensor-sharded over
  ``model`` — the same TP machinery the LM cells use, exercised inside the
  vmapped simulation loop.

``jit(search_wave).lower(...).compile()`` succeeding on the 256/512-chip
meshes proves the paper's parallelization scheme is coherent at pod scale.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core import tree as tree_lib
from ..core.wu_uct import (
    SearchConfig,
    _phase1_select,
    _phase2_work,
    _phase3_settle,
)
from ..core.baselines import make_config
from ..distributed.sharding import data_axes
from ..envs import Environment, make_tap_game


def _policy_net_env(base_env: Environment, params) -> Environment:
    """Tap env whose default policy is an MLP over observations (the role the
    distilled PPO net plays in the paper's Atari setup)."""

    def rollout_policy(key, state):
        obs = base_env.observe(state)
        h = jax.nn.relu(obs @ params["w1"] + params["b1"])
        logits = h @ params["w2"]
        return jax.random.categorical(key, logits).astype(jnp.int32)

    return Environment(
        name=base_env.name + "+mlp",
        num_actions=base_env.num_actions,
        init=base_env.init,
        step=base_env.step,
        rollout_policy=rollout_policy,
        observe=base_env.observe,
    )


class SearchCell(NamedTuple):
    fn: object
    arg_specs: tuple
    in_shardings: tuple
    out_shardings: object
    cfg: SearchConfig


def build_search_cell(
    mesh: Mesh,
    wave_size: int = 256,
    num_simulations: int = 1024,
    d_mlp: int = 8192,
) -> SearchCell:
    base_env = make_tap_game(grid_size=6, num_colors=4, goal_count=12,
                             step_budget=20)
    obs_dim = int(base_env.observe(base_env.init(jax.random.PRNGKey(0))).shape[0])
    cfg = make_config(
        "wu_uct",
        num_simulations=num_simulations,
        wave_size=wave_size,
        max_depth=10,
        max_sim_steps=20,
        max_width=5,
        gamma=1.0,
    )
    dp = data_axes(mesh)
    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)

    def constrain_slots(tree_args):
        def per_leaf(x):
            if not hasattr(x, "ndim") or x.ndim == 0 or x.shape[0] != wave_size:
                return x
            spec = P(dp_spec, *([None] * (x.ndim - 1)))
            return jax.lax.with_sharding_constraint(x, spec)

        return jax.tree.map(per_leaf, tree_args)

    def search_wave(params, tree, rng):
        env = _policy_net_env(base_env, params)
        rng, k_sel, k_sim = jax.random.split(rng, 3)
        tree, slots, _ = _phase1_select(tree, k_sel, cfg)
        child_states, r_edge, done_child, rets = _phase2_work(
            env, cfg, tree, slots, k_sim, constrain=constrain_slots
        )
        tree = _phase3_settle(
            tree, cfg, slots, child_states, r_edge, done_child, rets
        )
        return tree

    # Abstract arguments.
    params_abs = {
        "w1": jax.ShapeDtypeStruct((obs_dim, d_mlp), jnp.bfloat16),
        "b1": jax.ShapeDtypeStruct((d_mlp,), jnp.bfloat16),
        "w2": jax.ShapeDtypeStruct((d_mlp, base_env.num_actions), jnp.bfloat16),
    }
    capacity = num_simulations + wave_size + 1
    tree_abs = jax.eval_shape(
        lambda: tree_lib.init_tree(
            base_env.init(jax.random.PRNGKey(0)), capacity, base_env.num_actions
        )
    )
    rng_abs = jax.ShapeDtypeStruct((2,), jnp.uint32)

    pshard = {
        "w1": NamedSharding(mesh, P(None, "model")),
        "b1": NamedSharding(mesh, P("model")),
        "w2": NamedSharding(mesh, P("model", None)),
    }
    replicated = NamedSharding(mesh, P())
    tshard = jax.tree.map(lambda _: replicated, tree_abs)

    return SearchCell(
        fn=search_wave,
        arg_specs=(params_abs, tree_abs, rng_abs),
        in_shardings=(pshard, tshard, replicated),
        out_shardings=tshard,
        cfg=cfg,
    )
