"""Training launcher: data pipeline → sharded train loop → checkpoints.

Runs on any mesh (single device for smoke, production pod via dry-run).
Demonstrates the full fault-tolerance story:

* deterministic data addressing (resume = restore step counter),
* atomic + async checkpointing with keep-k GC,
* elastic restore (restart on a different mesh reshards automatically),
* optional int8 error-feedback gradient compression.

Usage (CPU-scale smoke):
  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
      --steps 20 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_reduced
from repro.models import abstract_params, init_params
from repro.training import (
    AdamWConfig,
    CheckpointManager,
    SyntheticStream,
    TrainConfig,
    adamw_init,
    make_train_step,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.smoke else get_config(args.arch)
    if args.smoke:
        cfg = dataclasses.replace(cfg, loss_chunk=64)

    train_cfg = TrainConfig(
        optimizer=AdamWConfig(lr=args.lr, warmup_steps=10, total_steps=args.steps),
        microbatches=args.microbatches,
        compress_grads=args.compress_grads,
    )
    step_fn = jax.jit(make_train_step(cfg, train_cfg), donate_argnums=(0, 1))

    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    opt_state = adamw_init(params)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params:,}")

    start_step = 0
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if mgr and mgr.latest_step() is not None:
        start_step, (params, opt_state) = mgr.restore((params, opt_state))
        print(f"restored checkpoint at step {start_step}")

    stream = SyntheticStream(cfg.vocab_size, args.batch, args.seq, seed=args.seed)

    t_last, tok_acc = time.time(), 0
    for step in range(start_step, args.steps):
        batch = jax.tree.map(jnp.asarray, stream.batch_at(step))
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        tok_acc += args.batch * args.seq
        if (step + 1) % 5 == 0 or step == start_step:
            dt = time.time() - t_last
            print(
                f"step {step + 1:5d} loss={float(metrics['loss']):.4f} "
                f"gnorm={float(metrics['grad_norm']):.3f} "
                f"lr={float(metrics['lr']):.2e} tok/s={tok_acc / max(dt, 1e-9):,.0f}"
            )
            t_last, tok_acc = time.time(), 0
        if mgr and (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, (params, opt_state))
    if mgr:
        mgr.save(args.steps, (params, opt_state), blocking=True)
        print(f"final checkpoint: step {args.steps} -> {args.ckpt_dir}")


if __name__ == "__main__":
    main()
