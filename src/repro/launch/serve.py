"""Serving launcher: batched continuous-batching engine over any arch.

Usage (CPU-scale smoke):
  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
      --requests 6 --prompt-len 12 --max-len 48
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced
from repro.models import init_params
from repro.serving import ServeConfig, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-len", type=int, default=48)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.smoke else get_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(
        cfg, params,
        ServeConfig(batch_slots=args.slots, max_len=args.max_len,
                    temperature=args.temperature, eos_token=1),
    )
    rng = np.random.default_rng(0)
    prompts = [
        list(rng.integers(2, cfg.vocab_size, size=args.prompt_len))
        for _ in range(args.requests)
    ]
    t0 = time.time()
    outputs = engine.run(prompts, max_ticks=args.max_len * 2)
    dt = time.time() - t0
    total_tokens = sum(len(o) for o in outputs)
    for i, out in enumerate(outputs):
        print(f"request {i}: generated {len(out)} tokens: {out[:12]}...")
    print(
        f"\nserved {args.requests} requests on {args.slots} slots in {dt:.1f}s "
        f"({total_tokens / max(dt, 1e-9):.1f} tok/s aggregate)"
    )


if __name__ == "__main__":
    main()
