"""Paged KV cache: a shared block pool + per-slot page tables + refcounts.

Dense slot caches give every in-flight sequence its own ``[max_len]`` KV
row, so HBM — not compute — caps how many sequences stay in flight.  The
paged layout stores K/V in a shared pool of fixed-size blocks
(``[L, num_blocks, block_size, Hkv, D]``) and addresses each slot's logical
positions through a per-slot page table (``i32[N, max_pages]``): logical
position ``t`` of slot ``n`` lives at pool row
``(table[n, t // block_size], t % block_size)``.

Blocks carry refcounts so slots can SHARE pages: sibling search slots that
fan out from one root prefill all point at the same prefix blocks
(refcount = number of sharers), and a slot only gets a private copy of a
block when it is about to WRITE into a shared one (copy-on-write).
Rollback becomes a page-table edit: dropping a suffix decrements the
refcounts of its exclusive pages back into the free pool — no cache rows
are rewritten.

Invariants (tested in tests/test_paged_evaluator.py):

* ``refcount[p]`` == number of (slot, page-index) pairs with
  ``table[n, i] == p`` and ``i < ceil(len[n] / block_size)`` — i.e. live
  table entries, counted with multiplicity.
* Table entries at page indices ``>= ceil(len[n] / block_size)`` are
  garbage (they may hold ``num_blocks`` or stale ids) and must never be
  dereferenced without clipping + kv_len masking.
* Within a live block, rows at positions ``>= len[n]`` are garbage, exactly
  like the dense contract — masked by attention, overwritten before
  visible.
* A slot writes only into blocks with ``refcount == 1`` that it owns; any
  write targeting a shared block copies it first (copy-on-write).

Everything here is functional (pure jnp) so it jits inside the async
engines' ``lax.while_loop`` carries; allocation failure cannot raise from
traced code, so it latches an ``oom`` counter that callers surface as
:class:`PagePoolExhaustedError` at the eager boundary.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (
    attention_block,
    mlp_block,
    moe_block,
    paged_tree_attention_block,
    rms_norm,
)
from .lm import KV_CACHE_FAMILIES, _layer_scan


class PagePoolExhaustedError(RuntimeError):
    """The shared KV block pool ran out of free blocks.

    Raised at eager boundaries (init / after a jitted program settles) when
    the latched ``oom`` counter is nonzero; grow ``num_blocks`` or lower
    concurrency.
    """


def num_pages(max_len: int, block_size: int) -> int:
    return -(-max_len // block_size)


def init_paged_cache(
    cfg: ModelConfig,
    n_slots: int,
    max_len: int,
    *,
    block_size: int,
    num_blocks: int,
):
    """Allocate an empty paged KV cache (pool + tables + refcounts).

    ``table`` starts filled with the out-of-range sentinel ``num_blocks``
    ("no block"), ``len`` at zero, every block free.  ``oom`` counts
    allocation requests that found no free block (latched, never reset by
    library code).
    """
    if cfg.family not in KV_CACHE_FAMILIES:
        raise ValueError(
            f"paged KV caches support families {KV_CACHE_FAMILIES}, "
            f"not {cfg.family!r}"
        )
    L = cfg.num_layers
    hkv, hd = cfg.num_kv_heads, cfg.head_dim
    mp = num_pages(max_len, block_size)
    return {
        "k": jnp.zeros((L, num_blocks, block_size, hkv, hd), cfg.dtype),
        "v": jnp.zeros((L, num_blocks, block_size, hkv, hd), cfg.dtype),
        "table": jnp.full((n_slots, mp), num_blocks, jnp.int32),
        "len": jnp.zeros((n_slots,), jnp.int32),
        "refcount": jnp.zeros((num_blocks,), jnp.int32),
        "oom": jnp.int32(0),
    }


def alloc_blocks(refcount: jax.Array, need: jax.Array):
    """Grab one free pool block per requesting row — functionally.

    ``refcount``: i32[P]; ``need``: bool[N].  The k-th requesting row (in
    row order) receives the k-th free block (in pool order), built from two
    cumsums and one drop-mode scatter — no host loop, no sort, jits inside
    while_loop bodies.

    Returns ``(blocks, refcount, n_failed)`` where ``blocks`` is i32[N]
    holding the allocated block id, or the sentinel ``P`` for rows that
    asked for nothing *or* found the pool exhausted; allocated blocks come
    back with refcount 1; ``n_failed`` counts needy rows that got nothing.
    """
    p = refcount.shape[0]
    n = need.shape[0]
    free = refcount == 0
    free_rank = jnp.cumsum(free) - 1          # rank of each free block
    req_rank = jnp.cumsum(need) - 1           # rank of each requesting row
    # rank -> block id: only the first N free blocks can be handed out this
    # call, so the map is sized N and later free blocks drop out.
    rank_to_block = (
        jnp.full((n,), p, jnp.int32)
        .at[jnp.where(free, free_rank, n)]
        .set(jnp.arange(p, dtype=jnp.int32), mode="drop")
    )
    blocks = jnp.where(
        need, rank_to_block[jnp.clip(req_rank, 0, n - 1)], p
    ).astype(jnp.int32)
    got = need & (blocks < p)
    refcount = refcount.at[blocks].add(
        jnp.where(got, 1, 0), mode="drop"
    )
    return blocks, refcount, jnp.sum(need & ~got)


def release_pages(
    refcount: jax.Array,
    table: jax.Array,      # [R, max_pages] — rows being rolled back
    lo: jax.Array,         # i32[R] — first page index to release
    hi: jax.Array,         # i32[R] — one past the last page index
):
    """Decref every table entry in ``[lo[r], hi[r])`` of each row.

    The page-table *edit* that replaces a dense cache rewrite on rollback:
    blocks whose refcount hits zero rejoin the free pool; shared blocks
    simply lose one sharer.
    """
    r, mp = table.shape
    p = refcount.shape[0]
    pages = jnp.arange(mp)
    live = (pages[None, :] >= lo[:, None]) & (pages[None, :] < hi[:, None])
    idx = jnp.where(live, table, p).reshape(-1)
    return refcount.at[idx].add(
        jnp.where(live.reshape(-1), -1, 0), mode="drop"
    )


def blocks_in_use(cache) -> jax.Array:
    """Number of pool blocks currently allocated (refcount > 0)."""
    return jnp.sum(cache["refcount"] > 0)


def gather_pages(cache):
    """Debug/oracle helper: materialize dense per-slot K/V views.

    Returns ``(k, v)`` of shape ``[L, N, max_pages·block_size, Hkv, D]``;
    positions ``>= len[n]`` are garbage per the contract.
    """
    p = cache["k"].shape[1]
    t = jnp.clip(cache["table"], 0, p - 1)

    def g(pool):
        out = pool[:, t]                      # [L, N, mp, bs, hkv, hd]
        l_, n_, mp_, bs_, hkv_, hd_ = out.shape
        return out.reshape(l_, n_, mp_ * bs_, hkv_, hd_)

    return g(cache["k"]), g(cache["v"])


def paged_decode_step(params, cfg: ModelConfig, token, cache):
    """One decode step over a paged cache; pure write-and-attend.

    The caller owns all page bookkeeping (COW, allocation, refcounts, len)
    and passes the resolved physical targets in the cache dict:

    * ``write_block``/``write_off`` (i32[N]): where each row's new K/V entry
      lands; block id == pool size means "no write" (masked row / exhausted
      pool) and the scatter drops it.
    * ``pos`` (i32[N]): the query's absolute position (RoPE).
    * ``len`` (i32[N]): the ATTEND length — includes the token being written
      for rows that write, excludes it for masked rows.

    Returns ``(logits [N, V], cache with updated pools)``.
    """
    if cfg.family not in KV_CACHE_FAMILIES:
        raise ValueError(
            f"paged_decode_step supports families {KV_CACHE_FAMILIES}, "
            f"not {cfg.family!r}"
        )
    token = jnp.asarray(token).reshape(-1, 1)
    x = params["embed"][token]
    positions = cache["pos"][:, None]

    def body(x, xs):
        bp, pk, pv = xs
        layer_cache = {
            "k": pk,
            "v": pv,
            "table": cache["table"],
            "len": cache["len"],
            "write_block": cache["write_block"],
            "write_off": cache["write_off"],
        }
        h, nc = attention_block(
            bp["attn"], cfg, rms_norm(x, bp["attn_norm"], cfg.rms_eps),
            positions, cache=layer_cache,
        )
        x = x + h
        if cfg.family == "moe":
            h, _ = moe_block(
                bp["moe"], cfg, rms_norm(x, bp["mlp_norm"], cfg.rms_eps)
            )
        else:
            h = mlp_block(bp["mlp"], rms_norm(x, bp["mlp_norm"], cfg.rms_eps))
        return x + h, (nc["k"], nc["v"])

    x, (ks, vs) = _layer_scan(
        body, x, (params["blocks"], cache["k"], cache["v"]), cfg
    )
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    head = params.get("lm_head", None)
    logits = x @ head if head is not None else x @ params["embed"].T
    return logits[:, -1, :], dict(cache, k=ks, v=vs)


def paged_decode_frontier(params, cfg: ModelConfig, tokens, cache):
    """Score ``A`` candidate next tokens per row over a paged prefix.

    Read-only twin of :func:`repro.models.lm.decode_frontier` for the paged
    layout: ``tokens`` is ``[N, A]`` candidate alternatives for position
    ``cache['len']``; the shared prefix is addressed through ``table`` and
    the pool is NEVER written — each candidate's own K/V entry comes back in
    the returned ``spec`` (``{"k": [L, N, A, Hkv, D], "v": ...}``) for the
    caller to commit via its own page bookkeeping.

    ``cache`` needs only ``k``/``v`` pools, ``table``, ``len`` (attend
    length == candidate position) — no write keys.
    """
    if cfg.family not in KV_CACHE_FAMILIES:
        raise ValueError(
            f"paged_decode_frontier supports families {KV_CACHE_FAMILIES}, "
            f"not {cfg.family!r}"
        )
    tokens = jnp.asarray(tokens)
    n, a = tokens.shape
    x = params["embed"][tokens]
    cur_len = jnp.asarray(cache["len"], jnp.int32)
    positions = jnp.broadcast_to(
        cur_len[:, None] if jnp.ndim(cur_len) == 1 else cur_len, (n, a)
    )

    def body(x, xs):
        bp, pk, pv = xs
        h, ks, vs = paged_tree_attention_block(
            bp["attn"], cfg, rms_norm(x, bp["attn_norm"], cfg.rms_eps),
            positions, pk, pv, cache["table"], cur_len,
        )
        x = x + h
        if cfg.family == "moe":
            h, _ = moe_block(
                bp["moe"], cfg, rms_norm(x, bp["mlp_norm"], cfg.rms_eps)
            )
        else:
            h = mlp_block(bp["mlp"], rms_norm(x, bp["mlp_norm"], cfg.rms_eps))
        return x + h, (ks, vs)

    x, (ks, vs) = _layer_scan(
        body, x, (params["blocks"], cache["k"], cache["v"]), cfg
    )
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    head = params.get("lm_head", None)
    logits = x @ head if head is not None else x @ params["embed"].T
    return logits, {"k": ks, "v": vs}
