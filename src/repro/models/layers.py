"""Transformer building blocks: RMSNorm, RoPE, GQA attention, SwiGLU, MoE.

Everything is a pure function over a parameter dict.  Attention defaults to a
chunked online-softmax formulation ("flash in jnp") whose memory is
O(S·chunk) instead of O(S²) — this is also the oracle the Pallas kernel in
``repro.kernels.flash_attention`` is validated against, and the path the
multi-pod dry-run compiles (Pallas cannot target the CPU backend).
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Norms & rotary embeddings
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dtype)


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, D]; positions: [B, S] (or [S])."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                       # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B, S, D/2]
    cos = jnp.cos(angles)[..., None, :]                       # [B, S, 1, D/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def chunked_attention(
    q: jax.Array,           # [B, Sq, Hq, D]
    k: jax.Array,           # [B, Sk, Hkv, D]
    v: jax.Array,           # [B, Sk, Hkv, D]
    *,
    causal: bool = True,
    q_offset: jax.Array | int = 0,
    kv_len: Optional[jax.Array] = None,   # valid KV prefix length (decode)
    chunk: int = 1024,
) -> jax.Array:
    """Online-softmax attention over KV chunks (GQA-aware).

    ``q_offset`` is the absolute position of q[0] (for causal masking during
    chunked prefill / decode); it may be a scalar or a per-row ``[B]`` vector
    (ragged chunked catch-up: every row decodes its chunk at its own
    offset).  ``kv_len`` masks the KV tail (cache slots that have not been
    written yet); scalar or per-row ``[B]``.
    """
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    group = hq // hkv
    scale = 1.0 / math.sqrt(d)
    chunk = min(chunk, sk)
    if sk % chunk != 0:
        pad = chunk - sk % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_len = jnp.minimum(kv_len, sk) if kv_len is not None else jnp.int32(sk)
        sk = sk + pad
    n_chunks = sk // chunk

    # Inputs stay in their storage dtype (bf16 on TPU); matmuls accumulate in
    # f32 via preferred_element_type — no f32 copy of K/V ever materializes
    # (an f32 cache copy doubles HBM traffic and, sharded, doubles any
    # resharding collective — see EXPERIMENTS.md §Perf iteration B1).
    qf = q.reshape(b, sq, hkv, group, d)
    kc = k.reshape(b, n_chunks, chunk, hkv, d)
    vc = v.reshape(b, n_chunks, chunk, hkv, d)

    # [Bq, Sq] with Bq in {1, B}: scalar offsets broadcast, vector offsets
    # give each row its own causal frontier.
    q_pos = (
        jnp.asarray(q_offset, jnp.int32).reshape(-1, 1) + jnp.arange(sq)
    )
    kl = None if kv_len is None else jnp.asarray(kv_len).reshape(-1)

    def body(carry, xs):
        m, l, acc = carry
        k_i, v_i, idx = xs
        s = jnp.einsum(
            "bqhgd,bkhd->bhgqk", qf, k_i,
            preferred_element_type=jnp.float32,
        ) * scale                                             # [B,Hkv,G,Sq,C]
        kv_pos = idx * chunk + jnp.arange(chunk)              # [C]
        mask = jnp.ones((q_pos.shape[0], sq, chunk), jnp.bool_)
        if causal:
            mask = mask & (q_pos[:, :, None] >= kv_pos[None, None, :])
        if kl is not None:
            mask = mask & (kv_pos[None, None, :] < kl[:, None, None])
        mask = mask[:, None, None]                       # [B?,1,1,Sq,C]
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(v_i.dtype), v_i,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l, acc), None

    init = (
        jnp.full((b, hkv, group, sq), NEG_INF, jnp.float32),
        jnp.zeros((b, hkv, group, sq), jnp.float32),
        jnp.zeros((b, hkv, group, sq, d), jnp.float32),
    )
    xs = (
        jnp.moveaxis(kc, 1, 0),
        jnp.moveaxis(vc, 1, 0),
        jnp.arange(n_chunks),
    )
    (m, l, acc), _ = jax.lax.scan(body, init, xs)
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    out = jnp.moveaxis(out.reshape(b, hq, sq, d), 1, 2)       # [B,Sq,Hq,D]
    return out.astype(q.dtype)


def decode_attention(
    q: jax.Array,          # [B, 1, Hq, D]
    k_cache: jax.Array,    # [B, S, Hkv, D]
    v_cache: jax.Array,    # [B, S, Hkv, D]
    kv_len: jax.Array,     # [] or [B] — number of valid cache entries
) -> jax.Array:
    """Single-token attention over a (possibly long) KV cache."""
    b, _, hq, d = q.shape
    _, s, hkv, _ = k_cache.shape
    group = hq // hkv
    scale = 1.0 / math.sqrt(d)
    # Storage-dtype streaming with f32 accumulation (see §Perf iteration B1):
    # never materialize an f32 copy of the KV cache.
    qf = q.reshape(b, hkv, group, d)
    scores = jnp.einsum(
        "bhgd,bshd->bhgs", qf, k_cache, preferred_element_type=jnp.float32
    ) * scale
    pos = jnp.arange(s)
    valid = pos[None, :] < jnp.reshape(kv_len, (-1, 1))       # [B or 1, S]
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, 1, hq, d).astype(q.dtype)


def paged_decode_attention(
    q: jax.Array,           # [B, 1, Hq, D]
    pool_k: jax.Array,      # [P, block_size, Hkv, D] — shared block pool
    pool_v: jax.Array,      # [P, block_size, Hkv, D]
    page_table: jax.Array,  # [B, n_pages] i32
    kv_len: jax.Array,      # [] or [B]
) -> jax.Array:
    """Single-token attention over a paged (block-sparse) KV cache.

    jnp oracle for the Pallas kernel in ``kernels/decode_attention``: gather
    each row's pages into a dense view, then run the ragged decode path.
    Table entries beyond ``ceil(kv_len / block_size)`` may be garbage — they
    are clipped into pool range and their positions masked by ``kv_len``.
    """
    b = q.shape[0]
    p, block_size, hkv, d = pool_k.shape
    n_pages = page_table.shape[1]
    tab = jnp.clip(page_table.astype(jnp.int32), 0, p - 1)
    k = pool_k[tab].reshape(b, n_pages * block_size, hkv, d)
    v = pool_v[tab].reshape(b, n_pages * block_size, hkv, d)
    return decode_attention(q, k, v, kv_len)


def tree_decode_attention(
    q: jax.Array,           # [B, A, Hq, D] — A speculative queries per row
    k_cache: jax.Array,     # [B, S, Hkv, D]
    v_cache: jax.Array,     # [B, S, Hkv, D]
    k_spec: jax.Array,      # [B, A, Hkv, D] — speculative tail keys
    v_spec: jax.Array,      # [B, A, Hkv, D]
    kv_len: jax.Array,      # [] or [B] — number of valid cache entries
    tree_mask: Optional[jax.Array] = None,   # [A, A] bool; default identity
) -> jax.Array:
    """Tree-batched speculative decode: A candidate tokens share one prefix.

    Every query sits at absolute position ``kv_len`` and attends to the full
    valid prefix plus the speculative tail entries ``tree_mask[i, :]`` allows
    (identity by default: each candidate sees only its own K/V).  The tail
    K/V live OUTSIDE the cache — nothing here writes cache state, which is
    what makes the frontier scores safe to throw away or commit later.

    jnp oracle for the Pallas kernel in ``kernels/decode_attention``.
    """
    b, a, hq, d = q.shape
    _, s, hkv, _ = k_cache.shape
    group = hq // hkv
    scale = 1.0 / math.sqrt(d)
    qf = q.reshape(b, a, hkv, group, d)
    scores = jnp.einsum(
        "bahgd,bshd->bahgs", qf, k_cache, preferred_element_type=jnp.float32
    ) * scale                                                  # [B,A,Hkv,G,S]
    pos = jnp.arange(s)
    valid = pos[None, :] < jnp.reshape(kv_len, (-1, 1))        # [B or 1, S]
    scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    tail = jnp.einsum(
        "bahgd,bjhd->bahgj", qf, k_spec, preferred_element_type=jnp.float32
    ) * scale                                                  # [B,A,Hkv,G,A]
    if tree_mask is None:
        tree_mask = jnp.eye(a, dtype=jnp.bool_)
    attend = jnp.asarray(tree_mask).astype(jnp.bool_)
    tail = jnp.where(attend[None, :, None, None, :], tail, NEG_INF)
    full = jnp.concatenate([scores, tail], axis=-1)            # [B,A,Hkv,G,S+A]
    p = jax.nn.softmax(full, axis=-1)
    v_full = jnp.concatenate([v_cache, v_spec], axis=1)        # [B,S+A,Hkv,D]
    out = jnp.einsum(
        "bahgs,bshd->bahgd", p.astype(v_full.dtype), v_full,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, a, hq, d).astype(q.dtype)


def paged_tree_decode_attention(
    q: jax.Array,           # [B, A, Hq, D]
    pool_k: jax.Array,      # [P, block_size, Hkv, D]
    pool_v: jax.Array,      # [P, block_size, Hkv, D]
    page_table: jax.Array,  # [B, n_pages] i32
    k_spec: jax.Array,      # [B, A, Hkv, D]
    v_spec: jax.Array,      # [B, A, Hkv, D]
    kv_len: jax.Array,      # [] or [B]
    tree_mask: Optional[jax.Array] = None,
) -> jax.Array:
    """Tree-batched speculative decode over a paged prefix (jnp oracle).

    Same gather-then-dense strategy as ``paged_decode_attention``: table
    entries beyond the live pages may be garbage — clipped into pool range,
    positions masked by ``kv_len``.
    """
    b = q.shape[0]
    p, block_size, hkv, d = pool_k.shape
    n_pages = page_table.shape[1]
    tab = jnp.clip(page_table.astype(jnp.int32), 0, p - 1)
    k = pool_k[tab].reshape(b, n_pages * block_size, hkv, d)
    v = pool_v[tab].reshape(b, n_pages * block_size, hkv, d)
    return tree_decode_attention(q, k, v, k_spec, v_spec, kv_len, tree_mask)


# ---------------------------------------------------------------------------
# Attention module (projections + rope + cache handling)
# ---------------------------------------------------------------------------


def init_attention(key, cfg, d_model=None, dtype=None):
    d = d_model or cfg.d_model
    hd, hq, hkv = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
    dtype = dtype or cfg.dtype
    k1, k2, k3, k4 = jax.random.split(key, 4)
    std = 0.02
    p = {
        "wq": (jax.random.normal(k1, (d, hq * hd)) * std).astype(dtype),
        "wk": (jax.random.normal(k2, (d, hkv * hd)) * std).astype(dtype),
        "wv": (jax.random.normal(k3, (d, hkv * hd)) * std).astype(dtype),
        "wo": (jax.random.normal(k4, (hq * hd, d)) * std).astype(dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    return p


def attention_qkv(p, cfg, x, positions, rope: bool = True):
    b, s, _ = x.shape
    hd, hq, hkv = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, hq, hd)
    k = k.reshape(b, s, hkv, hd)
    v = v.reshape(b, s, hkv, hd)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _use_pallas(cfg) -> bool:
    return getattr(cfg, "attn_impl", "xla") == "pallas"


def attention_block(
    p,
    cfg,
    x,                       # [B, S, d]
    positions,               # [B, S]
    *,
    causal: bool = True,
    rope: bool = True,
    cache=None,              # optional dict(k, v, len) — decode/prefill cache
):
    """Full attention block; returns (out, new_cache).

    ``cfg.attn_impl == 'pallas'`` routes the no-cache causal path through the
    flash-attention TPU kernel and single-token decode — scalar or per-slot
    vector cache lengths — through the split-KV decode kernel (interpret
    mode on CPU); paths the kernels don't cover (chunked prefill with
    offsets) fall back to the jnp oracle — which the kernels are verified
    against bit-for-bit in tests/test_kernels.py.
    """
    q, k, v = attention_qkv(p, cfg, x, positions, rope=rope)
    if cache is not None and "table" in cache:
        # Paged decode: K/V live in a shared block pool addressed through a
        # per-row page table.  The caller pre-computes the physical write
        # target — ``write_block``/``write_off`` per row, with block id == P
        # (out of range) meaning "do not write" (masked slot / exhausted
        # pool) — and ``len`` is the ATTEND length (it already counts the
        # token being written, where one is).  Drop-mode scatter keeps the
        # whole thing one fused batched op.
        assert x.shape[1] == 1, "paged cache supports single-token decode"
        wb, wo = cache["write_block"], cache["write_off"]
        kc = cache["k"].at[wb, wo].set(
            k[:, 0].astype(cache["k"].dtype), mode="drop"
        )
        vc = cache["v"].at[wb, wo].set(
            v[:, 0].astype(cache["v"].dtype), mode="drop"
        )
        if _use_pallas(cfg):
            from ..kernels.decode_attention.ops import (
                paged_decode_attention as _pdk,
            )

            out = _pdk(q[:, 0], kc, vc, cache["table"], cache["len"])[:, None]
        else:
            out = paged_decode_attention(
                q, kc, vc, cache["table"], cache["len"]
            )
        new_cache = dict(cache, k=kc, v=vc)
        b, s = x.shape[:2]
        out = out.reshape(b, s, cfg.num_heads * cfg.head_dim) @ p["wo"]
        return out, new_cache
    if cache is None:
        if _use_pallas(cfg) and causal and q.shape[1] == k.shape[1]:
            from ..kernels.flash_attention.ops import flash_attention

            sq = q.shape[1]
            bq = max(1, min(256, sq))
            while sq % bq:
                bq //= 2
            out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bq)
        else:
            out = chunked_attention(q, k, v, causal=causal, chunk=cfg.attn_chunk)
        new_cache = None
    else:
        # Write new K/V at cache['len']: prefill writes S entries from 0,
        # decode writes one entry at len.  ``len`` may be a scalar (uniform
        # batch: dry-run cells) or a per-slot [B] vector (continuous-batching
        # engine; decode only).
        start = cache["len"]
        if jnp.ndim(start) == 0:
            kc = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), start, axis=1
            )
            vc = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), start, axis=1
            )
        elif x.shape[1] == 1:
            bidx = jnp.arange(x.shape[0])
            kc = cache["k"].at[bidx, start].set(k[:, 0].astype(cache["k"].dtype))
            vc = cache["v"].at[bidx, start].set(v[:, 0].astype(cache["v"].dtype))
        else:
            # Ragged chunk write (chunked catch-up refill): every row writes
            # its S new entries at its own offset; positions past the cache
            # end are dropped (rows already caught up write only into their
            # garbage-beyond-len region, which stays garbage).
            bidx = jnp.arange(x.shape[0])[:, None]
            pos = start[:, None] + jnp.arange(x.shape[1])[None, :]
            kc = cache["k"].at[bidx, pos].set(
                k.astype(cache["k"].dtype), mode="drop"
            )
            vc = cache["v"].at[bidx, pos].set(
                v.astype(cache["v"].dtype), mode="drop"
            )
        new_len = jnp.minimum(start + x.shape[1], cache["k"].shape[1])
        if x.shape[1] == 1:
            # The decode kernel takes scalar or per-slot [B] cache lengths,
            # so the ragged continuous-batching path is covered too.
            if _use_pallas(cfg):
                from ..kernels.decode_attention.ops import decode_attention as _dk

                bk = max(1, min(512, kc.shape[1]))
                while kc.shape[1] % bk:
                    bk //= 2
                out = _dk(q[:, 0], kc, vc, new_len, block_k=bk)[:, None]
            else:
                out = decode_attention(q, kc, vc, new_len)
        else:
            out = chunked_attention(
                q, kc, vc, causal=causal, q_offset=start, kv_len=new_len,
                chunk=cfg.attn_chunk,
            )
        new_cache = {"k": kc, "v": vc, "len": new_len}
    b, s = x.shape[:2]
    out = out.reshape(b, s, cfg.num_heads * cfg.head_dim) @ p["wo"]
    return out, new_cache


def tree_attention_block(p, cfg, x, positions, k_cache, v_cache, kv_len):
    """Frontier attention: ``A`` speculative queries over a READ-ONLY cache.

    ``x`` is ``[N, A, d]`` — the A candidate tokens of each slot, all sitting
    at absolute position ``kv_len`` (the same ``positions`` row for every
    candidate).  Unlike :func:`attention_block`, the cache is never written:
    each candidate's own K/V ride along as the speculative tail
    (identity tree mask), and the caller decides which candidate — if any —
    to commit later.  Returns ``(out [N, A, d], k_spec, v_spec)``.
    """
    q, k, v = attention_qkv(p, cfg, x, positions)
    if _use_pallas(cfg):
        from ..kernels.decode_attention.ops import tree_decode_attention as _tk

        s = k_cache.shape[1]
        bk = max(1, min(512, s))
        while s % bk:
            bk //= 2
        out = _tk(q, k_cache, v_cache, k, v, kv_len, block_k=bk)
    else:
        out = tree_decode_attention(q, k_cache, v_cache, k, v, kv_len)
    n, a = x.shape[:2]
    out = out.reshape(n, a, cfg.num_heads * cfg.head_dim) @ p["wo"]
    return out, k, v


def paged_tree_attention_block(
    p, cfg, x, positions, pool_k, pool_v, page_table, kv_len
):
    """Frontier attention over a paged prefix (read-only, pool never written).

    Same contract as :func:`tree_attention_block` with the shared prefix
    addressed through a per-row page table.
    """
    q, k, v = attention_qkv(p, cfg, x, positions)
    if _use_pallas(cfg):
        from ..kernels.decode_attention.ops import (
            paged_tree_decode_attention as _ptk,
        )

        out = _ptk(q, pool_k, pool_v, page_table, k, v, kv_len)
    else:
        out = paged_tree_decode_attention(
            q, pool_k, pool_v, page_table, k, v, kv_len
        )
    n, a = x.shape[:2]
    out = out.reshape(n, a, cfg.num_heads * cfg.head_dim) @ p["wo"]
    return out, k, v


def cross_attention_block(p, cfg, x, enc_kv):
    """Enc-dec cross attention: q from x, K/V precomputed from encoder."""
    b, s, _ = x.shape
    hd, hq = cfg.head_dim, cfg.num_heads
    q = (x @ p["wq"]).reshape(b, s, hq, hd)
    if cfg.qkv_bias:
        q = q + p["bq"].reshape(hq, hd)
    out = chunked_attention(
        q, enc_kv["k"], enc_kv["v"], causal=False,
        chunk=min(cfg.attn_chunk, enc_kv["k"].shape[1]),
    )
    return out.reshape(b, s, hq * hd) @ p["wo"]


# ---------------------------------------------------------------------------
# Dense SwiGLU MLP
# ---------------------------------------------------------------------------


def init_mlp(key, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    std = 0.02
    return {
        "w_gate": (jax.random.normal(k1, (d_model, d_ff)) * std).astype(dtype),
        "w_up": (jax.random.normal(k2, (d_model, d_ff)) * std).astype(dtype),
        "w_down": (jax.random.normal(k3, (d_ff, d_model)) * std).astype(dtype),
    }


def mlp_block(p, x):
    return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]


# ---------------------------------------------------------------------------
# MoE: top-k routing with capacity-bounded scatter dispatch.
#
# Dispatch avoids the O(T·E·C) one-hot tensor: token positions inside each
# expert come from a cumsum over the [T, E] assignment matrix, tokens are
# scattered into an [E·C, d] buffer, experts run as one batched matmul
# ([E, C, d] @ [E, d, f] — MXU-shaped, EP-shardable on E), and results gather
# back with gate weighting.  HLO FLOPs ≈ active-expert FLOPs (top-k/E of
# dense), which keeps the roofline "useful compute" ratio honest.
# ---------------------------------------------------------------------------


def init_moe(key, cfg, dtype):
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    std = 0.02
    p = {
        "router": (jax.random.normal(k1, (d, e)) * std).astype(jnp.float32),
        "w_gate": (jax.random.normal(k2, (e, d, f)) * std).astype(dtype),
        "w_up": (jax.random.normal(k3, (e, d, f)) * std).astype(dtype),
        "w_down": (jax.random.normal(k4, (e, f, d)) * std).astype(dtype),
    }
    if cfg.shared_expert_d_ff:
        p["shared"] = init_mlp(k5, d, cfg.shared_expert_d_ff, dtype)
    return p


def moe_block(p, cfg, x):
    """MoE layer.  x: [B, S, d] → (out [B, S, d], aux_loss []).

    Under a mesh with a >1 ``model`` axis the routed experts run inside a
    ``shard_map`` (true expert parallelism): tokens stay sharded over the
    data axes and replicated over ``model``; each model shard dispatches to
    its local experts with *local* capacity and the combine is one psum over
    ``model`` — the same communication class as a Megatron MLP.  Without a
    mesh the local dense-buffer path below runs (smoke tests, CPU search).
    """
    from ..distributed.sharding import ambient_abstract_mesh

    mesh = ambient_abstract_mesh()
    try:
        axes = dict(mesh.shape)
    except (AttributeError, TypeError):
        # No ambient mesh (None) or a mesh whose .shape isn't dict-able
        # (older JAX AbstractMesh): fall back to the unsharded local path.
        axes = {}
    tp = axes.get("model", 1)
    if tp > 1 and cfg.num_experts % tp == 0:
        out, aux = _moe_block_sharded(p, cfg, x, mesh)
        if "shared" in p:
            out = out + mlp_block(p["shared"], x)
        return out, aux
    return _moe_block_local(p, cfg, x)


def _moe_block_sharded(p, cfg, x, mesh):
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def inner(xb, router, wg, wu, wd):
        bl, sl, _ = xb.shape
        t = bl * sl
        xt = xb.reshape(t, d)
        e_loc = wg.shape[0]
        e_off = jax.lax.axis_index("model") * e_loc

        logits = xt.astype(jnp.float32) @ router                 # [T, E]
        if cfg.num_experts_real is not None and cfg.num_experts_real < e:
            logits = jnp.where(jnp.arange(e) >= cfg.num_experts_real, -1e30, logits)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_idx = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

        density = jnp.mean(
            jax.nn.one_hot(expert_idx[:, 0], e, dtype=jnp.float32), axis=0
        )
        aux = jnp.sum(density * jnp.mean(probs, axis=0)) * e * cfg.router_aux_weight
        if dp:
            aux = jax.lax.pmean(aux, dp)

        capacity = int(max(1, math.ceil(t * k / e * cfg.capacity_factor)))
        flat_e = expert_idx.reshape(-1)                          # [T*k]
        local = (flat_e >= e_off) & (flat_e < e_off + e_loc)
        local_e = jnp.clip(flat_e - e_off, 0, e_loc - 1)
        onehot = jnp.where(
            local[:, None],
            jax.nn.one_hot(local_e, e_loc, dtype=jnp.int32),
            0,
        )
        pos = jnp.take_along_axis(
            jnp.cumsum(onehot, axis=0) - onehot, local_e[:, None], axis=1
        )[:, 0]
        keep = local & (pos < capacity)
        slot = jnp.where(
            keep, local_e * capacity + jnp.minimum(pos, capacity - 1),
            e_loc * capacity,
        )
        buf = jnp.zeros((e_loc * capacity + 1, d), xb.dtype)
        buf = buf.at[slot].set(jnp.repeat(xt, k, axis=0))
        expert_in = buf[: e_loc * capacity].reshape(e_loc, capacity, d)

        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, wg))
        h = h * jnp.einsum("ecd,edf->ecf", expert_in, wu)
        expert_out = jnp.einsum("ecf,efd->ecd", h, wd)

        flat_out = jnp.concatenate(
            [expert_out.reshape(e_loc * capacity, d),
             jnp.zeros((1, d), xb.dtype)], axis=0,
        )
        gathered = flat_out[slot].reshape(t, k, d)
        gates = (gate_vals * keep.reshape(t, k)).astype(xb.dtype)
        out = jnp.einsum("tkd,tk->td", gathered, gates)
        out = jax.lax.psum(out, "model")                         # EP combine
        return out.reshape(bl, sl, d), aux

    P = jax.sharding.PartitionSpec
    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    out, aux = jax.shard_map(
        inner,
        mesh=mesh,
        in_specs=(
            P(dp_spec, None, None),
            P(),
            P("model", None, None),
            P("model", None, None),
            P("model", None, None),
        ),
        out_specs=(P(dp_spec, None, None), P()),
        check_vma=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return out, aux


def _moe_block_local(p, cfg, x):
    """Single-device reference MoE (dense scatter dispatch)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    t = b * s
    xt = x.reshape(t, d)

    logits = (xt.astype(jnp.float32) @ p["router"])            # [T, E]
    if cfg.num_experts_real is not None and cfg.num_experts_real < e:
        pad_mask = jnp.arange(e) >= cfg.num_experts_real
        logits = jnp.where(pad_mask, -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)            # [T, k]
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Load-balancing auxiliary loss (Switch-style).
    density = jnp.mean(
        jax.nn.one_hot(expert_idx[:, 0], e, dtype=jnp.float32), axis=0
    )
    density_proxy = jnp.mean(probs, axis=0)
    aux = jnp.sum(density * density_proxy) * e * cfg.router_aux_weight

    capacity = int(max(1, math.ceil(t * k / e * cfg.capacity_factor)))

    # Position of each (token, slot) within its expert's buffer.
    flat_expert = expert_idx.reshape(-1)                        # [T*k]
    onehot = jax.nn.one_hot(flat_expert, e, dtype=jnp.int32)    # [T*k, E]
    pos_in_expert = (jnp.cumsum(onehot, axis=0) - onehot)       # [T*k, E]
    pos = jnp.take_along_axis(
        pos_in_expert, flat_expert[:, None], axis=1
    )[:, 0]                                                     # [T*k]
    keep = pos < capacity
    slot = flat_expert * capacity + jnp.minimum(pos, capacity - 1)
    slot = jnp.where(keep, slot, e * capacity)                  # overflow bin

    buf = jnp.zeros((e * capacity + 1, d), x.dtype)
    tok_rep = jnp.repeat(xt, k, axis=0)                         # [T*k, d]
    buf = buf.at[slot].set(tok_rep)                             # last-write wins
    expert_in = buf[: e * capacity].reshape(e, capacity, d)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, p["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", expert_in, p["w_up"])
    expert_out = jnp.einsum("ecf,efd->ecd", h, p["w_down"])

    flat_out = jnp.concatenate(
        [expert_out.reshape(e * capacity, d), jnp.zeros((1, d), x.dtype)], axis=0
    )
    gathered = flat_out[slot].reshape(t, k, d)
    gates = (gate_vals * keep.reshape(t, k)).astype(x.dtype)
    out = jnp.einsum("tkd,tk->td", gathered, gates).reshape(b, s, d)

    if "shared" in p:
        out = out + mlp_block(p["shared"], x)
    return out, aux
