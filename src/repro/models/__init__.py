from .config import ModelConfig
from .lm import (
    KV_CACHE_FAMILIES,
    abstract_params,
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
    prefill,
    prefill_ragged,
)

__all__ = [
    "KV_CACHE_FAMILIES",
    "ModelConfig",
    "abstract_params",
    "decode_step",
    "forward",
    "init_cache",
    "init_params",
    "loss_fn",
    "prefill",
    "prefill_ragged",
]
