from .config import ModelConfig
from .lm import (
    abstract_params,
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
    prefill,
)

__all__ = [
    "ModelConfig",
    "abstract_params",
    "decode_step",
    "forward",
    "init_cache",
    "init_params",
    "loss_fn",
    "prefill",
]
