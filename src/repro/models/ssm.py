"""Mamba-2 (SSD — state-space duality) blocks: chunked scan + O(1) decode.

The chunked formulation (Dao & Gu, arXiv:2405.21060) splits the sequence into
chunks of length ``Q``: a quadratic attention-like *intra-chunk* term (MXU
friendly) and a sequential *inter-chunk* state pass (tiny).  This jnp
implementation is the oracle for the ``repro.kernels.ssd_scan`` Pallas kernel
and the path compiled by the dry-run.

Decode keeps a constant-size recurrent state — the reason the ``long_500k``
cell is runnable for SSM/hybrid architectures only.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init_ssm_block(key, cfg, dtype):
    d, di, n, h, kk = (
        cfg.d_model,
        cfg.d_inner,
        cfg.ssm_state,
        cfg.ssm_heads,
        cfg.conv_kernel,
    )
    ks = jax.random.split(key, 8)
    std = 0.02
    return {
        "in_x": (jax.random.normal(ks[0], (d, di)) * std).astype(dtype),
        "in_z": (jax.random.normal(ks[1], (d, di)) * std).astype(dtype),
        "in_B": (jax.random.normal(ks[2], (d, n)) * std).astype(dtype),
        "in_C": (jax.random.normal(ks[3], (d, n)) * std).astype(dtype),
        "in_dt": (jax.random.normal(ks[4], (d, h)) * std).astype(dtype),
        "conv_x": (jax.random.normal(ks[5], (cfg.conv_kernel, di)) * std).astype(dtype),
        "conv_B": (jax.random.normal(ks[6], (cfg.conv_kernel, n)) * std).astype(dtype),
        "conv_C": (jax.random.normal(ks[7], (cfg.conv_kernel, n)) * std).astype(dtype),
        "A_log": jnp.zeros((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "norm": jnp.ones((di,), dtype),
        "out": (jax.random.normal(jax.random.fold_in(key, 9), (di, d)) * std).astype(dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv along S.  x: [B, S, C]; w: [K, C]."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + pad[:, i : i + x.shape[1], :] * w[i]
    return out


def _conv_step(window: jax.Array, x_t: jax.Array, w: jax.Array):
    """One causal-conv step.  window: [B, K-1, C] (previous inputs)."""
    full = jnp.concatenate([window, x_t[:, None, :]], axis=1)    # [B, K, C]
    out = jnp.einsum("bkc,kc->bc", full, w)
    return out, full[:, 1:, :]


def ssd_chunked(
    xdt: jax.Array,    # [B, S, H, P]   (x pre-multiplied by dt)
    dA: jax.Array,     # [B, S, H]      (dt * A, negative)
    Bmat: jax.Array,   # [B, S, N]
    Cmat: jax.Array,   # [B, S, N]
    chunk: int,
    h0: jax.Array | None = None,   # [B, H, P, N] initial state
):
    """Chunked SSD scan; returns (y [B,S,H,P], h_final [B,H,P,N])."""
    b, s, h, p = xdt.shape
    n = Bmat.shape[-1]
    q = min(chunk, s)
    s_orig = s
    if s % q != 0:
        # Pad with dt=0 tokens: decay exp(0)=1 and zero state contribution,
        # so the final state is exact and padded outputs are discarded.
        pad = q - s % q
        xdt = jnp.pad(xdt, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0)))
        Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0)))
        s = s + pad
    nc = s // q

    xdt = xdt.astype(jnp.float32).reshape(b, nc, q, h, p)
    dA = dA.astype(jnp.float32).reshape(b, nc, q, h)
    Bc = Bmat.astype(jnp.float32).reshape(b, nc, q, n)
    Cc = Cmat.astype(jnp.float32).reshape(b, nc, q, n)

    cum = jnp.cumsum(dA, axis=2)                                  # [B,nc,Q,H]
    total = cum[:, :, -1, :]                                      # [B,nc,H]

    # ---- intra-chunk quadratic term -------------------------------------
    cb = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)                    # [B,nc,Q,Q]
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]           # [B,nc,Q,Q,H]
    tri = jnp.tril(jnp.ones((q, q), jnp.bool_))
    decay = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = cb[..., None] * decay                                # [B,nc,Q,Q,H]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, xdt)

    # ---- inter-chunk state pass ------------------------------------------
    # State contribution of each chunk (decayed to chunk end):
    w_end = jnp.exp(total[:, :, None, :] - cum)                   # [B,nc,Q,H]
    s_chunk = jnp.einsum("bcqh,bcqn,bcqhp->bchpn", w_end, Bc, xdt)

    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)

    def body(carry, xs):
        h_prev = carry
        s_c, tot_c = xs                                           # [B,H,P,N], [B,H]
        h_new = h_prev * jnp.exp(tot_c)[:, :, None, None] + s_c
        return h_new, h_prev

    (h_final, h_prevs) = jax.lax.scan(
        body,
        h0,
        (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(total, 1, 0)),
    )
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                         # [B,nc,H,P,N]

    y_inter = jnp.einsum("bcqn,bchpn->bcqhp", Cc, h_prevs) * jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(b, s, h, p)[:, :s_orig]
    return y, h_final


def ssm_block(p, cfg, u, *, cache=None, return_cache: bool = False):
    """Mamba-2 block.  u: [B, S, d] → (out, new_cache).

    ``cache``: dict(conv [B, K-1, di+2N], state [B, H, P, N]) for decode;
    ``S == 1`` uses the O(1) recurrence.  ``return_cache`` makes the chunked
    (prefill) path emit the decode cache.
    """
    b, s, d = u.shape
    di, n, h, pdim = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    kk = cfg.conv_kernel

    x = u @ p["in_x"]
    z = u @ p["in_z"]
    Bm = u @ p["in_B"]
    Cm = u @ p["in_C"]
    dt = jax.nn.softplus(
        (u @ p["in_dt"]).astype(jnp.float32) + p["dt_bias"]
    )                                                              # [B,S,H]
    A = -jnp.exp(p["A_log"])                                       # [H]

    if cache is None or s > 1:
        if cache is not None:
            raise NotImplementedError("chunked prefill with cache not needed")
        raw_window = jnp.concatenate([x, Bm, Cm], axis=-1)[:, s - (kk - 1):, :]
        x = jax.nn.silu(_causal_conv(x, p["conv_x"]))
        Bm = jax.nn.silu(_causal_conv(Bm, p["conv_B"]))
        Cm = jax.nn.silu(_causal_conv(Cm, p["conv_C"]))
        xh = x.reshape(b, s, h, pdim)
        xdt = xh * dt[..., None]
        dA = dt * A
        if getattr(cfg, "attn_impl", "xla") == "pallas" and not return_cache:
            # TPU kernel path (kernels/ssd_scan); the cache-producing prefill
            # needs h_final, which the fused kernel keeps in VMEM — fall back.
            from ..kernels.ssd_scan.ops import ssd_scan as _ssd_kernel

            q = min(cfg.ssd_chunk, s)
            while s % q:
                q //= 2
            y = _ssd_kernel(xdt, dA, Bm, Cm, chunk=q)
            h_final = None
        else:
            y, h_final = ssd_chunked(xdt, dA, Bm, Cm, cfg.ssd_chunk)
        new_cache = (
            {"conv": raw_window, "state": h_final} if return_cache else None
        )
    else:
        # O(1) decode step.
        conv_win = cache["conv"]                                   # [B,K-1,di+2N]
        packed = jnp.concatenate([x[:, 0], Bm[:, 0], Cm[:, 0]], axis=-1)
        w_packed = jnp.concatenate([p["conv_x"], p["conv_B"], p["conv_C"]], axis=1)
        conv_out, conv_win = _conv_step(conv_win, packed, w_packed)
        conv_out = jax.nn.silu(conv_out)
        x_t = conv_out[:, :di].reshape(b, h, pdim).astype(jnp.float32)
        B_t = conv_out[:, di : di + n].astype(jnp.float32)
        C_t = conv_out[:, di + n :].astype(jnp.float32)
        dt_t = dt[:, 0]                                            # [B,H]
        dA_t = jnp.exp(dt_t * A)                                   # [B,H]
        hst = cache["state"]                                       # [B,H,P,N]
        hst = hst * dA_t[:, :, None, None] + (
            (dt_t[:, :, None] * x_t)[..., None] * B_t[:, None, None, :]
        )
        y = jnp.einsum("bhpn,bn->bhp", hst, C_t)
        y = y.reshape(b, 1, h, pdim)
        xh = x_t.reshape(b, 1, h, pdim)
        new_cache = {"conv": conv_win, "state": hst}

    y = y + p["D"][:, None] * xh.astype(jnp.float32)
    y = y.reshape(b, s, di)

    # Gated RMSNorm (Mamba-2) then output projection.
    from .layers import rms_norm

    y = y.astype(u.dtype) * jax.nn.silu(z)
    y = rms_norm(y, p["norm"], cfg.rms_eps)
    return y @ p["out"], new_cache


def init_ssm_cache(cfg, batch: int, dtype=jnp.float32):
    di, n, h, pdim = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    return {
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, di + 2 * n), dtype),
        "state": jnp.zeros((batch, h, pdim, n), jnp.float32),
    }


def ssd_sequential_ref(xdt, dA, Bmat, Cmat, h0=None):
    """O(S) sequential reference recurrence (oracle for ssd_chunked)."""
    b, s, h, p = xdt.shape
    n = Bmat.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)

    def body(carry, xs):
        hst = carry
        x_t, dA_t, B_t, C_t = xs
        hst = hst * jnp.exp(dA_t)[:, :, None, None] + (
            x_t[..., None] * B_t[:, None, None, :]
        )
        y_t = jnp.einsum("bhpn,bn->bhp", hst, C_t)
        return hst, y_t

    xs = (
        jnp.moveaxis(xdt.astype(jnp.float32), 1, 0),
        jnp.moveaxis(dA.astype(jnp.float32), 1, 0),
        jnp.moveaxis(Bmat.astype(jnp.float32), 1, 0),
        jnp.moveaxis(Cmat.astype(jnp.float32), 1, 0),
    )
    h_final, ys = jax.lax.scan(body, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h_final
