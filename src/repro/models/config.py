"""Model configuration for every architecture family in the assignment.

One dataclass covers dense / MoE / SSM / hybrid / VLM-stub / enc-dec; the
family switch selects the block composition.  Configs for the 10 assigned
architectures live in ``repro.configs``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | encdec
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None        # default d_model // num_heads

    # --- MoE ---
    num_experts: int = 0                  # routed experts
    num_experts_per_tok: int = 0
    moe_d_ff: int = 0                     # per-expert hidden size
    shared_expert_d_ff: int = 0           # fused shared-experts hidden size
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # EP divisibility padding: experts >= num_experts_real are dead (router
    # logits masked to -inf); set by launch/cells._pad_experts.
    num_experts_real: Optional[int] = None

    # --- SSM (Mamba-2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_kernel: int = 4
    ssd_chunk: int = 256

    # --- hybrid (Zamba2-style) ---
    attn_every: int = 0                   # shared attn block every k SSM blocks

    # --- VLM stub ---
    num_patches: int = 0                  # precomputed patch embeds prepended

    # --- enc-dec (Whisper) ---
    num_encoder_layers: int = 0
    encoder_seq: int = 0                  # precomputed frame embeds (stub)

    # --- misc ---
    qkv_bias: bool = False
    rope_theta: float = 1e4
    rms_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: jnp.dtype = jnp.bfloat16
    # Attention implementation: 'xla' (chunked online-softmax jnp; used by the
    # dry-run since Pallas cannot compile on the CPU backend) or 'pallas'.
    attn_impl: str = "xla"
    attn_chunk: int = 1024
    remat: bool = True
    # scan_layers=False unrolls the layer loop (used by the dry-run roofline
    # extrapolation; XLA cost analysis counts while-bodies once).
    scan_layers: bool = True
    # Chunked cross-entropy: peak logits memory = B*loss_chunk*V instead of
    # B*S*V.  0 = unchunked.
    loss_chunk: int = 0
    # prefill computes logits for the last position only (serving does not
    # need the rest) — saves a [B,S,V] matmul.
    prefill_logits_last_only: bool = False
    # Megatron-style sequence parallelism: residual stream sharded over
    # (seq × model-axis) at block boundaries, turning TP all-reduces into
    # reduce-scatter + all-gather pairs (half the wire bytes) and sharding
    # the norms.  No-op outside a mesh or when seq doesn't divide.
    seq_shard_activations: bool = False

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: SSM / hybrid only (per assignment rules)."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Approximate parameter count (for 6·N·D roofline bookkeeping)."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        hd = self.head_dim
        n_embed = V * d * (1 if self.tie_embeddings else 2)
        total = n_embed
        if self.family in ("dense", "moe", "vlm"):
            attn = d * (self.num_heads * hd) + 2 * d * (self.num_kv_heads * hd) \
                + (self.num_heads * hd) * d
            if self.family == "moe":
                ffn = 3 * d * self.moe_d_ff * self.num_experts \
                    + 3 * d * self.shared_expert_d_ff + d * self.num_experts
            else:
                ffn = 3 * d * self.d_ff
            total += L * (attn + ffn + 2 * d)
        elif self.family == "ssm":
            di, H, N = self.d_inner, self.ssm_heads, self.ssm_state
            blk = d * di * 2 + d * 2 * N + d * H + di * d \
                + self.conv_kernel * (di + 2 * N) + 3 * H + di
            total += L * (blk + d)
        elif self.family == "hybrid":
            di, H, N = self.d_inner, self.ssm_heads, self.ssm_state
            blk = d * di * 2 + d * 2 * N + d * H + di * d \
                + self.conv_kernel * (di + 2 * N) + 3 * H + di
            shared_attn = d * (self.num_heads * hd) + 2 * d * (self.num_kv_heads * hd) \
                + (self.num_heads * hd) * d + 3 * d * self.d_ff + 2 * d
            total += L * (blk + d) + shared_attn
        elif self.family == "encdec":
            attn = d * (self.num_heads * hd) + 2 * d * (self.num_kv_heads * hd) \
                + (self.num_heads * hd) * d
            ffn = 3 * d * self.d_ff
            total += self.num_encoder_layers * (attn + ffn + 2 * d)
            total += L * (2 * attn + ffn + 3 * d)   # self + cross attention
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        if self.family != "moe":
            return self.param_count()
        d, L = self.d_model, self.num_layers
        hd = self.head_dim
        attn = d * (self.num_heads * hd) + 2 * d * (self.num_kv_heads * hd) \
            + (self.num_heads * hd) * d
        ffn = 3 * d * self.moe_d_ff * self.num_experts_per_tok \
            + 3 * d * self.shared_expert_d_ff + d * self.num_experts
        n_embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return n_embed + L * (attn + ffn + 2 * d)


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    base = dict(
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 2)),
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        dtype=jnp.float32,
        attn_chunk=64,
        remat=False,
    )
    if cfg.family == "moe":
        base.update(num_experts=4, num_experts_per_tok=2, moe_d_ff=64,
                    shared_expert_d_ff=64 if cfg.shared_expert_d_ff else 0)
    if cfg.family in ("ssm", "hybrid"):
        base.update(ssm_state=16, ssm_head_dim=16, ssd_chunk=16)
    if cfg.family == "hybrid":
        base.update(attn_every=2)
    if cfg.family == "vlm":
        base.update(num_patches=8)
    if cfg.family == "encdec":
        base.update(num_encoder_layers=2, encoder_seq=16)
    base.update(overrides)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **base)
