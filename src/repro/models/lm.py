"""Unified language model covering all assigned architecture families.

* ``dense`` — pre-norm GQA transformer (llama3 / phi3 / deepseek / qwen2.5)
* ``moe``   — dense attention + routed-expert MLP (+ fused shared experts)
* ``ssm``   — Mamba-2 stack (attention-free)
* ``hybrid``— Mamba-2 stack with one *shared* attention block applied every
              ``attn_every`` layers (Zamba2-style); the shared block has its
              own KV cache per application site
* ``vlm``   — dense backbone with precomputed patch embeddings prepended
              (modality frontend stubbed per the assignment)
* ``encdec``— encoder-decoder (Whisper); conv frontend stubbed with
              precomputed frame embeddings

Layers are stacked and executed with ``lax.scan`` (+ optional remat), which
keeps HLO size and compile time bounded for the 94-layer dry-run cells.
Params are a plain dict pytree; ``abstract_params`` produces allocation-free
ShapeDtypeStructs for ``jit(...).lower()``.
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (
    attention_block,
    chunked_attention,
    cross_attention_block,
    init_attention,
    init_mlp,
    init_moe,
    mlp_block,
    moe_block,
    rms_norm,
    tree_attention_block,
)
from .ssm import init_ssm_block, init_ssm_cache, ssm_block

Pytree = Any


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------


def _init_transformer_block(key, cfg: ModelConfig, cross: bool = False):
    ks = jax.random.split(key, 6)
    p = {
        "attn_norm": jnp.ones((cfg.d_model,), cfg.dtype),
        "attn": init_attention(ks[0], cfg),
        "mlp_norm": jnp.ones((cfg.d_model,), cfg.dtype),
    }
    if cfg.family == "moe":
        p["moe"] = init_moe(ks[1], cfg, cfg.dtype)
    else:
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.dtype)
    if cross:
        p["cross_norm"] = jnp.ones((cfg.d_model,), cfg.dtype)
        p["cross"] = init_attention(ks[2], cfg)
    return p


def _init_ssm_layer(key, cfg: ModelConfig):
    return {
        "norm": jnp.ones((cfg.d_model,), cfg.dtype),
        "ssm": init_ssm_block(key, cfg, cfg.dtype),
    }


def init_params(cfg: ModelConfig, key: jax.Array) -> Pytree:
    k_embed, k_blocks, k_head, k_shared, k_enc = jax.random.split(key, 5)
    std = 0.02
    params: dict = {
        "embed": (
            jax.random.normal(k_embed, (cfg.vocab_size, cfg.d_model)) * std
        ).astype(cfg.dtype),
        "final_norm": jnp.ones((cfg.d_model,), cfg.dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(k_head, (cfg.d_model, cfg.vocab_size)) * std
        ).astype(cfg.dtype)

    layer_keys = jax.random.split(k_blocks, cfg.num_layers)
    if cfg.family in ("dense", "moe", "vlm"):
        params["blocks"] = jax.vmap(lambda k: _init_transformer_block(k, cfg))(
            layer_keys
        )
    elif cfg.family == "ssm":
        params["blocks"] = jax.vmap(lambda k: _init_ssm_layer(k, cfg))(layer_keys)
    elif cfg.family == "hybrid":
        params["blocks"] = jax.vmap(lambda k: _init_ssm_layer(k, cfg))(layer_keys)
        params["shared_attn"] = _init_transformer_block(k_shared, cfg)
    elif cfg.family == "encdec":
        params["blocks"] = jax.vmap(
            lambda k: _init_transformer_block(k, cfg, cross=True)
        )(layer_keys)
        enc_keys = jax.random.split(k_enc, cfg.num_encoder_layers)
        params["encoder"] = {
            "blocks": jax.vmap(lambda k: _init_transformer_block(k, cfg))(enc_keys),
            "final_norm": jnp.ones((cfg.d_model,), cfg.dtype),
        }
    else:
        raise ValueError(cfg.family)
    return params


def abstract_params(cfg: ModelConfig) -> Pytree:
    """Allocation-free parameter ShapeDtypeStructs (for the dry-run)."""
    return jax.eval_shape(
        functools.partial(init_params, cfg), jax.random.PRNGKey(0)
    )


# ---------------------------------------------------------------------------
# Layer-loop execution: lax.scan (default; bounded HLO size / compile time)
# or an unrolled Python loop (dry-run cost extrapolation).
# ---------------------------------------------------------------------------


def _layer_scan(body, carry, xs, cfg: ModelConfig):
    if cfg.scan_layers:
        return jax.lax.scan(body, carry, xs)
    length = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(length):
        xi = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


# ---------------------------------------------------------------------------
# Hybrid helpers: the shared attention block and its per-site cache
# ---------------------------------------------------------------------------


def _num_attn_sites(cfg: ModelConfig) -> int:
    if cfg.family != "hybrid" or cfg.attn_every <= 0:
        return 0
    return (cfg.num_layers + cfg.attn_every - 1) // cfg.attn_every


def _shared_attn_apply(shared, cfg, x, positions, site_cache):
    """One application of the shared transformer block (attn + MLP)."""
    h, new_cache = attention_block(
        shared["attn"], cfg, rms_norm(x, shared["attn_norm"], cfg.rms_eps),
        positions, cache=site_cache,
    )
    x = x + h
    x = x + mlp_block(shared["mlp"], rms_norm(x, shared["mlp_norm"], cfg.rms_eps))
    return x, new_cache


# ---------------------------------------------------------------------------
# Block bodies (scan-compatible)
# ---------------------------------------------------------------------------


def _transformer_body(cfg, bp, x, positions, cache, enc_out=None):
    if cfg.seq_shard_activations:
        from ..distributed.sharding import constrain

        x = constrain(x, ("pod", "data"), "model", None)
    h, new_cache = attention_block(
        bp["attn"], cfg, rms_norm(x, bp["attn_norm"], cfg.rms_eps),
        positions, cache=cache,
    )
    x = x + h
    aux = jnp.float32(0.0)
    if enc_out is not None:
        x = x + cross_attention_block(
            bp["cross"], cfg, rms_norm(x, bp["cross_norm"], cfg.rms_eps), enc_out
        )
    if cfg.family == "moe":
        h, aux = moe_block(bp["moe"], cfg, rms_norm(x, bp["mlp_norm"], cfg.rms_eps))
    else:
        h = mlp_block(bp["mlp"], rms_norm(x, bp["mlp_norm"], cfg.rms_eps))
    return x + h, new_cache, aux


def _ssm_body(cfg, bp, x, cache, return_cache=False):
    h, new_cache = ssm_block(
        bp["ssm"], cfg, rms_norm(x, bp["norm"], cfg.rms_eps),
        cache=cache, return_cache=return_cache,
    )
    return x + h, new_cache


# ---------------------------------------------------------------------------
# Forward (training / no-cache)
# ---------------------------------------------------------------------------


def _embed_inputs(params, cfg: ModelConfig, batch) -> tuple[jax.Array, jax.Array]:
    tokens = batch["tokens"]
    x = params["embed"][tokens]
    if cfg.family == "vlm" and "patch_embeds" in batch:
        patches = batch["patch_embeds"].astype(x.dtype)
        x = jnp.concatenate([patches, x], axis=1)
    s = x.shape[1]
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), x.shape[:2])
    return x, positions


def _run_encoder(params, cfg, frames):
    x = frames.astype(cfg.dtype)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])

    def body(x, bp):
        h, _ = attention_block(
            bp["attn"], cfg, rms_norm(x, bp["attn_norm"], cfg.rms_eps),
            positions, causal=False,
        )
        x = x + h
        x = x + mlp_block(bp["mlp"], rms_norm(x, bp["mlp_norm"], cfg.rms_eps))
        return x, None

    fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = _layer_scan(fn, x, params["encoder"]["blocks"], cfg)
    return rms_norm(x, params["encoder"]["final_norm"], cfg.rms_eps)


def _enc_kv(cfg, bp_cross, enc_out):
    b, se, _ = enc_out.shape
    hkv, hd = cfg.num_kv_heads, cfg.head_dim
    k = (enc_out @ bp_cross["wk"]).reshape(b, se, hkv, hd)
    v = (enc_out @ bp_cross["wv"]).reshape(b, se, hkv, hd)
    if cfg.qkv_bias:
        k = k + bp_cross["bk"].reshape(hkv, hd)
        v = v + bp_cross["bv"].reshape(hkv, hd)
    return {"k": k, "v": v}


def forward(
    params, cfg: ModelConfig, batch, return_hidden: bool = False
) -> tuple[jax.Array, jax.Array]:
    """Full forward (no cache).  Returns (logits | final hidden, aux_loss)."""
    x, positions = _embed_inputs(params, cfg, batch)

    enc_out = None
    if cfg.family == "encdec":
        enc_out = _run_encoder(params, cfg, batch["frame_embeds"])

    if cfg.family in ("dense", "moe", "vlm", "encdec"):

        def body(carry, bp):
            x, aux = carry
            kv = _enc_kv(cfg, bp["cross"], enc_out) if enc_out is not None else None
            x, _, aux_i = _transformer_body(cfg, bp, x, positions, None, kv)
            return (x, aux + aux_i), None

        fn = jax.checkpoint(body) if cfg.remat else body
        (x, aux), _ = _layer_scan(fn, (x, jnp.float32(0.0)), params["blocks"], cfg)

    elif cfg.family == "ssm":

        def body(x, bp):
            x, _ = _ssm_body(cfg, bp, x, None)
            return x, None

        fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = _layer_scan(fn, x, params["blocks"], cfg)
        aux = jnp.float32(0.0)

    elif cfg.family == "hybrid":
        shared = params["shared_attn"]

        def body(x, xs):
            bp, idx = xs
            is_site = (idx % cfg.attn_every) == 0

            def with_attn(x):
                out, _ = _shared_attn_apply(shared, cfg, x, positions, None)
                return out

            x = jax.lax.cond(is_site, with_attn, lambda x: x, x)
            x, _ = _ssm_body(cfg, bp, x, None)
            return x, None

        fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = _layer_scan(
            fn, x, (params["blocks"], jnp.arange(cfg.num_layers)), cfg
        )
        aux = jnp.float32(0.0)
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    if return_hidden:
        return x, aux
    head = params.get("lm_head", None)
    logits = x @ head if head is not None else x @ params["embed"].T
    return logits, aux


def _ce_terms(pred: jax.Array, targets: jax.Array, mask: jax.Array):
    """(Σ nll, Σ mask) over a [B, S, V] fp32 slab."""
    logz = jax.nn.logsumexp(pred, axis=-1)
    gold = jnp.take_along_axis(pred, targets[..., None], axis=-1)[..., 0]
    return jnp.sum((logz - gold) * mask), jnp.sum(mask)


def loss_fn(params, cfg: ModelConfig, batch) -> tuple[jax.Array, dict]:
    """Next-token cross entropy (text positions only for VLM).

    With ``cfg.loss_chunk > 0`` the LM head + CE run chunked over the
    sequence inside a rematerialized scan, bounding peak logits memory to
    ``B × loss_chunk × V`` instead of ``B × S × V``.
    """
    tokens = batch["tokens"]
    mask = batch.get("loss_mask")
    mask_full = (
        jnp.ones_like(tokens[:, 1:], jnp.float32) if mask is None else mask[:, 1:]
    )
    targets = tokens[:, 1:]

    if cfg.loss_chunk <= 0:
        logits, aux = forward(params, cfg, batch)
        if cfg.family == "vlm" and "patch_embeds" in batch:
            logits = logits[:, batch["patch_embeds"].shape[1]:, :]
        pred = logits[:, :-1, :].astype(jnp.float32)
        nll, denom = _ce_terms(pred, targets, mask_full)
        loss = nll / jnp.maximum(denom, 1.0)
        return loss + aux, {"loss": loss, "aux": aux, "tokens": denom}

    hidden, aux = forward(params, cfg, batch, return_hidden=True)
    if cfg.family == "vlm" and "patch_embeds" in batch:
        hidden = hidden[:, batch["patch_embeds"].shape[1]:, :]
    hidden = hidden[:, :-1, :]
    head = params.get("lm_head", None)
    head = head if head is not None else params["embed"].T
    s = hidden.shape[1]
    c = cfg.loss_chunk
    pad = (-s) % c
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        mask_full = jnp.pad(mask_full, ((0, 0), (0, pad)))
    nc = hidden.shape[1] // c
    hs = jnp.moveaxis(hidden.reshape(hidden.shape[0], nc, c, -1), 1, 0)
    ts = jnp.moveaxis(targets.reshape(targets.shape[0], nc, c), 1, 0)
    ms = jnp.moveaxis(mask_full.reshape(mask_full.shape[0], nc, c), 1, 0)

    @jax.checkpoint
    def chunk(carry, xs):
        h_c, t_c, m_c = xs
        pred = (h_c @ head).astype(jnp.float32)
        nll_c, den_c = _ce_terms(pred, t_c, m_c)
        return (carry[0] + nll_c, carry[1] + den_c), None

    (nll, denom), _ = jax.lax.scan(
        chunk, (jnp.float32(0.0), jnp.float32(0.0)), (hs, ts, ms)
    )
    loss = nll / jnp.maximum(denom, 1.0)
    return loss + aux, {"loss": loss, "aux": aux, "tokens": denom}


# ---------------------------------------------------------------------------
# Serving: cache init / prefill / decode
# ---------------------------------------------------------------------------

# Families whose decode cache is pure position-indexed KV — the ones that
# support the ragged right-padded prefill and len-rollback contract (see
# prefill_ragged).  Recurrent caches (ssm/hybrid) and frontend-fed families
# (vlm/encdec) are excluded; every consumer of the contract
# (CachedModelEvaluator, ServingEngine.add_requests, SearchService's
# evaluator default) tests against this one set.
KV_CACHE_FAMILIES = ("dense", "moe")


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int) -> Pytree:
    """Allocate the decode cache (KV / SSM state / enc-dec cross-KV)."""
    L = cfg.num_layers
    hkv, hd = cfg.num_kv_heads, cfg.head_dim
    kv = lambda: {
        "k": jnp.zeros((L, batch_size, max_len, hkv, hd), cfg.dtype),
        "v": jnp.zeros((L, batch_size, max_len, hkv, hd), cfg.dtype),
    }
    if cfg.family in ("dense", "moe", "vlm"):
        return {"kv": kv(), "len": jnp.int32(0)}
    if cfg.family == "ssm":
        c = init_ssm_cache(cfg, batch_size)
        return {
            "ssm": jax.tree.map(
                lambda x: jnp.broadcast_to(x, (L,) + x.shape), c
            ),
            "len": jnp.int32(0),
        }
    if cfg.family == "hybrid":
        sites = _num_attn_sites(cfg)
        c = init_ssm_cache(cfg, batch_size)
        return {
            "ssm": jax.tree.map(lambda x: jnp.broadcast_to(x, (L,) + x.shape), c),
            "kv": {
                "k": jnp.zeros((sites, batch_size, max_len, hkv, hd), cfg.dtype),
                "v": jnp.zeros((sites, batch_size, max_len, hkv, hd), cfg.dtype),
            },
            "len": jnp.int32(0),
        }
    if cfg.family == "encdec":
        se = cfg.encoder_seq
        return {
            "kv": kv(),
            "cross": {
                "k": jnp.zeros((L, batch_size, se, hkv, hd), cfg.dtype),
                "v": jnp.zeros((L, batch_size, se, hkv, hd), cfg.dtype),
            },
            "len": jnp.int32(0),
        }
    raise ValueError(cfg.family)


def _step_with_cache(
    params, cfg: ModelConfig, batch, cache, last_positions=None
) -> tuple[jax.Array, Pytree]:
    """Shared prefill/decode path: runs S tokens against the cache.

    ``last_positions`` (``i32[B]``, ragged prefill) gathers the final hidden
    state at each row's own last valid position *before* the unembed, so the
    logits slab stays ``[B, 1, V]`` instead of ``[B, S, V]``.
    """
    x, positions = _embed_inputs(params, cfg, batch)
    cur_len = cache["len"]
    positions = positions + (
        cur_len[:, None] if jnp.ndim(cur_len) == 1 else cur_len
    )
    s = x.shape[1]
    prefill_mode = s > 1

    if cfg.family in ("dense", "moe", "vlm", "encdec"):
        has_cross = cfg.family == "encdec"

        def body(carry, xs):
            x = carry
            if cfg.seq_shard_activations and prefill_mode:
                from ..distributed.sharding import constrain

                x = constrain(x, ("pod", "data"), "model", None)
            if has_cross:
                bp, kc, vc, ck, cv = xs
                enc_kv = {"k": ck, "v": cv}
            else:
                bp, kc, vc = xs
                enc_kv = None
            layer_cache = {"k": kc, "v": vc, "len": cur_len}
            h, new_cache = attention_block(
                bp["attn"], cfg, rms_norm(x, bp["attn_norm"], cfg.rms_eps),
                positions, cache=layer_cache,
            )
            x = x + h
            if enc_kv is not None:
                x = x + cross_attention_block(
                    bp["cross"], cfg,
                    rms_norm(x, bp["cross_norm"], cfg.rms_eps), enc_kv,
                )
            if cfg.family == "moe":
                h, _ = moe_block(
                    bp["moe"], cfg, rms_norm(x, bp["mlp_norm"], cfg.rms_eps)
                )
            else:
                h = mlp_block(bp["mlp"], rms_norm(x, bp["mlp_norm"], cfg.rms_eps))
            return x + h, (new_cache["k"], new_cache["v"])

        xs = (params["blocks"], cache["kv"]["k"], cache["kv"]["v"])
        if has_cross:
            xs = xs + (cache["cross"]["k"], cache["cross"]["v"])
        fn = jax.checkpoint(body) if (cfg.remat and prefill_mode) else body
        x, (ks, vs) = _layer_scan(fn, x, xs, cfg)
        new_cache = dict(cache, kv={"k": ks, "v": vs}, len=cur_len + s)

    elif cfg.family == "ssm":

        def body(x, xs):
            bp, conv, state = xs
            layer_cache = None if prefill_mode else {"conv": conv, "state": state}
            x, nc = _ssm_body(cfg, bp, x, layer_cache, return_cache=True)
            return x, (nc["conv"], nc["state"])

        fn = jax.checkpoint(body) if (cfg.remat and prefill_mode) else body
        x, (convs, states) = _layer_scan(
            fn, x,
            (params["blocks"], cache["ssm"]["conv"], cache["ssm"]["state"]), cfg,
        )
        new_cache = dict(
            cache, ssm={"conv": convs, "state": states}, len=cur_len + s
        )

    elif cfg.family == "hybrid":
        shared = params["shared_attn"]
        kv_k, kv_v = cache["kv"]["k"], cache["kv"]["v"]

        def body(carry, xs):
            x, kv_k, kv_v = carry
            bp, conv, state, idx = xs
            is_site = (idx % cfg.attn_every) == 0
            site = idx // cfg.attn_every

            def with_attn(op):
                x, kv_k, kv_v = op
                site_cache = {"k": kv_k[site], "v": kv_v[site], "len": cur_len}
                out, nc = _shared_attn_apply(shared, cfg, x, positions, site_cache)
                return (
                    out,
                    kv_k.at[site].set(nc["k"]),
                    kv_v.at[site].set(nc["v"]),
                )

            x, kv_k, kv_v = jax.lax.cond(
                is_site, with_attn, lambda op: op, (x, kv_k, kv_v)
            )
            layer_cache = None if prefill_mode else {"conv": conv, "state": state}
            x, nc = _ssm_body(cfg, bp, x, layer_cache, return_cache=True)
            return (x, kv_k, kv_v), (nc["conv"], nc["state"])

        fn = jax.checkpoint(body) if (cfg.remat and prefill_mode) else body
        (x, kv_k, kv_v), (convs, states) = _layer_scan(
            fn,
            (x, kv_k, kv_v),
            (
                params["blocks"],
                cache["ssm"]["conv"],
                cache["ssm"]["state"],
                jnp.arange(cfg.num_layers),
            ),
            cfg,
        )
        new_cache = dict(
            cache,
            ssm={"conv": convs, "state": states},
            kv={"k": kv_k, "v": kv_v},
            len=cur_len + s,
        )
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    if prefill_mode and last_positions is not None:
        idx = jnp.reshape(jnp.asarray(last_positions, jnp.int32), (-1, 1, 1))
        x = jnp.take_along_axis(x, idx, axis=1)
    elif prefill_mode and cfg.prefill_logits_last_only:
        x = x[:, -1:, :]
    head = params.get("lm_head", None)
    logits = x @ head if head is not None else x @ params["embed"].T
    return logits, new_cache


def prefill(params, cfg: ModelConfig, batch, cache) -> tuple[jax.Array, Pytree]:
    """Run the prompt through the model, filling the cache.

    For enc-dec models the encoder runs here and its cross-KV is cached.
    Returns (last-position logits [B, V], cache).
    """
    if cfg.family == "encdec":
        enc_out = _run_encoder(params, cfg, batch["frame_embeds"])

        def per_layer(bp):
            return _enc_kv(cfg, bp["cross"], enc_out)

        cross = jax.vmap(per_layer)(params["blocks"])
        cache = dict(cache, cross=cross)
    logits, cache = _step_with_cache(params, cfg, batch, cache)
    return logits[:, -1, :], cache


def prefill_ragged(
    params, cfg: ModelConfig, tokens, lengths, cache
) -> tuple[jax.Array, Pytree]:
    """Batched ragged prefill: right-padded prompts, per-slot lengths.

    ``tokens`` is ``[B, S]`` with row ``b`` valid up to ``lengths[b]``; one
    forward fills all ``B`` cache slots and the returned logits ``[B, V]``
    are taken at each row's *own* last valid position.  The returned cache
    carries a per-slot ``len`` **vector** — the layout every ragged consumer
    (``decode_step``, the serving engine, ``CachedModelEvaluator``) shares:

    * KV rows at positions ``< len[b]`` are valid; rows at ``>= len[b]`` are
      garbage (computed from pad tokens).  That is safe because attention
      masks ``kv_pos < len`` and every later write lands at position
      ``len[b]`` *before* ``len[b]`` advances past it — garbage is always
      overwritten before it becomes visible.

    Recurrent (SSM / hybrid) caches have no per-position validity to hide
    behind — pad tokens would pollute the state — so only KV-cache families
    take this path.
    """
    if cfg.family not in KV_CACHE_FAMILIES:
        raise ValueError(
            f"prefill_ragged supports KV-cache LM families, not {cfg.family!r}"
        )
    lengths = jnp.asarray(lengths, jnp.int32)
    logits, cache = _step_with_cache(
        params, cfg, {"tokens": tokens}, cache,
        last_positions=jnp.maximum(lengths - 1, 0),
    )
    return logits[:, 0], dict(cache, len=lengths)


def decode_chunk(
    params, cfg: ModelConfig, tokens, target, cache
) -> tuple[jax.Array, Pytree]:
    """Ragged chunked catch-up: advance each row up to ``C`` tokens at once.

    ``tokens`` is ``[B, C]`` holding, for each row, the next ``C`` tokens
    starting at the row's own ``cache['len']``; ``target`` (``i32[B]``) is
    the length each row is catching up *to*.  One forward re-decodes a whole
    chunk of a divergent suffix — batched over rows AND positions — instead
    of ``C`` single-token ``decode_step`` dispatches (the refill while_loop
    this replaces).  Per row:

    * rows with ``len < target`` advance to ``min(len + C, target)``;
    * rows already at target keep their length — their chunk writes land
      beyond ``len`` in the garbage region and stay invisible;
    * returned logits ``[B, V]`` are gathered at ``target - 1 - len``
      (clamped into the chunk), i.e. they are the next-token logits for any
      row that *finishes* its catch-up within this chunk — exactly the rows
      whose logits the caller refreshes.

    Only KV-cache families can take this path (same contract as
    ``prefill_ragged``: positions ``>= len`` are garbage until overwritten).
    """
    if cfg.family not in KV_CACHE_FAMILIES:
        raise ValueError(
            f"decode_chunk supports KV-cache LM families, not {cfg.family!r}"
        )
    cur = jnp.asarray(cache["len"], jnp.int32)
    target = jnp.asarray(target, jnp.int32)
    c = tokens.shape[1]
    gather = jnp.clip(target - 1 - cur, 0, c - 1)
    logits, cache = _step_with_cache(
        params, cfg, {"tokens": tokens}, cache, last_positions=gather
    )
    new_len = jnp.where(cur < target, jnp.minimum(cur + c, target), cur)
    return logits[:, 0], dict(cache, len=new_len)


def decode_frontier(
    params, cfg: ModelConfig, tokens, cache
) -> tuple[jax.Array, Pytree]:
    """Score ``A`` candidate next tokens per row in ONE forward (read-only).

    ``tokens`` is ``[N, A]``: each row's candidate children, all sitting at
    absolute position ``cache['len']`` — they are *alternatives* for the
    same next position, not a sequence.  The shared prefix K/V is read once
    per layer (tree attention with an identity mask over the speculative
    tail: candidate ``i`` attends the prefix plus its own K/V only), and the
    cache is NEVER written.  Returns ``(logits [N, A, V], spec)`` where
    ``spec = {"k": [L, N, A, Hkv, D], "v": ...}`` holds each candidate's own
    K/V entry so the caller can commit the chosen child's row later without
    recomputing it.

    Only KV-cache families qualify (same garbage-region contract as
    ``prefill_ragged``; speculative tails live OUTSIDE the cache entirely).
    """
    if cfg.family not in KV_CACHE_FAMILIES:
        raise ValueError(
            f"decode_frontier supports KV-cache LM families, not {cfg.family!r}"
        )
    tokens = jnp.asarray(tokens)
    n, a = tokens.shape
    x = params["embed"][tokens]
    cur_len = jnp.asarray(cache["len"], jnp.int32)
    positions = jnp.broadcast_to(
        cur_len[:, None] if jnp.ndim(cur_len) == 1 else cur_len, (n, a)
    )

    def body(x, xs):
        bp, kc, vc = xs
        h, ks, vs = tree_attention_block(
            bp["attn"], cfg, rms_norm(x, bp["attn_norm"], cfg.rms_eps),
            positions, kc, vc, cur_len,
        )
        x = x + h
        if cfg.family == "moe":
            h, _ = moe_block(
                bp["moe"], cfg, rms_norm(x, bp["mlp_norm"], cfg.rms_eps)
            )
        else:
            h = mlp_block(bp["mlp"], rms_norm(x, bp["mlp_norm"], cfg.rms_eps))
        return x + h, (ks, vs)

    x, (ks, vs) = _layer_scan(
        body, x, (params["blocks"], cache["kv"]["k"], cache["kv"]["v"]), cfg
    )
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    head = params.get("lm_head", None)
    logits = x @ head if head is not None else x @ params["embed"].T
    return logits, {"k": ks, "v": vs}


def decode_step(params, cfg: ModelConfig, token, cache) -> tuple[jax.Array, Pytree]:
    """One autoregressive step.  token: [B] or [B, 1] → (logits [B, V], cache).

    ``cache["len"]`` may be a scalar (uniform batch) or a per-slot ``[B]``
    vector (ragged decode: continuous batching, async search slots) — each
    slot writes and attends at its own position, through the Pallas decode
    kernel when ``cfg.attn_impl == 'pallas'``.
    """
    token = token.reshape(token.shape[0], 1)
    logits, cache = _step_with_cache(params, cfg, {"tokens": token}, cache)
    return logits[:, -1, :], cache
