from .base import Environment
from .bandit_tree import make_bandit_tree
from .random_mdp import make_random_mdp
from .tap_game import make_tap_game

__all__ = [
    "Environment",
    "make_bandit_tree",
    "make_random_mdp",
    "make_tap_game",
]
