"""Token-generation environment: WU-UCT searches over LM continuations.

This is where the paper's technique meets the assigned architectures: the
*simulation* step of MCTS is a policy-network rollout (exactly the paper's
Atari setup, where a distilled PPO net drives simulations — App. D), with
the policy network being any of the 10 assigned LMs served by the framework.

State = (tokens so far, length); actions = the top-K tokens under the policy
LM at the current position; reward = per-token log-likelihood under a target
("reward") model — so the search maximizes target-model likelihood while
being guided by the policy model.  Terminal at EOS or max length.

The env recomputes forward passes per step (node states must be compact to
live in the tree's state buffer); slot-level KV caching happens inside the
serving engine when used at scale.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..models import forward
from ..models.config import ModelConfig
from .base import Environment


class TokenEnvState(NamedTuple):
    tokens: jax.Array   # i32[max_len]
    length: jax.Array   # i32[]
    done: jax.Array     # bool[]


def apply_token(
    state: TokenEnvState, token: jax.Array, logp: jax.Array, eos_token: int
) -> tuple[TokenEnvState, jax.Array, jax.Array]:
    """Transition core shared by ``step`` and the batched ``ModelEvaluator``:
    append ``token`` at the current position, reward its ``logp``, terminate
    at EOS or max length, freeze finished sequences.

    Shape-polymorphic: accepts the scalar per-slot state or states with any
    leading batch axes — keeping the evaluator's batched MDP equivalent to
    the env's by construction.
    """
    max_len = state.tokens.shape[-1]
    token = jnp.asarray(token, jnp.int32)
    at_pos = jnp.arange(max_len) == state.length[..., None]
    new_tokens = jnp.where(at_pos, token[..., None], state.tokens)
    new_len = state.length + 1
    hit_end = (token == eos_token) | (new_len >= max_len)
    nxt = TokenEnvState(
        tokens=jnp.where(state.done[..., None], state.tokens, new_tokens),
        length=jnp.where(state.done, state.length, new_len),
        done=state.done | hit_end,
    )
    reward = jnp.where(state.done, 0.0, logp)
    return nxt, reward, nxt.done


def make_token_env(
    policy_cfg: ModelConfig,
    policy_params,
    prompt: jax.Array,          # i32[P]
    max_len: int = 64,
    top_k: int = 8,
    eos_token: int = 0,
    reward_cfg: Optional[ModelConfig] = None,
    reward_params=None,
) -> Environment:
    """Actions = ranks into the policy model's top-K at the current state."""
    prompt_len = int(prompt.shape[0])
    reward_cfg = reward_cfg or policy_cfg
    reward_params = reward_params if reward_params is not None else policy_params

    def _logits(params, cfg, tokens, length):
        lg, _ = forward(params, cfg, {"tokens": tokens[None]})
        return lg[0, length - 1]

    def init(key: jax.Array) -> TokenEnvState:
        del key
        tokens = jnp.zeros((max_len,), jnp.int32)
        tokens = tokens.at[:prompt_len].set(prompt)
        return TokenEnvState(tokens, jnp.int32(prompt_len), jnp.bool_(False))

    def step(state: TokenEnvState, action: jax.Array):
        action = jnp.asarray(action, jnp.int32)
        pol = _logits(policy_params, policy_cfg, state.tokens, state.length)
        _, top_idx = jax.lax.top_k(pol, top_k)
        # step() runs inside jitted search waves, so an out-of-range rank
        # cannot raise here; the clip is a gather guard, and the eager
        # boundary (SearchService.decide) validates the searched action and
        # raises InvalidSearchActionError before any clipped value is served.
        # reprolint: disable=JX004
        token = top_idx[jnp.clip(action, 0, top_k - 1)]

        rew_logits = _logits(reward_params, reward_cfg, state.tokens, state.length)
        logp = jax.nn.log_softmax(rew_logits.astype(jnp.float32))[token]

        return apply_token(state, token, logp, eos_token)

    def rollout_policy(key: jax.Array, state: TokenEnvState) -> jax.Array:
        # Sample an action rank ∝ the policy's top-K probabilities.
        pol = _logits(policy_params, policy_cfg, state.tokens, state.length)
        top_vals, _ = jax.lax.top_k(pol, top_k)
        return jax.random.categorical(key, top_vals).astype(jnp.int32)

    def observe(state: TokenEnvState) -> jax.Array:
        return state.tokens.astype(jnp.float32)

    return Environment(
        name=f"token_env({policy_cfg.name},k={top_k})",
        num_actions=top_k,
        init=init,
        step=step,
        rollout_policy=rollout_policy,
        observe=observe,
    )
