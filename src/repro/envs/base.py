"""Environment protocol used by every search algorithm in this repo.

The MDP contract follows the paper (Sec. 2.1, footnote 2): the action space is
finite and ``step`` is *deterministic given the state* — stochasticity is
folded into a PRNG key carried inside the state, so that MCTS child states are
well-defined (this is how the paper's production system handles the "high
randomness" of the Joy City transitions).

All callables must be jittable and vmappable; states are pytrees of arrays
with static shapes so they can live in the tree's centralized state buffer.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

Pytree = Any
StepFn = Callable[[Pytree, jax.Array], tuple[Pytree, jax.Array, jax.Array]]


@dataclasses.dataclass(frozen=True)
class Environment:
    """Bundle of pure functions describing one environment."""

    name: str
    num_actions: int
    init: Callable[[jax.Array], Pytree]              # key -> state
    step: StepFn                                     # (state, a) -> (state', r, done)
    # Default (simulation) policy: key, state -> action.  Defaults to uniform;
    # the Atari experiments plug a distilled policy network here (App. D).
    rollout_policy: Optional[Callable[[jax.Array, Pytree], jax.Array]] = None
    # Optional value bootstrap V(s) used to truncate simulations (App. D).
    value_fn: Optional[Callable[[Pytree], jax.Array]] = None
    # Optional observation extractor for policy/value networks.
    observe: Optional[Callable[[Pytree], jax.Array]] = None

    def policy(self, key: jax.Array, state: Pytree) -> jax.Array:
        if self.rollout_policy is not None:
            return self.rollout_policy(key, state)
        return jax.random.randint(key, (), 0, self.num_actions, jnp.int32)
