"""Tabular stochastic MDP (Garnet-style) with chance folded into the state key.

Transitions are categorical draws from a fixed table; the draw consumes the
PRNG key stored in the state, so ``step`` stays deterministic-given-state as
the MCTS contract requires while the *environment* is genuinely stochastic —
the same regime as the Joy City levels ("high randomness in the transition").
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .base import Environment


class RandomMDPState(NamedTuple):
    s: jax.Array      # i32[] current tabular state
    t: jax.Array      # i32[] timestep
    key: jax.Array    # u32[2] chance key
    done: jax.Array   # bool[]


def make_random_mdp(
    num_states: int = 32,
    num_actions: int = 4,
    horizon: int = 20,
    branching: int = 4,
    seed: int = 0,
) -> Environment:
    base = jax.random.PRNGKey(seed)
    k_p, k_r, k_succ = jax.random.split(base, 3)
    # Each (s, a) can land on `branching` successor states with dirichlet probs.
    succ = jax.random.randint(
        k_succ, (num_states, num_actions, branching), 0, num_states, jnp.int32
    )
    probs = jax.random.dirichlet(
        k_p, jnp.ones((branching,)), (num_states, num_actions)
    ).astype(jnp.float32)
    rewards = jax.random.uniform(k_r, (num_states, num_actions), jnp.float32)

    def init(key: jax.Array) -> RandomMDPState:
        return RandomMDPState(
            jnp.int32(0), jnp.int32(0), jax.random.fold_in(key, 7), jnp.bool_(False)
        )

    def step(state: RandomMDPState, action: jax.Array):
        action = jnp.asarray(action, jnp.int32)
        key, sub = jax.random.split(state.key)
        branch = jax.random.categorical(sub, jnp.log(probs[state.s, action]))
        s_next = succ[state.s, action, branch]
        r = rewards[state.s, action]
        t = state.t + 1
        done = t >= horizon
        nxt = RandomMDPState(
            s=jnp.where(state.done, state.s, s_next),
            t=jnp.where(state.done, state.t, t),
            key=key,
            done=state.done | done,
        )
        return nxt, jnp.where(state.done, 0.0, r), nxt.done

    def observe(state: RandomMDPState) -> jax.Array:
        return jax.nn.one_hot(state.s, num_states, dtype=jnp.float32)

    return Environment(
        name=f"random_mdp(s={num_states},a={num_actions},h={horizon})",
        num_actions=num_actions,
        init=init,
        step=step,
        observe=observe,
    )
