"""Tap-elimination game — a faithful JAX analogue of the paper's "Joy City".

Mechanics (App. C.1): a ``G×G`` grid of colored items; tapping a cell whose
same-color connected region has size ≥ 2 eliminates the region; the remaining
cells collapse downward (gravity) and empty cells at the top are refilled with
random colors (the stochastic transition).  The goal is to eliminate a target
count of the goal color within a step budget; the number of steps used ("game
step") is the performance metric, exactly as in Sec. 5.1.

Everything is jittable: flood fill is an iterated 4-neighbour dilation inside
a ``lax.while_loop``; gravity is a stable per-column argsort; refill consumes
the PRNG key carried in the state (so ``step`` is deterministic given state,
as MCTS requires).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .base import Environment

EMPTY = jnp.int8(-1)


class TapGameState(NamedTuple):
    grid: jax.Array        # i8[G, G]  (row 0 = top)
    steps_left: jax.Array  # i32[]
    goal_left: jax.Array   # i32[]  remaining goal-color cells to eliminate
    key: jax.Array         # u32[2] chance key for refills
    done: jax.Array        # bool[]


def _flood_fill(grid: jax.Array, r: jax.Array, c: jax.Array) -> jax.Array:
    """Boolean mask of the same-color connected region containing (r, c)."""
    g = grid.shape[0]
    color = grid[r, c]
    same = (grid == color) & (grid != EMPTY)
    seed = jnp.zeros_like(same).at[r, c].set(True) & same

    def dilate(mask):
        up = jnp.pad(mask[1:], ((0, 1), (0, 0)))
        down = jnp.pad(mask[:-1], ((1, 0), (0, 0)))
        left = jnp.pad(mask[:, 1:], ((0, 0), (0, 1)))
        right = jnp.pad(mask[:, :-1], ((0, 0), (1, 0)))
        return (mask | up | down | left | right) & same

    def cond(carry):
        mask, prev_n = carry
        return jnp.sum(mask) != prev_n

    def body(carry):
        mask, _ = carry
        return dilate(mask), jnp.sum(mask)

    mask, _ = jax.lax.while_loop(cond, body, (seed, jnp.int32(-1)))
    return mask


def _gravity(grid: jax.Array) -> jax.Array:
    """Compact non-empty cells downward per column (stable)."""
    empty = grid == EMPTY
    # Stable sort per column: False (non-empty) sorts before True, so we sort
    # by `~empty` descending... we want empties first (top).  argsort of
    # `empty` descending == empties on top.  Use stable argsort of (~empty).
    order = jnp.argsort(~empty, axis=0, stable=True)  # empties (False) first
    return jnp.take_along_axis(grid, order, axis=0)


def _refill(grid: jax.Array, key: jax.Array, num_colors: int) -> jax.Array:
    fresh = jax.random.randint(key, grid.shape, 0, num_colors, jnp.int8)
    return jnp.where(grid == EMPTY, fresh, grid)


def make_tap_game(
    grid_size: int = 6,
    num_colors: int = 4,
    goal_color: int = 0,
    goal_count: int = 12,
    step_budget: int = 20,
    refill: bool = True,
) -> Environment:
    g = grid_size

    def init(key: jax.Array) -> TapGameState:
        k_grid, k_state = jax.random.split(key)
        grid = jax.random.randint(k_grid, (g, g), 0, num_colors, jnp.int8)
        return TapGameState(
            grid=grid,
            steps_left=jnp.int32(step_budget),
            goal_left=jnp.int32(goal_count),
            key=k_state,
            done=jnp.bool_(False),
        )

    def step(state: TapGameState, action: jax.Array):
        action = jnp.asarray(action, jnp.int32)
        r, c = action // g, action % g
        mask = _flood_fill(state.grid, r, c)
        size = jnp.sum(mask)
        tapped_valid = (state.grid[r, c] != EMPTY) & (size >= 2)

        eliminated = jnp.where(tapped_valid & mask, 1, 0)
        goal_hit = jnp.sum(
            eliminated * (state.grid == jnp.int8(goal_color)).astype(jnp.int32)
        )
        new_grid = jnp.where(tapped_valid & mask, EMPTY, state.grid)
        new_grid = _gravity(new_grid)
        key, k_fill = jax.random.split(state.key)
        if refill:
            new_grid = _refill(new_grid, k_fill, num_colors)

        goal_left = jnp.maximum(state.goal_left - goal_hit, 0)
        steps_left = state.steps_left - 1
        won = goal_left == 0
        done = won | (steps_left <= 0)

        # Reward shaping: progress toward the goal, a small penalty per step
        # (so fewer game steps = higher return, matching the paper's metric),
        # and a terminal win bonus.
        reward = (
            goal_hit.astype(jnp.float32) / float(goal_count)
            - 0.01
            + jnp.where(won & ~state.done, 1.0, 0.0)
        )
        nxt = TapGameState(
            grid=jnp.where(state.done, state.grid, new_grid),
            steps_left=jnp.where(state.done, state.steps_left, steps_left),
            goal_left=jnp.where(state.done, state.goal_left, goal_left),
            key=key,
            done=state.done | done,
        )
        return nxt, jnp.where(state.done, 0.0, reward), nxt.done

    def rollout_policy(key: jax.Array, state: TapGameState) -> jax.Array:
        """Greedy-ish default policy: prefer cells in large regions of the
        goal color; cheap proxy — tap a random cell whose 4-neighbourhood
        contains a same-color neighbour, biased toward the goal color."""
        grid = state.grid
        up = jnp.pad(grid[1:], ((0, 1), (0, 0)), constant_values=-2)
        down = jnp.pad(grid[:-1], ((1, 0), (0, 0)), constant_values=-2)
        left = jnp.pad(grid[:, 1:], ((0, 0), (0, 1)), constant_values=-2)
        right = jnp.pad(grid[:, :-1], ((0, 0), (1, 0)), constant_values=-2)
        has_pair = (
            (grid == up) | (grid == down) | (grid == left) | (grid == right)
        ) & (grid != EMPTY)
        is_goal = grid == jnp.int8(goal_color)
        logits = (
            jnp.where(has_pair, 0.0, -1e9)
            + jnp.where(is_goal, 2.0, 0.0)
        ).reshape(-1)
        # Fall back to uniform if no pair exists anywhere.
        logits = jnp.where(
            jnp.any(has_pair), logits, jnp.zeros_like(logits)
        )
        return jax.random.categorical(key, logits).astype(jnp.int32)

    def observe(state: TapGameState) -> jax.Array:
        onehot = jax.nn.one_hot(
            state.grid.astype(jnp.int32), num_colors, dtype=jnp.float32
        )
        extras = jnp.stack(
            [
                state.steps_left.astype(jnp.float32) / step_budget,
                state.goal_left.astype(jnp.float32) / goal_count,
            ]
        )
        return jnp.concatenate([onehot.reshape(-1), extras])

    return Environment(
        name=f"tap_game(g={g},colors={num_colors})",
        num_actions=g * g,
        init=init,
        step=step,
        rollout_policy=rollout_policy,
        observe=observe,
    )
