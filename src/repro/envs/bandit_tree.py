"""Synthetic tree-structured MDP with a known optimum.

A depth-``D``, branching-``A`` tree whose edge rewards are pseudo-random but
*fixed by a seed* (hashed from the implicit node id), so that the optimal
return and the optimal first action are computable exactly by dynamic
programming.  This is the instrument we use to measure the failure modes the
paper describes analytically:

* **collapse of exploration** — identical selections by concurrent workers;
  observable as low entropy of visited leaves,
* **exploitation failure** — virtual loss repelling workers from the known
  best branch; observable as regret vs. the exact optimum.

Implicit heap indexing: ``child(n, a) = n * A + a + 1``.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .base import Environment


class BanditTreeState(NamedTuple):
    node: jax.Array    # i32[] implicit node id
    depth: jax.Array   # i32[]
    done: jax.Array    # bool[]


def _edge_reward(seed: int, node: jax.Array, action: jax.Array, num_actions: int):
    """Deterministic per-edge reward in [0, 1), hashed from (node, action)."""
    child = node * num_actions + action + 1
    key = jax.random.fold_in(jax.random.PRNGKey(seed), child)
    return jax.random.uniform(key, (), jnp.float32)


def make_bandit_tree(depth: int = 5, num_actions: int = 4, seed: int = 0) -> Environment:
    def init(key: jax.Array) -> BanditTreeState:
        del key
        return BanditTreeState(jnp.int32(0), jnp.int32(0), jnp.bool_(False))

    def step(state: BanditTreeState, action: jax.Array):
        action = jnp.asarray(action, jnp.int32)
        r = _edge_reward(seed, state.node, action, num_actions)
        child = state.node * num_actions + action + 1
        new_depth = state.depth + 1
        done = new_depth >= depth
        # No-op after termination.
        nxt = BanditTreeState(
            node=jnp.where(state.done, state.node, child),
            depth=jnp.where(state.done, state.depth, new_depth),
            done=state.done | done,
        )
        r = jnp.where(state.done, 0.0, r)
        return nxt, r, nxt.done

    def observe(state: BanditTreeState) -> jax.Array:
        return jnp.stack(
            [state.node.astype(jnp.float32), state.depth.astype(jnp.float32)]
        )

    return Environment(
        name=f"bandit_tree(d={depth},a={num_actions},seed={seed})",
        num_actions=num_actions,
        init=init,
        step=step,
        observe=observe,
    )


def solve_bandit_tree(
    depth: int, num_actions: int, seed: int, gamma: float = 1.0
) -> tuple[float, int, np.ndarray]:
    """Exact DP solution: (optimal return, optimal first action, Q_root)."""
    rng = jax.random.PRNGKey(seed)

    def edge_r(node: int, action: int) -> float:
        child = node * num_actions + action + 1
        key = jax.random.fold_in(rng, child)
        return float(jax.random.uniform(key, (), jnp.float32))

    from functools import lru_cache

    import sys

    sys.setrecursionlimit(10000)

    @lru_cache(maxsize=None)
    def value(node: int, d: int) -> float:
        if d >= depth:
            return 0.0
        return max(
            edge_r(node, a) + gamma * value(node * num_actions + a + 1, d + 1)
            for a in range(num_actions)
        )

    q_root = np.array(
        [
            edge_r(0, a) + gamma * value(a + 1, 1)
            for a in range(num_actions)
        ],
        np.float64,
    )
    return float(q_root.max()), int(q_root.argmax()), q_root
