"""WU-UCT: parallel MCTS ("Watch the Unobserved", ICLR 2020) as a JAX
framework — search core, environments, 10 LM architectures, training,
serving, distribution, Pallas TPU kernels.  See README.md / DESIGN.md."""

__version__ = "1.0.0"
