"""whisper-small [arXiv:2212.04356].

Enc-dec: 12 encoder + 12 decoder layers, d=768 12H (MHA) d_ff=3072 V=51865.
The conv frontend is STUBBED per the assignment: ``input_specs()`` supplies
1500 precomputed frame embeddings.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    num_layers=12,
    num_encoder_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    encoder_seq=1500,
)
