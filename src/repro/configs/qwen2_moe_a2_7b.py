"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B; hf].

24L d_model=2048 16H (GQA kv=16) vocab=151936; MoE: 60 routed experts top-4
(per-expert d_ff=1408) + 4 shared experts (fused as one 4x1408=5632 SwiGLU).
60 experts are padded to 64 at sharding time for EP divisibility (router
logits of pad experts masked; see distributed/sharding.py).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    num_experts=60,
    num_experts_per_tok=4,
    moe_d_ff=1408,
    shared_expert_d_ff=5632,
    qkv_bias=True,
)
