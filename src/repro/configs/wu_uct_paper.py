"""The paper's own experiment configuration (Sec. 5 / App. D).

Atari setup: 128 simulations, 16 simulation workers, search width 20, depth
100, gamma 0.99, 100-step rollouts with value bootstrap mixing 0.5.
Tap-game setup: width 5, depth 10, 10/100 simulations.
"""
from ..core.policies import PolicyConfig
from ..core.wu_uct import SearchConfig

ATARI = SearchConfig(
    num_simulations=128,
    wave_size=16,
    max_depth=100,
    max_sim_steps=100,
    max_width=20,
    gamma=0.99,
    policy=PolicyConfig(kind="wu_uct", beta=1.0),
    stat_mode="wu",
    value_mix=0.5,
)

TAP_GAME = SearchConfig(
    num_simulations=100,
    wave_size=16,
    max_depth=10,
    max_sim_steps=20,
    max_width=5,
    gamma=1.0,
    policy=PolicyConfig(kind="wu_uct", beta=1.0),
    stat_mode="wu",
)
