"""qwen2.5-32b [hf:Qwen/Qwen2.5 family]. 64L d=5120 40H (GQA kv=8) d_ff=27648 V=152064, QKV bias."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=27648,
    vocab_size=152064,
    qkv_bias=True,
)
