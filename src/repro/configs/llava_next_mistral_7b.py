"""llava-next-mistral-7b [hf:llava-hf/llava-v1.6-mistral-7b-hf].

Mistral-7B backbone: 32L d=4096 32H (GQA kv=8) d_ff=14336 V=32000.  The
anyres vision tower + projector are STUBBED per the assignment:
``input_specs()`` supplies 576 precomputed (post-projector) patch embeddings
prepended to the token stream.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    num_patches=576,
)
