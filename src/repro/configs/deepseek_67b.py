"""deepseek-67b [arXiv:2401.02954]. 95L d=8192 64H (GQA kv=8) d_ff=22016 V=102400."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    family="dense",
    num_layers=95,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=102400,
)
