"""qwen3-moe-235b-a22b [hf:Qwen/Qwen3-30B-A3B family; hf].

94L d_model=4096 64H (GQA kv=4) vocab=151936; MoE: 128 routed experts top-8,
per-expert d_ff=1536.  No shared experts.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    d_ff=1536,
    vocab_size=151936,
    num_experts=128,
    num_experts_per_tok=8,
    moe_d_ff=1536,
)
