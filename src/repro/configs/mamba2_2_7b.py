"""mamba2-2.7b [arXiv:2405.21060]. 64L d=2560 (attention-free), state=128, V=50280."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    head_dim=1,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
)
