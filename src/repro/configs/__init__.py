"""Assigned-architecture registry (10 archs) + the paper's own search config.

Every module defines ``CONFIG`` with the exact public-literature dimensions
from the assignment; ``reduced()`` variants drive the CPU smoke tests.
"""

from __future__ import annotations

import importlib

from ..models.config import ModelConfig, reduced

ARCHS = [
    "qwen2_moe_a2_7b",
    "qwen3_moe_235b_a22b",
    "llama3_8b",
    "phi3_medium_14b",
    "deepseek_67b",
    "qwen2_5_32b",
    "llava_next_mistral_7b",
    "zamba2_7b",
    "mamba2_2_7b",
    "whisper_small",
]

# assignment ids (with dashes/dots) → module names
ALIASES = {
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "llama3-8b": "llama3_8b",
    "phi3-medium-14b": "phi3_medium_14b",
    "deepseek-67b": "deepseek_67b",
    "qwen2.5-32b": "qwen2_5_32b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "zamba2-7b": "zamba2_7b",
    "mamba2-2.7b": "mamba2_2_7b",
    "whisper-small": "whisper_small",
}


def get_config(name: str) -> ModelConfig:
    mod_name = ALIASES.get(name, name.replace("-", "_").replace(".", "_"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def get_reduced(name: str, **overrides) -> ModelConfig:
    return reduced(get_config(name), **overrides)


def list_archs() -> list[str]:
    return list(ALIASES.keys())
