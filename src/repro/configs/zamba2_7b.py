"""zamba2-7b [arXiv:2411.15242].

81 Mamba-2 blocks (d_model=3584, state=64) with ONE shared transformer block
(32H MHA kv=32, d_ff=14336) applied every 6 layers (14 application sites,
separate KV cache per site, shared weights) — the Zamba2 weight-sharing
pattern.  Concatenated-input variant simplified to residual application
(noted in DESIGN.md).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    attn_every=6,
)
