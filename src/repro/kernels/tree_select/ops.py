"""Jit'd public wrapper for the batched tree-selection kernel."""

from __future__ import annotations

import functools

import jax

from .tree_select import tree_select_fwd


@functools.partial(jax.jit, static_argnames=("beta", "block_b", "interpret"))
def tree_select(
    n_c, o_c, v_c, n_p, o_p, valid, *, beta: float = 1.0, block_b: int = 256,
    interpret: bool | None = None,
):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return tree_select_fwd(
        n_c, o_c, v_c, n_p, o_p, valid,
        beta=beta, block_b=block_b, interpret=interpret,
    )
