"""Jit'd public wrapper for the batched tree-selection kernel."""

from __future__ import annotations

import functools

import jax

from .tree_select import tree_select_fwd


@functools.partial(
    jax.jit,
    static_argnames=("kind", "beta", "r_vl", "n_vl", "block_b", "interpret"),
)
def tree_select(
    n_c, o_c, v_c, n_p, o_p, valid, vl_c=None, *,
    kind: str = "wu_uct", beta: float = 1.0, r_vl: float = 1.0,
    n_vl: float = 1.0, block_b: int = 256, interpret: bool | None = None,
):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return tree_select_fwd(
        n_c, o_c, v_c, n_p, o_p, valid, vl_c,
        kind=kind, beta=beta, r_vl=r_vl, n_vl=n_vl,
        block_b=block_b, interpret=interpret,
    )
