"""Pure-jnp oracle for the batched tree-selection kernel (all score kinds).

Delegates the per-kind score math to :func:`..tree_select._scores` — the
same jnp expression the Pallas kernel traces — so the oracle and the kernel
cannot drift; what this module adds is only the non-fused mask + argmax.
"""

from __future__ import annotations

import jax.numpy as jnp

from .tree_select import NEG_INF, _scores


def tree_select_ref(
    n_c,
    o_c,
    v_c,
    n_p,
    o_p,
    valid,
    vl_c=None,
    *,
    kind: str = "wu_uct",
    beta: float = 1.0,
    r_vl: float = 1.0,
    n_vl: float = 1.0,
):
    n_c = n_c.astype(jnp.float32)
    o_c = o_c.astype(jnp.float32)
    v_c = v_c.astype(jnp.float32)
    vl_c = jnp.zeros_like(v_c) if vl_c is None else vl_c.astype(jnp.float32)
    score = _scores(
        n_c, o_c, v_c, vl_c,
        n_p.astype(jnp.float32)[:, None], o_p.astype(jnp.float32)[:, None],
        kind=kind, beta=beta, r_vl=r_vl, n_vl=n_vl,
    )
    score = jnp.where(valid, score, NEG_INF)
    return jnp.argmax(score, axis=1).astype(jnp.int32), jnp.max(score, axis=1)
