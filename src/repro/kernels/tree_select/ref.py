"""Pure-jnp oracle for the batched WU-UCT selection kernel."""

from __future__ import annotations

import jax.numpy as jnp


def tree_select_ref(n_c, o_c, v_c, n_p, o_p, valid, beta: float = 1.0):
    n_c = n_c.astype(jnp.float32)
    o_c = o_c.astype(jnp.float32)
    v_c = v_c.astype(jnp.float32)
    log_term = jnp.log(jnp.maximum(n_p + o_p, 1.0))[:, None]
    denom = n_c + o_c
    explore = beta * jnp.sqrt(2.0 * log_term / jnp.maximum(denom, 1e-9))
    score = v_c + jnp.where(denom > 0, explore, jnp.inf)
    score = jnp.where(valid, score, -1e30)
    return jnp.argmax(score, axis=1).astype(jnp.int32), jnp.max(score, axis=1)
