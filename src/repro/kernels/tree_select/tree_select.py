"""Pallas TPU kernel: fused tree-policy selection over batched children tables.

The master-side hot op of every selection rule in this package is

    a = argmax_a  score_kind(child stats, parent stats)

For batched multi-root search (``B`` trees advancing in lockstep — the
throughput mode of this framework), the statistics of all children of the
``B`` current nodes are gathered into dense ``[B, A]`` tables and this kernel
fuses score computation + masked argmax in one VMEM pass, instead of
materializing scores and running a separate argmax reduction.  One program
handles a ``[block_b, A]`` tile.

Score variants (``kind``) mirror :func:`repro.core.policies.child_scores`,
which stays the interpret-mode reference:

* ``wu_uct``   — paper eq. (4): unobserved counts ``O`` correct both terms.
* ``uct``      — paper eq. (2): classic UCB1-over-trees.
* ``treep``    — eq. (2) over virtual-loss-adjusted values ``V − VL``.
* ``treep_vc`` — eq. (7), App. E: virtual loss + virtual pseudo-count with
                 ``c = O`` in-flight queries, applied non-destructively.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

KINDS = ("wu_uct", "uct", "treep", "treep_vc")


def _scores(nc, oc, vc, vlc, n_p, o_p, *, kind, beta, r_vl, n_vl):
    """Per-action scores for a [bb, A] tile; ops mirror policies.child_scores
    exactly (same order, same clamps) so tie-breaks agree bitwise."""
    if kind == "wu_uct":
        log_term = jnp.log(jnp.maximum(n_p + o_p, 1.0))          # [bb, 1]
        denom = nc + oc
        explore = beta * jnp.sqrt(2.0 * log_term / jnp.maximum(denom, 1e-9))
        explore = jnp.where(denom > 0, explore, jnp.inf)
        return vc + explore
    if kind == "uct":
        log_term = jnp.log(jnp.maximum(n_p, 1.0))
        explore = beta * jnp.sqrt(2.0 * log_term / jnp.maximum(nc, 1e-9))
        explore = jnp.where(nc > 0, explore, jnp.inf)
        return vc + explore
    if kind == "treep":
        log_term = jnp.log(jnp.maximum(n_p, 1.0))
        explore = beta * jnp.sqrt(2.0 * log_term / jnp.maximum(nc, 1e-9))
        explore = jnp.where(nc > 0, explore, jnp.inf)
        return (vc - vlc) + explore
    if kind == "treep_vc":
        c = oc
        v_adj = (nc * vc - c * r_vl) / jnp.maximum(nc + c * n_vl, 1e-9)
        log_term = jnp.log(jnp.maximum(n_p + o_p, 1.0))
        denom = nc + c * n_vl
        explore = beta * jnp.sqrt(2.0 * log_term / jnp.maximum(denom, 1e-9))
        explore = jnp.where(denom > 0, explore, jnp.inf)
        return v_adj + explore
    raise ValueError(f"unknown policy kind: {kind}")


def _select_kernel(
    nc_ref,     # [block_b, A] child N
    oc_ref,     # [block_b, A] child O
    vc_ref,     # [block_b, A] child V
    vlc_ref,    # [block_b, A] child VL (virtual-loss accumulator)
    np_ref,     # [block_b, 1] parent N
    op_ref,     # [block_b, 1] parent O
    valid_ref,  # [block_b, A] i32 mask
    act_ref,    # [block_b, 1] i32 out — argmax action
    score_ref,  # [block_b, 1] f32 out — best score
    *,
    kind: str,
    beta: float,
    r_vl: float,
    n_vl: float,
):
    nc = nc_ref[...].astype(jnp.float32)
    oc = oc_ref[...].astype(jnp.float32)
    vc = vc_ref[...].astype(jnp.float32)
    vlc = vlc_ref[...].astype(jnp.float32)
    n_p = np_ref[...].astype(jnp.float32)
    o_p = op_ref[...].astype(jnp.float32)
    valid = valid_ref[...] != 0

    score = _scores(nc, oc, vc, vlc, n_p, o_p, kind=kind, beta=beta,
                    r_vl=r_vl, n_vl=n_vl)
    score = jnp.where(valid, score, NEG_INF)

    best = jnp.max(score, axis=1, keepdims=True)              # [bb, 1]
    bb, a = score.shape
    idx = jax.lax.broadcasted_iota(jnp.int32, (bb, a), 1)
    # first argmax: smallest index achieving the max
    cand = jnp.where(score == best, idx, a)
    act_ref[...] = jnp.min(cand, axis=1, keepdims=True).astype(jnp.int32)
    score_ref[...] = best


def tree_select_fwd(
    n_c: jax.Array,     # [B, A]
    o_c: jax.Array,     # [B, A]
    v_c: jax.Array,     # [B, A]
    n_p: jax.Array,     # [B]
    o_p: jax.Array,     # [B]
    valid: jax.Array,   # [B, A] bool
    vl_c: jax.Array | None = None,  # [B, A] (TreeP only; zeros if None)
    *,
    kind: str = "wu_uct",
    beta: float = 1.0,
    r_vl: float = 1.0,
    n_vl: float = 1.0,
    block_b: int = 256,
    interpret: bool = True,
):
    if kind not in KINDS:
        raise ValueError(f"unknown policy kind: {kind!r}; expected one of {KINDS}")
    b, a = n_c.shape
    if vl_c is None:
        vl_c = jnp.zeros_like(v_c)
    block_b = min(block_b, b)
    # Pad the batch axis up to a block multiple; padded rows are all-invalid.
    pad = (-b) % block_b
    if pad:
        pad2 = lambda x: jnp.pad(x, ((0, pad), (0, 0)))
        pad1 = lambda x: jnp.pad(x, ((0, pad),))
        n_c, o_c, v_c, vl_c = map(pad2, (n_c, o_c, v_c, vl_c))
        valid = jnp.pad(valid, ((0, pad), (0, 0)))
        n_p, o_p = pad1(n_p), pad1(o_p)
    bp = b + pad
    kernel = functools.partial(
        _select_kernel, kind=kind, beta=beta, r_vl=r_vl, n_vl=n_vl
    )
    act, score = pl.pallas_call(
        kernel,
        grid=(bp // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, a), lambda i: (i, 0)),
            pl.BlockSpec((block_b, a), lambda i: (i, 0)),
            pl.BlockSpec((block_b, a), lambda i: (i, 0)),
            pl.BlockSpec((block_b, a), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_b, a), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp, 1), jnp.int32),
            jax.ShapeDtypeStruct((bp, 1), jnp.float32),
        ],
        interpret=interpret,
    )(
        n_c,
        o_c,
        v_c,
        vl_c,
        n_p.reshape(bp, 1),
        o_p.reshape(bp, 1),
        valid.astype(jnp.int32),
    )
    return act[:b, 0], score[:b, 0]
