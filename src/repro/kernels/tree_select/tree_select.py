"""Pallas TPU kernel: fused WU-UCT selection over batched children tables.

The paper's master-side hot op is eq. (4):

    a = argmax_a  V'_a + β·sqrt(2·log(N_p + O_p) / (N'_a + O'_a))

For batched search (many trees / many nodes per wave — the throughput mode of
this framework), the statistics of all children of B nodes are gathered into
dense [B, A] tables and this kernel fuses score computation + masked argmax
in one VMEM pass, instead of materializing scores and running a separate
argmax reduction.  One program handles a [block_b, A] tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _select_kernel(
    nc_ref,     # [block_b, A] child N
    oc_ref,     # [block_b, A] child O
    vc_ref,     # [block_b, A] child V
    np_ref,     # [block_b, 1] parent N
    op_ref,     # [block_b, 1] parent O
    valid_ref,  # [block_b, A] i32 mask
    act_ref,    # [block_b, 1] i32 out — argmax action
    score_ref,  # [block_b, 1] f32 out — best score
    *,
    beta: float,
):
    nc = nc_ref[...].astype(jnp.float32)
    oc = oc_ref[...].astype(jnp.float32)
    vc = vc_ref[...].astype(jnp.float32)
    n_p = np_ref[...].astype(jnp.float32)
    o_p = op_ref[...].astype(jnp.float32)
    valid = valid_ref[...] != 0

    log_term = jnp.log(jnp.maximum(n_p + o_p, 1.0))           # [bb, 1]
    denom = nc + oc
    explore = beta * jnp.sqrt(2.0 * log_term / jnp.maximum(denom, 1e-9))
    score = vc + jnp.where(denom > 0, explore, jnp.inf)
    score = jnp.where(valid, score, NEG_INF)

    best = jnp.max(score, axis=1, keepdims=True)              # [bb, 1]
    bb, a = score.shape
    idx = jax.lax.broadcasted_iota(jnp.int32, (bb, a), 1)
    # first argmax: smallest index achieving the max
    cand = jnp.where(score == best, idx, a)
    act_ref[...] = jnp.min(cand, axis=1, keepdims=True).astype(jnp.int32)
    score_ref[...] = best


def tree_select_fwd(
    n_c: jax.Array,     # [B, A]
    o_c: jax.Array,     # [B, A]
    v_c: jax.Array,     # [B, A]
    n_p: jax.Array,     # [B]
    o_p: jax.Array,     # [B]
    valid: jax.Array,   # [B, A] bool
    *,
    beta: float = 1.0,
    block_b: int = 256,
    interpret: bool = True,
):
    b, a = n_c.shape
    block_b = min(block_b, b)
    assert b % block_b == 0
    kernel = functools.partial(_select_kernel, beta=beta)
    act, score = pl.pallas_call(
        kernel,
        grid=(b // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, a), lambda i: (i, 0)),
            pl.BlockSpec((block_b, a), lambda i: (i, 0)),
            pl.BlockSpec((block_b, a), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_b, a), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, 1), jnp.int32),
            jax.ShapeDtypeStruct((b, 1), jnp.float32),
        ],
        interpret=interpret,
    )(
        n_c,
        o_c,
        v_c,
        n_p.reshape(b, 1),
        o_p.reshape(b, 1),
        valid.astype(jnp.int32),
    )
    return act[:, 0], score[:, 0]
