"""Pure-jnp oracle for decode attention (thin wrapper over models.layers)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ...models.layers import decode_attention as _ref
from ...models.layers import paged_decode_attention as _paged_ref
from ...models.layers import paged_tree_decode_attention as _paged_tree_ref
from ...models.layers import tree_decode_attention as _tree_ref


def decode_attention_ref(q, k_cache, v_cache, kv_len):
    # models.layers.decode_attention takes [B, 1, Hq, D].
    out = _ref(q[:, None], k_cache, v_cache, jnp.asarray(kv_len))
    return out[:, 0]


def paged_decode_attention_ref(q, pool_k, pool_v, page_table, kv_len):
    # Dense-gather oracle: materialize each row's pages, then ragged decode.
    out = _paged_ref(
        q[:, None], pool_k, pool_v, page_table, jnp.asarray(kv_len)
    )
    return out[:, 0]


def tree_decode_attention_ref(
    q, k_cache, v_cache, k_spec, v_spec, kv_len, tree_mask=None
):
    return _tree_ref(
        q, k_cache, v_cache, k_spec, v_spec, jnp.asarray(kv_len), tree_mask
    )


def paged_tree_decode_attention_ref(
    q, pool_k, pool_v, page_table, k_spec, v_spec, kv_len, tree_mask=None
):
    return _paged_tree_ref(
        q, pool_k, pool_v, page_table, k_spec, v_spec,
        jnp.asarray(kv_len), tree_mask,
    )
