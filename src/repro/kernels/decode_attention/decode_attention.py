"""Pallas TPU kernel: single-token GQA decode attention over a long KV cache.

Decode is memory-bound (the whole valid KV prefix streams through VMEM once
per token), so the kernel's job is to keep that stream dense: KV blocks of
``block_k`` rows are brought in along a sequential grid axis while the
online-softmax state (m, l, acc) for all q heads of one batch element stays
resident in VMEM scratch.  Blocks entirely beyond ``kv_len`` are skipped —
with a ring-buffer cache the skipped tail costs no HBM traffic.

Layout: all q heads of one batch element are processed together
([Hq, D] tile), so each KV block is read once per batch element rather than
once per head — the GQA bandwidth saving that motivates grouped KV.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(
    len_ref,    # [B] i32 (SMEM) — per-batch valid KV prefix length
    q_ref,      # [Hq, D]
    k_ref,      # [block_k, Hkv, D]
    v_ref,      # [block_k, Hkv, D]
    o_ref,      # [Hq, D]
    m_scr,      # [Hq, 1] f32
    l_scr,      # [Hq, 1] f32
    acc_scr,    # [Hq, D] f32
    *,
    scale: float,
    block_k: int,
    n_kv: int,
    group: int,
):
    ki = pl.program_id(1)
    kv_len = len_ref[pl.program_id(0)]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(ki * block_k < kv_len)
    def _compute():
        q = q_ref[...].astype(jnp.float32)                    # [Hq, D]
        k = k_ref[...].astype(jnp.float32)                    # [bk, Hkv, D]
        v = v_ref[...].astype(jnp.float32)
        bk, hkv, dd = k.shape
        hq = q.shape[0]
        # scores[h, j] = q[h] · k[j, h // group]
        kg = jnp.repeat(k, group, axis=1)                     # [bk, Hq, D]
        s = jnp.einsum("hd,jhd->hj", q, kg) * scale           # [Hq, bk]
        kv_pos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (hq, bk), 1
        )
        valid = kv_pos < kv_len
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        vg = jnp.repeat(v, group, axis=1)                     # [bk, Hq, D]
        acc_scr[...] = acc_scr[...] * alpha + jnp.einsum("hj,jhd->hd", p, vg)
        m_scr[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        o_ref[...] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-20)
        ).astype(o_ref.dtype)


def decode_attention_fwd(
    q: jax.Array,        # [B, Hq, D]
    k_cache: jax.Array,  # [B, S, Hkv, D]
    v_cache: jax.Array,  # [B, S, Hkv, D]
    kv_len: jax.Array,   # [] or [B] i32 — ragged per-batch prefix lengths
    *,
    block_k: int = 512,
    interpret: bool = True,
) -> jax.Array:
    b, hq, d = q.shape
    _, s, hkv, _ = k_cache.shape
    group = hq // hkv
    block_k = min(block_k, s)
    assert s % block_k == 0
    n_kv = s // block_k
    scale = 1.0 / math.sqrt(d)
    # Scalar and per-batch (continuous batching / async-slot cache) lengths
    # share one kernel: the scalar broadcasts to a [B] SMEM vector.
    lens = jnp.broadcast_to(
        jnp.asarray(kv_len, jnp.int32).reshape(-1), (b,)
    )

    kernel = functools.partial(
        _decode_kernel, scale=scale, block_k=block_k, n_kv=n_kv, group=group
    )

    out = pl.pallas_call(
        kernel,
        grid=(b, n_kv),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((None, hq, d), lambda bi, ki: (bi, 0, 0)),
            pl.BlockSpec((None, block_k, hkv, d), lambda bi, ki: (bi, ki, 0, 0)),
            pl.BlockSpec((None, block_k, hkv, d), lambda bi, ki: (bi, ki, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, hq, d), lambda bi, ki: (bi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((hq, 1), jnp.float32),
            pltpu.VMEM((hq, 1), jnp.float32),
            pltpu.VMEM((hq, d), jnp.float32),
        ],
        interpret=interpret,
        **(
            {}
            if interpret
            else {
                "compiler_params": pltpu.CompilerParams(
                    dimension_semantics=("parallel", "arbitrary")
                )
            }
        ),
    )(lens, q, k_cache, v_cache)
    return out
