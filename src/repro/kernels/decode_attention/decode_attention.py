"""Pallas TPU kernel: single-token GQA decode attention over a long KV cache.

Decode is memory-bound (the whole valid KV prefix streams through VMEM once
per token), so the kernel's job is to keep that stream dense: KV blocks of
``block_k`` rows are brought in along a sequential grid axis while the
online-softmax state (m, l, acc) for all q heads of one batch element stays
resident in VMEM scratch.  Blocks entirely beyond ``kv_len`` are skipped —
with a ring-buffer cache the skipped tail costs no HBM traffic.

Layout: all q heads of one batch element are processed together
([Hq, D] tile), so each KV block is read once per batch element rather than
once per head — the GQA bandwidth saving that motivates grouped KV.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(
    len_ref,    # [B] i32 (SMEM) — per-batch valid KV prefix length
    q_ref,      # [Hq, D]
    k_ref,      # [block_k, Hkv, D]
    v_ref,      # [block_k, Hkv, D]
    o_ref,      # [Hq, D]
    m_scr,      # [Hq, 1] f32
    l_scr,      # [Hq, 1] f32
    acc_scr,    # [Hq, D] f32
    *,
    scale: float,
    block_k: int,
    n_kv: int,
    group: int,
):
    ki = pl.program_id(1)
    kv_len = len_ref[pl.program_id(0)]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(ki * block_k < kv_len)
    def _compute():
        q = q_ref[...].astype(jnp.float32)                    # [Hq, D]
        k = k_ref[...].astype(jnp.float32)                    # [bk, Hkv, D]
        v = v_ref[...].astype(jnp.float32)
        bk, hkv, dd = k.shape
        hq = q.shape[0]
        # scores[h, j] = q[h] · k[j, h // group]
        kg = jnp.repeat(k, group, axis=1)                     # [bk, Hq, D]
        s = jnp.einsum("hd,jhd->hj", q, kg) * scale           # [Hq, bk]
        kv_pos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (hq, bk), 1
        )
        valid = kv_pos < kv_len
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        vg = jnp.repeat(v, group, axis=1)                     # [bk, Hq, D]
        acc_scr[...] = acc_scr[...] * alpha + jnp.einsum("hj,jhd->hd", p, vg)
        m_scr[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        o_ref[...] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-20)
        ).astype(o_ref.dtype)


def _paged_decode_kernel(
    table_ref,  # [B, n_pages] i32 (scalar prefetch) — consumed by index maps
    len_ref,    # [B] i32 (scalar prefetch) — per-batch valid KV prefix length
    q_ref,      # [Hq, D]
    k_ref,      # [block_size, Hkv, D] — one page, fetched via the page table
    v_ref,      # [block_size, Hkv, D]
    o_ref,      # [Hq, D]
    m_scr,
    l_scr,
    acc_scr,
    *,
    scale: float,
    block_k: int,
    n_kv: int,
    group: int,
):
    """Page-table decode: the math is the dense split-KV kernel's — only the
    *addressing* differs.  ``table_ref`` is consumed by the BlockSpec index
    maps (scalar prefetch drives the K/V page DMA), so logical position
    ``pi·block_size + j`` of batch row ``b`` streams from physical pool block
    ``table[b, pi]`` while the online-softmax state never notices."""
    del table_ref
    _decode_kernel(
        len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
        scale=scale, block_k=block_k, n_kv=n_kv, group=group,
    )


def paged_decode_attention_fwd(
    q: jax.Array,           # [B, Hq, D]
    pool_k: jax.Array,      # [P, block_size, Hkv, D] — shared block pool
    pool_v: jax.Array,      # [P, block_size, Hkv, D]
    page_table: jax.Array,  # [B, n_pages] i32 — pool block id per logical page
    kv_len: jax.Array,      # [] or [B] i32 — valid prefix length per row
    *,
    interpret: bool = True,
) -> jax.Array:
    """Single-token GQA decode over a block-sparse (paged) KV cache.

    Logical KV position ``t`` of batch row ``b`` lives at pool row
    ``(page_table[b, t // block_size], t % block_size)``.  The sequential
    grid axis walks pages instead of contiguous cache blocks; the page id is
    read from SMEM (scalar prefetch) inside the K/V index maps, so each
    page's DMA is issued directly against the pool — no dense gather of the
    cache ever materializes.  Entries beyond ``ceil(kv_len / block_size)``
    may be garbage: they are clipped into range (the DMA must stay in
    bounds) and their scores are masked by ``kv_len`` exactly like the dense
    kernel's tail.
    """
    b, hq, d = q.shape
    p, block_size, hkv, _ = pool_k.shape
    n_pages = page_table.shape[1]
    group = hq // hkv
    scale = 1.0 / math.sqrt(d)
    lens = jnp.broadcast_to(
        jnp.asarray(kv_len, jnp.int32).reshape(-1), (b,)
    )
    table = jnp.clip(page_table.astype(jnp.int32), 0, p - 1)

    kernel = functools.partial(
        _paged_decode_kernel, scale=scale, block_k=block_size,
        n_kv=n_pages, group=group,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, n_pages),
        in_specs=[
            pl.BlockSpec((None, hq, d), lambda bi, pi, tab, lens: (bi, 0, 0)),
            pl.BlockSpec(
                (None, block_size, hkv, d),
                lambda bi, pi, tab, lens: (tab[bi, pi], 0, 0, 0),
            ),
            pl.BlockSpec(
                (None, block_size, hkv, d),
                lambda bi, pi, tab, lens: (tab[bi, pi], 0, 0, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (None, hq, d), lambda bi, pi, tab, lens: (bi, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((hq, 1), jnp.float32),
            pltpu.VMEM((hq, 1), jnp.float32),
            pltpu.VMEM((hq, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hq, d), q.dtype),
        interpret=interpret,
        **(
            {}
            if interpret
            else {
                "compiler_params": pltpu.CompilerParams(
                    dimension_semantics=("parallel", "arbitrary")
                )
            }
        ),
    )(table, lens, q, pool_k, pool_v)
    return out


def decode_attention_fwd(
    q: jax.Array,        # [B, Hq, D]
    k_cache: jax.Array,  # [B, S, Hkv, D]
    v_cache: jax.Array,  # [B, S, Hkv, D]
    kv_len: jax.Array,   # [] or [B] i32 — ragged per-batch prefix lengths
    *,
    block_k: int = 512,
    interpret: bool = True,
) -> jax.Array:
    b, hq, d = q.shape
    _, s, hkv, _ = k_cache.shape
    group = hq // hkv
    block_k = min(block_k, s)
    assert s % block_k == 0
    n_kv = s // block_k
    scale = 1.0 / math.sqrt(d)
    # Scalar and per-batch (continuous batching / async-slot cache) lengths
    # share one kernel: the scalar broadcasts to a [B] SMEM vector.
    lens = jnp.broadcast_to(
        jnp.asarray(kv_len, jnp.int32).reshape(-1), (b,)
    )

    kernel = functools.partial(
        _decode_kernel, scale=scale, block_k=block_k, n_kv=n_kv, group=group
    )

    out = pl.pallas_call(
        kernel,
        grid=(b, n_kv),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((None, hq, d), lambda bi, ki: (bi, 0, 0)),
            pl.BlockSpec((None, block_k, hkv, d), lambda bi, ki: (bi, ki, 0, 0)),
            pl.BlockSpec((None, block_k, hkv, d), lambda bi, ki: (bi, ki, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, hq, d), lambda bi, ki: (bi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((hq, 1), jnp.float32),
            pltpu.VMEM((hq, 1), jnp.float32),
            pltpu.VMEM((hq, d), jnp.float32),
        ],
        interpret=interpret,
        **(
            {}
            if interpret
            else {
                "compiler_params": pltpu.CompilerParams(
                    dimension_semantics=("parallel", "arbitrary")
                )
            }
        ),
    )(lens, q, k_cache, v_cache)
    return out
