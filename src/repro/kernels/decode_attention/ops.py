"""Jit'd public wrapper for the decode attention kernel."""

from __future__ import annotations

import functools

import jax

from .decode_attention import decode_attention_fwd, paged_decode_attention_fwd
from .tree_decode_attention import (
    paged_tree_decode_attention_fwd,
    tree_decode_attention_fwd,
)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(
    q, k_cache, v_cache, kv_len, *, block_k: int = 512,
    interpret: bool | None = None,
):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return decode_attention_fwd(
        q, k_cache, v_cache, kv_len, block_k=block_k, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(
    q, pool_k, pool_v, page_table, kv_len, *, interpret: bool | None = None,
):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return paged_decode_attention_fwd(
        q, pool_k, pool_v, page_table, kv_len, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def tree_decode_attention(
    q, k_cache, v_cache, k_spec, v_spec, kv_len, tree_mask=None, *,
    block_k: int = 512, interpret: bool | None = None,
):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return tree_decode_attention_fwd(
        q, k_cache, v_cache, k_spec, v_spec, kv_len, tree_mask,
        block_k=block_k, interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_tree_decode_attention(
    q, pool_k, pool_v, page_table, k_spec, v_spec, kv_len, tree_mask=None, *,
    interpret: bool | None = None,
):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return paged_tree_decode_attention_fwd(
        q, pool_k, pool_v, page_table, k_spec, v_spec, kv_len, tree_mask,
        interpret=interpret,
    )
