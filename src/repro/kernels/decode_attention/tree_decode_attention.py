"""Pallas TPU kernel: tree-batched speculative decode attention.

Frontier expansion scores all ``A`` candidate children of a settled leaf in
one forward — the queries differ only in their final token, so the shared
prefix K/V should stream through VMEM ONCE for the whole candidate set, not
once per candidate.  The kernel is the split-KV decode kernel widened to an
``[A, Hq, D]`` query tile: prefix blocks fold into the online-softmax state
exactly as before (now per candidate), and the last grid step folds in the
speculative tail — each candidate's own K/V entry, which lives OUTSIDE the
cache — under a caller-supplied ``[A, A]`` tree mask (identity for a flat
frontier: candidate ``i`` attends only tail entry ``i``).

The paged variant walks the page table via scalar prefetch, identical to
``_paged_decode_kernel``: only the addressing differs, the math is shared.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _tree_decode_kernel(
    len_ref,    # [B] i32 (SMEM) — per-batch valid KV prefix length
    q_ref,      # [A, Hq, D]
    k_ref,      # [block_k, Hkv, D]
    v_ref,      # [block_k, Hkv, D]
    ks_ref,     # [A, Hkv, D] — speculative tail keys for this row
    vs_ref,     # [A, Hkv, D]
    mask_ref,   # [A, A] i32 — tree mask (nonzero = attend)
    o_ref,      # [A, Hq, D]
    m_scr,      # [A, Hq, 1] f32
    l_scr,      # [A, Hq, 1] f32
    acc_scr,    # [A, Hq, D] f32
    *,
    scale: float,
    block_k: int,
    n_kv: int,
    group: int,
):
    ki = pl.program_id(1)
    kv_len = len_ref[pl.program_id(0)]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(ki * block_k < kv_len)
    def _compute():
        q = q_ref[...].astype(jnp.float32)                    # [A, Hq, D]
        k = k_ref[...].astype(jnp.float32)                    # [bk, Hkv, D]
        v = v_ref[...].astype(jnp.float32)
        a, hq, _ = q.shape
        bk = k.shape[0]
        kg = jnp.repeat(k, group, axis=1)                     # [bk, Hq, D]
        s = jnp.einsum("ahd,jhd->ahj", q, kg) * scale         # [A, Hq, bk]
        kv_pos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (a, hq, bk), 2
        )
        valid = kv_pos < kv_len
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        vg = jnp.repeat(v, group, axis=1)                     # [bk, Hq, D]
        acc_scr[...] = acc_scr[...] * alpha + jnp.einsum("ahj,jhd->ahd", p, vg)
        m_scr[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _tail_and_finalize():
        # Fold the speculative tail (A extra K/V entries, masked by the tree
        # mask) into the online-softmax state, then normalize.  Runs after
        # the prefix fold of this block (pl.when bodies run in order).
        q = q_ref[...].astype(jnp.float32)                    # [A, Hq, D]
        ks = jnp.repeat(
            ks_ref[...].astype(jnp.float32), group, axis=1
        )                                                     # [A, Hq, D]
        vs = jnp.repeat(vs_ref[...].astype(jnp.float32), group, axis=1)
        st = jnp.einsum("ahd,jhd->ahj", q, ks) * scale        # [A, Hq, A]
        attend = mask_ref[...] != 0                           # [A, A]
        st = jnp.where(attend[:, None, :], st, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(st, axis=-1, keepdims=True))
        p = jnp.where(attend[:, None, :], jnp.exp(st - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc_scr[...] * alpha + jnp.einsum("ahj,jhd->ahd", p, vs)
        o_ref[...] = (acc / jnp.maximum(l, 1e-20)).astype(o_ref.dtype)


def _paged_tree_decode_kernel(
    table_ref,  # [B, n_pages] i32 (scalar prefetch) — consumed by index maps
    len_ref,    # [B] i32 (scalar prefetch)
    q_ref,
    k_ref,      # [block_size, Hkv, D] — one page, fetched via the page table
    v_ref,
    ks_ref,
    vs_ref,
    mask_ref,
    o_ref,
    m_scr,
    l_scr,
    acc_scr,
    *,
    scale: float,
    block_k: int,
    n_kv: int,
    group: int,
):
    del table_ref
    _tree_decode_kernel(
        len_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, mask_ref, o_ref,
        m_scr, l_scr, acc_scr,
        scale=scale, block_k=block_k, n_kv=n_kv, group=group,
    )


def _prep_mask(tree_mask, a):
    if tree_mask is None:
        return jnp.eye(a, dtype=jnp.int32)
    return jnp.asarray(tree_mask).astype(jnp.int32)


def tree_decode_attention_fwd(
    q: jax.Array,           # [B, A, Hq, D]
    k_cache: jax.Array,     # [B, S, Hkv, D]
    v_cache: jax.Array,     # [B, S, Hkv, D]
    k_spec: jax.Array,      # [B, A, Hkv, D]
    v_spec: jax.Array,      # [B, A, Hkv, D]
    kv_len: jax.Array,      # [] or [B] i32
    tree_mask: jax.Array | None = None,   # [A, A]; None = identity
    *,
    block_k: int = 512,
    interpret: bool = True,
) -> jax.Array:
    b, a, hq, d = q.shape
    _, s, hkv, _ = k_cache.shape
    group = hq // hkv
    block_k = min(block_k, s)
    assert s % block_k == 0
    n_kv = s // block_k
    scale = 1.0 / math.sqrt(d)
    lens = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32).reshape(-1), (b,))
    mask = _prep_mask(tree_mask, a)

    kernel = functools.partial(
        _tree_decode_kernel, scale=scale, block_k=block_k, n_kv=n_kv,
        group=group,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, n_kv),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((None, a, hq, d), lambda bi, ki: (bi, 0, 0, 0)),
            pl.BlockSpec((None, block_k, hkv, d), lambda bi, ki: (bi, ki, 0, 0)),
            pl.BlockSpec((None, block_k, hkv, d), lambda bi, ki: (bi, ki, 0, 0)),
            pl.BlockSpec((None, a, hkv, d), lambda bi, ki: (bi, 0, 0, 0)),
            pl.BlockSpec((None, a, hkv, d), lambda bi, ki: (bi, 0, 0, 0)),
            pl.BlockSpec((a, a), lambda bi, ki: (0, 0)),
        ],
        out_specs=pl.BlockSpec((None, a, hq, d), lambda bi, ki: (bi, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, a, hq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((a, hq, 1), jnp.float32),
            pltpu.VMEM((a, hq, 1), jnp.float32),
            pltpu.VMEM((a, hq, d), jnp.float32),
        ],
        interpret=interpret,
        **(
            {}
            if interpret
            else {
                "compiler_params": pltpu.CompilerParams(
                    dimension_semantics=("parallel", "arbitrary")
                )
            }
        ),
    )(lens, q, k_cache, v_cache, k_spec, v_spec, mask)
    return out


def paged_tree_decode_attention_fwd(
    q: jax.Array,           # [B, A, Hq, D]
    pool_k: jax.Array,      # [P, block_size, Hkv, D]
    pool_v: jax.Array,      # [P, block_size, Hkv, D]
    page_table: jax.Array,  # [B, n_pages] i32
    k_spec: jax.Array,      # [B, A, Hkv, D]
    v_spec: jax.Array,      # [B, A, Hkv, D]
    kv_len: jax.Array,      # [] or [B] i32
    tree_mask: jax.Array | None = None,
    *,
    interpret: bool = True,
) -> jax.Array:
    """Tree decode whose shared prefix lives in a paged block pool.

    The sequential grid axis walks logical pages; the physical pool block id
    comes from scalar-prefetched ``page_table`` inside the K/V index maps,
    so no dense gather of the prefix ever materializes.  Garbage table
    entries beyond the live pages are clipped into range and masked by
    ``kv_len``, exactly like ``paged_decode_attention_fwd``.
    """
    b, a, hq, d = q.shape
    p, block_size, hkv, _ = pool_k.shape
    n_pages = page_table.shape[1]
    group = hq // hkv
    scale = 1.0 / math.sqrt(d)
    lens = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32).reshape(-1), (b,))
    table = jnp.clip(page_table.astype(jnp.int32), 0, p - 1)
    mask = _prep_mask(tree_mask, a)

    kernel = functools.partial(
        _paged_tree_decode_kernel, scale=scale, block_k=block_size,
        n_kv=n_pages, group=group,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, n_pages),
        in_specs=[
            pl.BlockSpec(
                (None, a, hq, d), lambda bi, pi, tab, lens: (bi, 0, 0, 0)
            ),
            pl.BlockSpec(
                (None, block_size, hkv, d),
                lambda bi, pi, tab, lens: (tab[bi, pi], 0, 0, 0),
            ),
            pl.BlockSpec(
                (None, block_size, hkv, d),
                lambda bi, pi, tab, lens: (tab[bi, pi], 0, 0, 0),
            ),
            pl.BlockSpec(
                (None, a, hkv, d), lambda bi, pi, tab, lens: (bi, 0, 0, 0)
            ),
            pl.BlockSpec(
                (None, a, hkv, d), lambda bi, pi, tab, lens: (bi, 0, 0, 0)
            ),
            pl.BlockSpec((a, a), lambda bi, pi, tab, lens: (0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (None, a, hq, d), lambda bi, pi, tab, lens: (bi, 0, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((a, hq, 1), jnp.float32),
            pltpu.VMEM((a, hq, 1), jnp.float32),
            pltpu.VMEM((a, hq, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, a, hq, d), q.dtype),
        interpret=interpret,
        **(
            {}
            if interpret
            else {
                "compiler_params": pltpu.CompilerParams(
                    dimension_semantics=("parallel", "arbitrary")
                )
            }
        ),
    )(table, lens, q, pool_k, pool_v, k_spec, v_spec, mask)
    return out
