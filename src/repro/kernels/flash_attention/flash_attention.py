"""Pallas TPU kernel: blockwise causal GQA flash attention (fwd).

Grid: (batch·q_heads, q_blocks, kv_blocks) with the kv dimension sequential
("arbitrary") so the online-softmax running state (m, l, acc) persists in
VMEM scratch across kv iterations.  GQA is handled in the K/V BlockSpec
index maps (kv head = q head // group) — no materialized head broadcast.
Fully-masked (future) kv blocks are skipped with ``pl.when``, so causal
compute is ~half of the dense S² (unlike the jnp oracle, which masks).

VMEM per program ≈ (block_q + 2·block_k)·head_dim·2B + block_q·block_k·4B
+ acc block_q·head_dim·4B — e.g. (256, 512) blocks at D=128: ~1.1 MB, far
under the ~16 MB/core budget; MXU-aligned (multiples of 128) throughout.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(
    q_ref,      # [block_q, D]
    k_ref,      # [block_k, D]
    v_ref,      # [block_k, D]
    o_ref,      # [block_q, D]
    m_scr,      # [block_q, 1] f32
    l_scr,      # [block_q, 1] f32
    acc_scr,    # [block_q, D] f32
    *,
    scale: float,
    block_q: int,
    block_k: int,
    n_kv: int,
    causal: bool,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kv_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    # Causal block skipping: compute only blocks intersecting the triangle.
    run = (not causal) or (ki * block_k <= qi * block_q + block_q - 1)

    @pl.when(run)
    def _compute():
        q = q_ref[...].astype(jnp.float32)
        k = k_ref[...].astype(jnp.float32)
        v = v_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                             # [bq, bk]
        if causal:
            s = jnp.where(q_pos >= kv_pos, s, NEG_INF)
        m_prev = m_scr[...]                                   # [bq, 1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        if causal:
            p = jnp.where(q_pos >= kv_pos, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        o_ref[...] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-20)
        ).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jax.Array,   # [B, Sq, Hq, D]
    k: jax.Array,   # [B, Sk, Hkv, D]
    v: jax.Array,   # [B, Sk, Hkv, D]
    *,
    causal: bool = True,
    block_q: int = 256,
    block_k: int = 512,
    interpret: bool = True,
) -> jax.Array:
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    group = hq // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0
    n_q, n_kv = sq // block_q, sk // block_k
    scale = 1.0 / math.sqrt(d)

    # [B, S, H, D] -> [B, H, S, D] so blocks are (seq, head_dim) tiles.
    qt = jnp.swapaxes(q, 1, 2).reshape(b * hq, sq, d)
    kt = jnp.swapaxes(k, 1, 2)                     # [B, Hkv, Sk, D]
    vt = jnp.swapaxes(v, 1, 2)

    kernel = functools.partial(
        _fa_kernel,
        scale=scale,
        block_q=block_q,
        block_k=block_k,
        n_kv=n_kv,
        causal=causal,
    )

    out = pl.pallas_call(
        kernel,
        grid=(b * hq, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec(
                (None, None, block_k, d),
                lambda bh, qi, ki, hq=hq, group=group: (
                    bh // hq, (bh % hq) // group, ki, 0
                ),
            ),
            pl.BlockSpec(
                (None, None, block_k, d),
                lambda bh, qi, ki, hq=hq, group=group: (
                    bh // hq, (bh % hq) // group, ki, 0
                ),
            ),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
        **(
            {}
            if interpret
            else {
                "compiler_params": pltpu.CompilerParams(
                    dimension_semantics=("parallel", "parallel", "arbitrary")
                )
            }
        ),
    )(qt, kt, vt)

    return jnp.swapaxes(out.reshape(b, hq, sq, d), 1, 2)
