"""Jit'd public wrapper: Pallas on TPU, interpret-mode validation on CPU."""

from __future__ import annotations

import functools

import jax

from .flash_attention import flash_attention_fwd


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def flash_attention(
    q, k, v, *, causal: bool = True, block_q: int = 256, block_k: int = 512,
    interpret: bool | None = None,
):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return flash_attention_fwd(
        q, k, v, causal=causal, block_q=block_q, block_k=block_k,
        interpret=interpret,
    )
