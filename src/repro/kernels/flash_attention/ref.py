"""Pure-jnp oracle for the flash attention kernel."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,   # [B, Sq, Hq, D]
    k: jax.Array,   # [B, Sk, Hkv, D]
    v: jax.Array,   # [B, Sk, Hkv, D]
    *,
    causal: bool = True,
) -> jax.Array:
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    group = hq // hkv
    scale = 1.0 / math.sqrt(d)
    qf = q.astype(jnp.float32).reshape(b, sq, hkv, group, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf) * scale
    if causal:
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p, vf)
    o = jnp.moveaxis(o.reshape(b, hq, sq, d), 1, 2)
    return o.astype(q.dtype)
