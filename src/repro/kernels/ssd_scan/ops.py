"""Jit'd public wrapper for the SSD scan kernel."""

from __future__ import annotations

import functools

import jax

from .ssd_scan import ssd_scan_fwd


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(xdt, dA, Bmat, Cmat, *, chunk: int = 256, interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return ssd_scan_fwd(xdt, dA, Bmat, Cmat, chunk=chunk, interpret=interpret)
