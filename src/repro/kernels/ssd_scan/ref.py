"""Oracles for the SSD scan kernel: the chunked jnp path used by the model
and the O(S) sequential recurrence (ground truth)."""

from ...models.ssm import ssd_chunked, ssd_sequential_ref


def ssd_ref_chunked(xdt, dA, Bmat, Cmat, chunk=256):
    y, _ = ssd_chunked(xdt, dA, Bmat, Cmat, chunk)
    return y


def ssd_ref_sequential(xdt, dA, Bmat, Cmat):
    y, _ = ssd_sequential_ref(xdt, dA, Bmat, Cmat)
    return y
