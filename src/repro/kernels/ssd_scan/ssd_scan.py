"""Pallas TPU kernel: Mamba-2 SSD chunked scan.

The SSD recurrence  h_t = exp(dA_t)·h_{t-1} + x_t ⊗ B_t,  y_t = C_t·h_t
is computed chunk-by-chunk: a quadratic intra-chunk term (two MXU matmuls
over [Q, Q] score tiles) plus an inter-chunk state pass.  The [P, N] state
for one (batch, head) lives in VMEM scratch across the sequential chunk grid
axis — the state never round-trips to HBM, which is the TPU-native version
of the paper's "keep the recurrent state in SRAM" GPU formulation.

Grid: (B·H, n_chunks); chunk axis sequential.  B/C are shared across heads
(Mamba-2 single group) and their BlockSpec index maps select by batch only.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    xdt_ref,    # [Q, P]   (x · dt)
    da_ref,     # [Q, 1]   (dt · A, negative)
    b_ref,      # [Q, N]
    c_ref,      # [Q, N]
    y_ref,      # [Q, P]
    h_scr,      # [P, N] f32 — carried state
    *,
    chunk: int,
):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    xdt = xdt_ref[...].astype(jnp.float32)        # [Q, P]
    da = da_ref[...].astype(jnp.float32)[:, 0]    # [Q]
    bm = b_ref[...].astype(jnp.float32)           # [Q, N]
    cm = c_ref[...].astype(jnp.float32)           # [Q, N]

    cum = jnp.cumsum(da)                          # [Q]
    total = cum[-1]

    # Intra-chunk: scores[i, j] = (C_i · B_j) · exp(cum_i − cum_j) for i ≥ j.
    cb = jax.lax.dot_general(
        cm, bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                             # [Q, Q]
    seg = cum[:, None] - cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(ii >= jj, jnp.exp(seg), 0.0)
    y_intra = jax.lax.dot_general(
        cb * decay, xdt, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                             # [Q, P]

    # Inter-chunk: y_i += exp(cum_i) · C_i · h_prevᵀ.
    h_prev = h_scr[...]                           # [P, N]
    y_inter = jax.lax.dot_general(
        cm, h_prev, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * jnp.exp(cum)[:, None]                     # [Q, P]

    y_ref[...] = (y_intra + y_inter).astype(y_ref.dtype)

    # State update: h ← exp(total)·h + Σ_j exp(total − cum_j)·xdt_j ⊗ B_j.
    w_end = jnp.exp(total - cum)                  # [Q]
    s_chunk = jax.lax.dot_general(
        xdt * w_end[:, None], bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                             # [P, N]
    h_scr[...] = h_prev * jnp.exp(total) + s_chunk


def ssd_scan_fwd(
    xdt: jax.Array,   # [B, S, H, P]
    dA: jax.Array,    # [B, S, H]
    Bmat: jax.Array,  # [B, S, N]
    Cmat: jax.Array,  # [B, S, N]
    *,
    chunk: int = 256,
    interpret: bool = True,
) -> jax.Array:
    b, s, h, p = xdt.shape
    n = Bmat.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk

    # [B, S, H, P] → [B·H, S, P]; dA → [B·H, S, 1]; B/C stay [B, S, N].
    xr = jnp.moveaxis(xdt, 2, 1).reshape(b * h, s, p)
    dar = jnp.moveaxis(dA, 2, 1).reshape(b * h, s, 1)

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y = pl.pallas_call(
        kernel,
        grid=(b * h, nc),
        in_specs=[
            pl.BlockSpec((None, chunk, p), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((None, chunk, 1), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((None, chunk, n), lambda bh, ci, h=h: (bh // h, ci, 0)),
            pl.BlockSpec((None, chunk, n), lambda bh, ci, h=h: (bh // h, ci, 0)),
        ],
        out_specs=pl.BlockSpec((None, chunk, p), lambda bh, ci: (bh, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, p), xdt.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
        **(
            {}
            if interpret
            else {
                "compiler_params": pltpu.CompilerParams(
                    dimension_semantics=("parallel", "arbitrary")
                )
            }
        ),
    )(xr, dar, Bmat, Cmat)
    return jnp.moveaxis(y.reshape(b, h, s, p), 1, 2)
