# Pallas TPU kernels for the compute hot-spots:
#   flash_attention  — blockwise causal GQA attention (train/prefill)
#   decode_attention — split-KV single-token decode w/ online LSE merge
#   ssd_scan         — Mamba-2 SSD chunked scan with carried state
#   tree_select      — fused UCB-score + masked argmax over children tables
#                      (the paper's master-side selection hot-op, batched)
# Each subpackage: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
# wrapper with interpret/backend switch), ref.py (pure-jnp oracle).
