"""User pass-rate prediction system — the paper's production deployment
(App. C), reproduced end-to-end on generated tap-game levels.

Pipeline (paper Fig. 7):
  1. generate levels of varying difficulty;
  2. run a 10-rollout WU-UCT bot (≈ average player) and a 100-rollout bot
     (≈ skilled player) on each level, several gameplays each;
  3. extract the paper's six features (pass-rate, mean/median step ratio,
     per bot);
  4. fit a linear regressor to (synthetic) human pass-rates;
  5. report MAE (paper: 8.6% over 130 released levels).

Human pass-rates are synthesized from a hidden difficulty model with noise —
the system never sees the difficulty directly, only gameplay features.

Run:  PYTHONPATH=src python examples/passrate_prediction.py [--levels 12]
"""

import argparse

import jax
import numpy as np

from repro.core import SearchSpec, play_episode
from repro.envs import make_tap_game


def gameplay_features(env, budget, n_games, seed, step_budget):
    cfg = SearchSpec(
        algo="wu_uct", num_simulations=budget, wave_size=min(budget, 10),
        max_depth=10, max_sim_steps=12, max_width=5, gamma=1.0,
    ).config
    passes, ratios = [], []
    for g in range(n_games):
        ret, moves, done = play_episode(
            env, cfg, jax.random.PRNGKey(seed * 977 + g), max_moves=step_budget
        )
        solved = done and moves < step_budget or ret > 0.9
        passes.append(float(solved))
        ratios.append(moves / step_budget)
    return [np.mean(passes), np.mean(ratios), np.median(ratios)]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--levels", type=int, default=14)
    ap.add_argument("--games", type=int, default=3)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    rows, human = [], []
    for lv in range(args.levels):
        # Difficulty knobs: more colors + higher goal = harder.
        colors = int(rng.integers(3, 6))
        goal = int(rng.integers(6, 14))
        budget_steps = int(rng.integers(16, 26))
        env = make_tap_game(
            grid_size=6, num_colors=colors, goal_count=goal,
            step_budget=budget_steps,
        )
        feats = gameplay_features(env, 10, args.games, lv * 2 + 1, budget_steps)
        feats += gameplay_features(env, 100, args.games, lv * 2 + 2, budget_steps)
        rows.append(feats)
        # Hidden human model: logistic in difficulty + noise.
        difficulty = 0.9 * colors + 0.45 * goal - 0.35 * budget_steps
        p = 1.0 / (1.0 + np.exp(0.55 * difficulty))
        human.append(np.clip(p + rng.normal(0, 0.05), 0, 1))
        print(
            f"level {lv:2d}: colors={colors} goal={goal:2d} steps={budget_steps} "
            f"features={[f'{f:.2f}' for f in feats]} human={human[-1]:.2f}"
        )

    x = np.asarray(rows)
    y = np.asarray(human)
    n_train = max(2, int(0.7 * len(y)))
    xd = np.concatenate([x, np.ones((len(y), 1))], axis=1)
    # Ridge regression (the paper fits a linear regressor on 300 levels; at
    # example scale regularization stands in for the larger training set).
    lam = 0.05
    a = xd[:n_train]
    w = np.linalg.solve(
        a.T @ a + lam * np.eye(a.shape[1]), a.T @ y[:n_train]
    )
    pred = np.clip(xd @ w, 0, 1)
    mae_train = np.abs(pred[:n_train] - y[:n_train]).mean()
    mae_test = np.abs(pred[n_train:] - y[n_train:]).mean()
    print(
        f"\npass-rate prediction MAE: train={100 * mae_train:.1f}% "
        f"test={100 * mae_test:.1f}%  (paper production system: 8.6%)"
    )


if __name__ == "__main__":
    main()
