"""Training example: train a policy LM with the full substrate — data
pipeline, AdamW, checkpointing with crash-recovery, gradient compression.

This is the CPU-scale version of the rollout-policy training the paper's
systems perform (A3C for Joy City, PPO distillation for Atari, App. C/D);
the same `launch/train.py` path drives pod-scale configs via the dry-run.

Run:  PYTHONPATH=src python examples/train_policy.py
      PYTHONPATH=src python examples/train_policy.py --model-100m  # full-size
"""

import argparse
import dataclasses
import os
import shutil
import tempfile

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.models.config import ModelConfig
from repro.models import init_params
from repro.training import (
    AdamWConfig,
    CheckpointManager,
    SyntheticStream,
    TrainConfig,
    adamw_init,
    make_train_step,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--model-100m", action="store_true",
                    help="~100M-param config (slow on CPU)")
    args = ap.parse_args()

    if args.model_100m:
        cfg = ModelConfig(
            name="policy-100m", family="dense", num_layers=8, d_model=768,
            num_heads=12, num_kv_heads=4, d_ff=2048, vocab_size=32000,
            dtype=jnp.float32, attn_chunk=256, loss_chunk=128,
        )
        batch, seq = 4, 256
    else:
        cfg = dataclasses.replace(get_reduced("llama3-8b"), loss_chunk=64)
        batch, seq = 8, 64

    ckpt_dir = tempfile.mkdtemp(prefix="wu_uct_policy_")
    params = init_params(cfg, jax.random.PRNGKey(0))
    print(f"model {cfg.name}: "
          f"{sum(x.size for x in jax.tree.leaves(params)):,} params")

    tc = TrainConfig(
        optimizer=AdamWConfig(lr=3e-4, warmup_steps=10, total_steps=args.steps),
        compress_grads=True,   # int8 error-feedback wire emulation
    )
    step = jax.jit(make_train_step(cfg, tc), donate_argnums=(0, 1))
    opt = adamw_init(params)
    stream = SyntheticStream(cfg.vocab_size, batch, seq, seed=0)
    mgr = CheckpointManager(ckpt_dir, keep=2)

    half = args.steps // 2
    for s in range(half):
        params, opt, m = step(params, opt,
                              jax.tree.map(jnp.asarray, stream.batch_at(s)))
        if (s + 1) % 10 == 0:
            print(f"step {s + 1}: loss={float(m['loss']):.4f}")
    mgr.save(half, (params, opt), blocking=True)
    print(f"checkpoint at step {half}; simulating crash + restart ...")

    # --- crash recovery: fresh process state, restore, continue -----------
    params2 = init_params(cfg, jax.random.PRNGKey(42))   # "new job" params
    opt2 = adamw_init(params2)
    start, (params2, opt2) = mgr.restore((params2, opt2))
    assert start == half
    for s in range(start, args.steps):
        params2, opt2, m = step(params2, opt2,
                                jax.tree.map(jnp.asarray, stream.batch_at(s)))
        if (s + 1) % 10 == 0:
            print(f"step {s + 1}: loss={float(m['loss']):.4f}")
    print("resumed training reached final step — elastic restart path works")
    shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
