"""Quickstart: WU-UCT on the tap game, compared against sequential UCT.

Everything goes through the one front door: describe the search with a
``SearchSpec`` and build it with ``build_searcher`` — the same surface
covers every engine (wave/async), batch mode and baseline algorithm.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import time

import jax

from repro.core import SearchSpec, build_searcher, play_episode
from repro.envs import make_tap_game


def main() -> None:
    env = make_tap_game(grid_size=6, num_colors=4, goal_count=10, step_budget=20)
    key = jax.random.PRNGKey(0)
    state = env.init(key)
    print(f"env: {env.name}; initial grid:\n{state.grid}\n")

    for algo, wave in [("uct", 1), ("wu_uct", 16)]:
        spec = SearchSpec(
            algo=algo, num_simulations=64, wave_size=wave, max_depth=10,
            max_sim_steps=15, max_width=5, gamma=1.0,
        )
        search = build_searcher(env, spec)
        res = jax.block_until_ready(search(state, key))  # compile
        t0 = time.perf_counter()
        res = jax.block_until_ready(search(state, jax.random.PRNGKey(1)))
        dt = time.perf_counter() - t0
        cfg = spec.config
        print(
            f"{algo:8s} W={cfg.wave_size:2d}: action={int(res.action)} "
            f"(cell {int(res.action) // 6},{int(res.action) % 6}) "
            f"tree_size={int(res.tree_size)} wall={dt * 1e3:.1f}ms "
            f"master_rounds={cfg.num_simulations // cfg.wave_size}"
        )

    print("\nplaying one full episode with WU-UCT (16 in-flight workers)...")
    spec = SearchSpec(
        algo="wu_uct", num_simulations=64, wave_size=16, max_depth=10,
        max_sim_steps=15, max_width=5, gamma=1.0,
    )
    ret, moves, done = play_episode(
        env, spec.config, jax.random.PRNGKey(7), max_moves=20,
        searcher=build_searcher(env, spec),
    )
    print(f"episode return={ret:.3f}, game steps={moves}, solved={done}")


if __name__ == "__main__":
    main()
