"""Quickstart: WU-UCT on the tap game, compared against sequential UCT.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import time

import jax

from repro.core import make_config, make_searcher, play_episode
from repro.envs import make_tap_game


def main() -> None:
    env = make_tap_game(grid_size=6, num_colors=4, goal_count=10, step_budget=20)
    key = jax.random.PRNGKey(0)
    state = env.init(key)
    print(f"env: {env.name}; initial grid:\n{state.grid}\n")

    for algo, wave in [("uct", 1), ("wu_uct", 16)]:
        cfg = make_config(
            algo, num_simulations=64, wave_size=wave, max_depth=10,
            max_sim_steps=15, max_width=5, gamma=1.0,
        )
        search = make_searcher(env, cfg)
        res = jax.block_until_ready(search(state, key))  # compile
        t0 = time.perf_counter()
        res = jax.block_until_ready(search(state, jax.random.PRNGKey(1)))
        dt = time.perf_counter() - t0
        print(
            f"{algo:8s} W={wave:2d}: action={int(res.action)} "
            f"(cell {int(res.action) // 6},{int(res.action) % 6}) "
            f"tree_size={int(res.tree_size)} wall={dt * 1e3:.1f}ms "
            f"master_rounds={cfg.num_simulations // cfg.wave_size}"
        )

    print("\nplaying one full episode with WU-UCT (16 in-flight workers)...")
    cfg = make_config(
        "wu_uct", num_simulations=64, wave_size=16, max_depth=10,
        max_sim_steps=15, max_width=5, gamma=1.0,
    )
    ret, moves, done = play_episode(env, cfg, jax.random.PRNGKey(7), max_moves=20)
    print(f"episode return={ret:.3f}, game steps={moves}, solved={done}")


if __name__ == "__main__":
    main()
