"""End-to-end driver: serve a small LM with batched requests AND run
WU-UCT token-level search against it — the paper's technique plugged into
the framework's serving stack (the Atari protocol with an LM as both the
environment and the rollout policy).

Pipeline:
  1. build a reduced llama3-family policy LM (any --arch works);
  2. briefly train it on a synthetic Zipf stream so it has real structure;
  3. serve a batch of requests through the continuous-batching engine;
  4. run WU-UCT over the token environment through the search front door
     (``SearchSpec`` + ``build_searcher``) and compare the searched
     continuation's reward against greedy decoding — search should win;
  5. serve a *batch* of search requests through ``SearchService`` — B
     independent trees in one program, all rollout slots evaluated by one
     model forward per master tick (``ModelEvaluator``).

Run:  PYTHONPATH=src python examples/serve_search.py [--arch llama3-8b]
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.core import SearchSpec, build_searcher
from repro.envs.token_env import make_token_env
from repro.models import init_params
from repro.serving import SearchService, ServeConfig, ServingEngine
from repro.training import AdamWConfig, SyntheticStream, TrainConfig, adamw_init, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--train-steps", type=int, default=30)
    ap.add_argument("--vocab", type=int, default=128)
    args = ap.parse_args()

    cfg = dataclasses.replace(get_reduced(args.arch), vocab_size=args.vocab)
    params = init_params(cfg, jax.random.PRNGKey(0))

    # --- 1. quick policy training on synthetic data -----------------------
    tc = TrainConfig(optimizer=AdamWConfig(lr=1e-3, warmup_steps=5,
                                           total_steps=args.train_steps))
    step = jax.jit(make_train_step(cfg, tc), donate_argnums=(0, 1))
    opt = adamw_init(params)
    stream = SyntheticStream(cfg.vocab_size, batch_size=8, seq_len=48, seed=0)
    for s in range(args.train_steps):
        params, opt, m = step(params, opt,
                              jax.tree.map(jnp.asarray, stream.batch_at(s)))
        if (s + 1) % 10 == 0:
            print(f"train step {s + 1}: loss={float(m['loss']):.3f}")

    # --- 2. batched serving ----------------------------------------------
    engine = ServingEngine(
        cfg, params, ServeConfig(batch_slots=4, max_len=48, eos_token=1)
    )
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(2, cfg.vocab_size, size=8)) for _ in range(6)]
    t0 = time.time()
    outputs = engine.run(prompts, max_ticks=64)
    n_tok = sum(len(o) for o in outputs)
    print(
        f"\nserved {len(prompts)} requests -> {n_tok} tokens "
        f"({n_tok / (time.time() - t0):.1f} tok/s on CPU)"
    )

    # --- 3. WU-UCT token search vs greedy decoding ------------------------
    prompt = jnp.asarray(prompts[0], jnp.int32)
    env = make_token_env(cfg, params, prompt, max_len=20, top_k=6, eos_token=1)
    spec = SearchSpec(
        algo="wu_uct", num_simulations=32, wave_size=8, max_depth=10,
        max_sim_steps=10, max_width=6, gamma=1.0,
    )
    search = build_searcher(env, spec)

    state = env.init(jax.random.PRNGKey(0))
    # Greedy continuation reward (action 0 = top-1 token at each step).
    g_state, g_reward = state, 0.0
    for _ in range(6):
        g_state, r, d = jax.jit(env.step)(g_state, jnp.int32(0))
        g_reward += float(r)
        if bool(d):
            break

    s_state, s_reward = state, 0.0
    key = jax.random.PRNGKey(1)
    for i in range(6):
        key, k = jax.random.split(key)
        res = search(s_state, k)
        s_state, r, d = jax.jit(env.step)(s_state, res.action)
        s_reward += float(r)
        if bool(d):
            break
    print(
        f"token search: greedy logp={g_reward:.3f}  "
        f"WU-UCT logp={s_reward:.3f}  (search ≥ greedy expected)"
    )

    # --- 4. batched search serving (one model forward per master tick) ----
    service = SearchService(
        cfg, params,
        SearchSpec(algo="wu_uct", engine="async", batch=4,
                   num_simulations=16, wave_size=4, max_depth=8,
                   max_sim_steps=8, max_width=6, gamma=1.0),
        top_k=6, max_len=20, eos_token=1,
    )
    t0 = time.time()
    tokens, res = service.decide(prompts[:4], jax.random.PRNGKey(2))
    print(
        f"search service: {len(tokens)} searched next-tokens {tokens} "
        f"in {time.time() - t0:.1f}s (B=4 trees, one LM forward per tick)"
    )


if __name__ == "__main__":
    main()
